"""L1 correctness: Pallas conv kernel vs pure-jnp oracle, bit-exact.

Hypothesis sweeps the kernel's full parameter space (shapes, strides,
padding, row/channel parallelism, both quantization widths) — the paper's
engine must be correct for *any* layer geometry the allocator produces.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_ws as kn
from compile.kernels import ref


def _rand(rng, shape, bits, frac=4):
    lim = max(1, (1 << (bits - 1)) // frac)
    dt = np.int8 if bits == 8 else np.int16
    return rng.integers(-lim, lim + 1, shape).astype(dt)


def _run_case(C, M, H, W, R, S, stride, pad, K, Mp, bits, seed, relu=True):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (C, H, W), bits)
    w = _rand(rng, (M, C, R, S), bits, frac=8)
    b = rng.integers(-200, 200, (M,)).astype(np.int32)
    ls = rng.integers(0, 3, (C,)).astype(np.int32)
    rs = rng.integers(0, 6, (M,)).astype(np.int32)
    out_k = kn.conv_ws(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(ls),
        jnp.asarray(rs), stride=stride, pad=pad, K=K, Mp=Mp, bits=bits,
        relu=relu,
    )
    out_r = ref.conv_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), jnp.asarray(ls),
        jnp.asarray(rs), stride=stride, pad=pad, bits=bits, relu=relu,
    )
    assert out_k.shape == out_r.shape
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    return out_k


@settings(max_examples=40, deadline=None)
@given(
    C=st.integers(1, 6),
    M=st.integers(1, 4),
    H=st.integers(3, 14),
    W=st.integers(3, 14),
    R=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 2),
    K=st.integers(1, 4),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
)
def test_conv_matches_oracle(C, M, H, W, R, stride, pad, K, bits, seed):
    S = R
    if H + 2 * pad < R or W + 2 * pad < S:
        return  # degenerate window
    _run_case(C, M, H, W, R, S, stride, pad, K, 0, bits, seed)


@settings(max_examples=20, deadline=None)
@given(
    mp_div=st.sampled_from([1, 2, 4]),
    K=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_channel_parallelism_is_numerics_neutral(mp_div, K, seed):
    """M' (output-channel parallelism) partitions work across grid programs;
    the result must not depend on it — the paper's allocator is free to pick
    any divisor (that's the whole point of the flexible buffer)."""
    M = 8
    out = _run_case(3, M, 9, 7, 3, 3, 1, 1, K, M // mp_div, 8, seed)
    base = _run_case(3, M, 9, 7, 3, 3, 1, 1, 1, 0, 8, seed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


@pytest.mark.parametrize("R,stride,pad", [(1, 1, 0), (3, 1, 1), (5, 1, 2),
                                          (3, 2, 1), (5, 2, 0), (7, 1, 3)])
def test_kernel_geometries(R, stride, pad):
    """Paper nets use 1x1..11x11 kernels (YOLO/AlexNet); exercise the odd
    geometries explicitly."""
    _run_case(4, 6, 16, 16, R, R, stride, pad, 2, 3, 8, 42)


@pytest.mark.parametrize("bits", [8, 16])
def test_asymmetric_kernel(bits):
    """R != S (paper Eq. 1 allows it)."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (2, 10, 12), bits)
    w = _rand(rng, (3, 2, 3, 5), bits)
    b = np.zeros(3, np.int32)
    ls = np.zeros(2, np.int32)
    rs = np.ones(3, np.int32)
    out_k = kn.conv_ws(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                       jnp.asarray(ls), jnp.asarray(rs), stride=1, pad=0,
                       K=2, bits=bits)
    out_r = ref.conv_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                         jnp.asarray(ls), jnp.asarray(rs), stride=1, pad=0,
                         bits=bits)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_saturation_clamps_both_rails():
    """Drive the accumulator past both rails; the epilogue must clamp
    exactly like the RTL truncate-with-saturate (paper Sec. 3.3)."""
    x = np.full((1, 4, 4), 127, np.int8)
    w_hi = np.full((1, 1, 3, 3), 127, np.int8)
    w_lo = np.full((1, 1, 3, 3), -128, np.int8)
    b = np.zeros(1, np.int32)
    ls = np.zeros(1, np.int32)
    rs = np.zeros(1, np.int32)
    hi = kn.conv_ws(jnp.asarray(x), jnp.asarray(w_hi), jnp.asarray(b),
                    jnp.asarray(ls), jnp.asarray(rs), pad=1, K=2, relu=False)
    lo = kn.conv_ws(jnp.asarray(x), jnp.asarray(w_lo), jnp.asarray(b),
                    jnp.asarray(ls), jnp.asarray(rs), pad=1, K=2, relu=False)
    assert int(np.max(np.asarray(hi))) == 127
    assert int(np.min(np.asarray(lo))) == -128


def test_rshift_is_arithmetic_floor():
    """-1 >> 1 must be -1 (floor), not 0 (trunc-toward-zero) — matches a
    hardware barrel shifter."""
    x = np.array([[[1]]], np.int8)
    w = np.array([[[[-1]]]], np.int8)
    b = np.zeros(1, np.int32)
    ls = np.zeros(1, np.int32)
    rs = np.ones(1, np.int32)
    out = kn.conv_ws(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                     jnp.asarray(ls), jnp.asarray(rs), pad=0, K=1,
                     relu=False)
    assert int(np.asarray(out)[0, 0, 0]) == -1


def test_zero_padding_matches_controller():
    """Padding handled by the controller's zeroMac must equal explicit
    zero-padded input."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 6, 6), 8)
    w = _rand(rng, (2, 2, 3, 3), 8)
    b = np.zeros(2, np.int32)
    ls = np.zeros(2, np.int32)
    rs = np.zeros(2, np.int32)
    padded = np.pad(x, ((0, 0), (1, 1), (1, 1)))
    a = kn.conv_ws(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                   jnp.asarray(ls), jnp.asarray(rs), pad=1, K=2, relu=False)
    bb = kn.conv_ws(jnp.asarray(padded), jnp.asarray(w), jnp.asarray(b),
                    jnp.asarray(ls), jnp.asarray(rs), pad=0, K=2, relu=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
