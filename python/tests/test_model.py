"""L2 correctness: whole-net kernel path vs oracle path; schedule invariance."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def nets():
    """Params are expensive to calibrate; build once per module."""
    return {
        name: (spec, M.build_params(spec, seed=0))
        for name, spec in M.NETS.items()
    }


def _frames(spec, n, seed=99):
    rng = np.random.default_rng(seed)
    lim = (1 << (spec.bits - 1)) // 2
    dt = np.int8 if spec.bits == 8 else np.int16
    return rng.integers(-lim, lim, (n, *spec.in_shape)).astype(dt)


@pytest.mark.parametrize("name", list(M.NETS))
def test_kernel_path_matches_oracle(nets, name):
    spec, params = nets[name]
    for f in _frames(spec, 3):
        out_k = M.forward_kernel(spec, params, jnp.asarray(f))
        out_r = M.forward_ref(spec, params, jnp.asarray(f))
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("K", [1, 2, 3, 5])
def test_row_parallelism_is_numerics_neutral(nets, K):
    """Paper Alg. 2 raises K for weight reuse; it must never change the
    output — only the schedule."""
    spec, params = nets["tinycnn"]
    f = jnp.asarray(_frames(spec, 1)[0])
    base = M.forward_kernel(spec, params, f, K=1)
    out = M.forward_kernel(spec, params, f, K=K)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_params_deterministic():
    """`make artifacts` must be reproducible: same seed, same params."""
    spec = M.NETS["tinycnn"]
    a = M.build_params(spec, seed=0)
    b = M.build_params(spec, seed=0)
    for pa, pb in zip(a, b):
        if pa is None:
            assert pb is None
            continue
        np.testing.assert_array_equal(pa.w, pb.w)
        np.testing.assert_array_equal(pa.rshift, pb.rshift)


def test_different_seeds_differ():
    spec = M.NETS["tinycnn"]
    a = M.build_params(spec, seed=0)
    b = M.build_params(spec, seed=1)
    assert any(
        pa is not None and not np.array_equal(pa.w, pb.w)
        for pa, pb in zip(a, b)
    )


def test_outputs_not_degenerate(nets):
    """Calibration must leave the net with informative outputs (not all
    saturated, not all zero) — otherwise the golden files prove nothing."""
    spec, params = nets["tinycnn"]
    outs = np.stack([
        np.asarray(M.forward_ref(spec, params, jnp.asarray(f)))
        for f in _frames(spec, 8)
    ])
    assert np.ptp(outs.astype(np.int32)) > 0, "all outputs identical"
    frac_sat = np.mean(np.abs(outs.astype(np.int32)) == 127)
    assert frac_sat < 0.9, f"outputs are saturation noise ({frac_sat:.0%})"


def test_batched_forward_stacks_frames(nets):
    spec, params = nets["lenet"]
    frames = _frames(spec, 4)
    fn = M.batched_forward(spec, params, 4)
    (out,) = fn(jnp.asarray(frames))
    for i, f in enumerate(frames):
        np.testing.assert_array_equal(
            np.asarray(out)[i],
            np.asarray(M.forward_kernel(spec, params, jnp.asarray(f))),
        )


def test_zoo_shapes():
    """Spot-check the zoo's declared geometry."""
    t = M.NETS["tinycnn"]
    assert t.in_shape == (3, 32, 32)
    assert sum(isinstance(l, M.Conv) for l in t.layers) == 3
    v = M.NETS["vgg_micro"]
    assert sum(isinstance(l, M.Conv) for l in v.layers) == 6
