"""L1 correctness: pooling and fully-connected kernels vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_ws as kn
from compile.kernels import ref


@settings(max_examples=30, deadline=None)
@given(
    C=st.integers(1, 8),
    H=st.integers(4, 20),
    W=st.integers(4, 20),
    R=st.integers(2, 3),
    K=st.integers(1, 3),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
)
def test_maxpool_matches_oracle(C, H, W, R, K, bits, seed):
    rng = np.random.default_rng(seed)
    dt = np.int8 if bits == 8 else np.int16
    info = np.iinfo(dt)
    x = rng.integers(info.min, info.max + 1, (C, H, W)).astype(dt)
    out_k = kn.maxpool(jnp.asarray(x), R=R, stride=R, K=K)
    out_r = ref.maxpool_ref(jnp.asarray(x), R=R, stride=R)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_maxpool_negative_only_input():
    """Pool padding value must be dtype-min, not zero, or all-negative
    windows come out wrong."""
    x = np.full((1, 4, 4), -5, np.int8)
    out = kn.maxpool(jnp.asarray(x), R=2, stride=2)
    assert np.all(np.asarray(out) == -5)


@settings(max_examples=30, deadline=None)
@given(
    n_in=st.integers(1, 128),
    n_out=st.integers(1, 32),
    bits=st.sampled_from([8, 16]),
    relu=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_fc_matches_oracle(n_in, n_out, bits, relu, seed):
    rng = np.random.default_rng(seed)
    dt = np.int8 if bits == 8 else np.int16
    lim = (1 << (bits - 1)) // 4
    x = rng.integers(-lim, lim, (n_in,)).astype(dt)
    w = rng.integers(-lim, lim, (n_out, n_in)).astype(dt)
    b = rng.integers(-500, 500, (n_out,)).astype(np.int32)
    rs = rng.integers(0, 8, (n_out,)).astype(np.int32)
    out_k = kn.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                  jnp.asarray(rs), bits=bits, relu=relu)
    out_r = ref.fc_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                       jnp.asarray(rs), bits=bits, relu=relu)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_fc_16bit_accumulator_width():
    """16-bit mode must accumulate beyond int32: 2048 * (2^14)^2 products
    overflow 32 bits but not the int64 accumulator."""
    n = 2048
    x = np.full((n,), 1 << 14, np.int32).astype(np.int16)  # int16 max-ish
    x = np.full((n,), 16384 - 1, np.int16)
    w = np.full((1, n), 16384 - 1, np.int16)
    b = np.zeros(1, np.int32)
    rs = np.full(1, 30, np.int32)  # bring it back into range
    out = kn.fc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                jnp.asarray(rs), bits=16, relu=False)
    ref_v = (n * (16384 - 1) ** 2) >> 30
    assert int(np.asarray(out)[0]) == min(ref_v, (1 << 15) - 1)
