"""Calibration properties (quantize.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as q


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(1, 8),
    n=st.integers(10, 500),
    scale=st.floats(1.0, 1e7),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 1000),
)
def test_calibrated_shift_maps_bulk_in_range(m, n, scale, bits, seed):
    rng = np.random.default_rng(seed)
    psum = (rng.normal(0, scale, (m, n))).astype(np.float64)
    rs = q.calibrate_rshift(psum, bits)
    assert rs.shape == (m,)
    assert np.all(rs >= 0) and np.all(rs <= 31)
    limit = (1 << (bits - 1)) - 1
    # The calibration contract: the 99.9th-percentile |psum| of each output
    # channel maps inside the representable range after its shift.
    hi = np.percentile(np.abs(psum), 99.9, axis=1)
    assert np.all(hi / (2.0 ** rs) <= limit + 1e-9)


def test_shift_is_minimal():
    """One less shift would overflow the declared percentile."""
    psum = np.full((1, 1000), 1000.0)
    rs = q.calibrate_rshift(psum, 8)
    assert 1000 / 2 ** rs[0] <= 127
    assert rs[0] == 0 or 1000 / 2 ** (rs[0] - 1) > 127


def test_small_psums_need_no_shift():
    psum = np.full((3, 100), 5.0)
    assert np.all(q.calibrate_rshift(psum, 8) == 0)


@pytest.mark.parametrize("bits", [8, 16])
def test_rand_weights_range_and_determinism(bits):
    import jax
    k = jax.random.PRNGKey(0)
    a = q.rand_weights(k, (4, 4), bits)
    b = q.rand_weights(k, (4, 4), bits)
    np.testing.assert_array_equal(a, b)
    lim = q.weight_range(bits) // 4
    assert np.all(np.abs(a.astype(np.int64)) <= lim)
    assert a.dtype == (np.int8 if bits == 8 else np.int16)


def test_default_lshift_deterministic():
    a = q.default_lshift(16, channel_spread=2, seed=3)
    b = q.default_lshift(16, channel_spread=2, seed=3)
    np.testing.assert_array_equal(a, b)
    assert np.all(a >= 0) and np.all(a <= 2)
    assert np.all(q.default_lshift(8) == 0)


def test_psum_bound_monotone_in_shifts():
    lo = q.fold_lshift_into_psum_bound(4, 3, 3, 8, np.zeros(4, np.int32))
    hi = q.fold_lshift_into_psum_bound(4, 3, 3, 8, np.full(4, 2, np.int32))
    assert hi == 4 * lo
    assert lo == 4 * 3 * 3 * 128 * 127
