"""AOT path: HLO text validity, manifest consistency, golden round-trip.

These tests protect the Python->Rust interchange contract: if they pass,
the Rust runtime integration test (rust/tests/runtime_golden.rs) operates
on well-formed inputs.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    spec = aot.ArtifactSpec("tinycnn", 2, golden_frames=3)
    entry = aot.build_artifact(spec, out)
    return out, spec, entry


def test_hlo_is_text_with_module_header(artifact):
    out, spec, entry = artifact
    text = open(os.path.join(out, entry["hlo"])).read()
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    assert "ENTRY" in text
    # jax >= 0.5 proto ids overflow xla_extension 0.5.1 — text is the
    # contract; a serialized proto would be binary and fail the check above.


def test_manifest_entry_shapes(artifact):
    out, spec, entry = artifact
    net = M.NETS[spec.net]
    assert entry["input_shape"] == [2, *net.in_shape]
    assert entry["output_shape"][0] == 2
    assert entry["dtype"] == "s8"
    assert entry["golden"]["frames"] == 3
    in_sz = os.path.getsize(os.path.join(out, entry["golden"]["input"]))
    assert in_sz == 3 * int(np.prod(net.in_shape))


def test_golden_files_match_oracle(artifact):
    """The golden output bin must equal re-running the oracle on the
    golden input bin — this is what the Rust side asserts against."""
    out, spec, entry = artifact
    net = M.NETS[spec.net]
    params = M.build_params(net, seed=spec.seed)
    frames = np.fromfile(
        os.path.join(out, entry["golden"]["input"]), dtype=np.int8
    ).reshape(3, *net.in_shape)
    golden = np.fromfile(
        os.path.join(out, entry["golden"]["output"]), dtype=np.int8
    ).reshape(3, -1)
    for f, g in zip(frames, golden):
        np.testing.assert_array_equal(
            np.asarray(M.forward_ref(net, params, jnp.asarray(f))), g
        )


def test_artifact_rebuild_is_identical(artifact, tmp_path):
    """`make artifacts` idempotency: same seed -> byte-identical HLO."""
    out, spec, entry = artifact
    entry2 = aot.build_artifact(spec, str(tmp_path))
    assert entry2["hlo_sha256"] == entry["hlo_sha256"]


def test_compiled_hlo_executes_locally(artifact):
    """Round-trip through XLA's own text parser + CPU client: what Rust's
    PJRT client does, proven from Python."""
    out, spec, entry = artifact
    from jax._src.lib import xla_client as xc
    text = open(os.path.join(out, entry["hlo"])).read()
    # the xla crate parses the same grammar via HloModuleProto::from_text
    assert "ROOT" in text
    net = M.NETS[spec.net]
    frames = np.fromfile(
        os.path.join(out, entry["golden"]["input"]), dtype=np.int8
    ).reshape(3, *net.in_shape)
    golden = np.fromfile(
        os.path.join(out, entry["golden"]["output"]), dtype=np.int8
    ).reshape(3, -1)
    params = M.build_params(net, seed=spec.seed)
    fn = M.batched_forward(net, params, spec.batch, K=spec.K)
    (got,) = fn(jnp.asarray(frames[: spec.batch]))
    np.testing.assert_array_equal(np.asarray(got), golden[: spec.batch])


def test_artifact_names_unique():
    names = [s.name for s in aot.ARTIFACTS]
    assert len(names) == len(set(names))


def test_no_elided_constants(artifact):
    """Regression: the default HLO printer elides big literals as
    ``constant({...})``; the Rust-side parser fills those with garbage and
    the baked weights vanish (all-zero inference). aot.py must print full
    constants."""
    out, spec, entry = artifact
    text = open(os.path.join(out, entry["hlo"])).read()
    assert "constant({...})" not in text
    # and at least one real weight tensor must appear inline
    assert any(
        "constant({ {" in ln or "constant({" in ln and "..." not in ln
        for ln in text.splitlines()
        if "constant" in ln
    )
