"""Layer-1 kernels: Pallas weight-stationary conv/pool/fc + pure-jnp oracle."""
