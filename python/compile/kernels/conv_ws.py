"""Layer-1 Pallas kernel: weight-stationary fixed-point convolution.

This is the software model of the paper's convolution layer engine
(Yi/Sun/Fujita 2021, Fig. 3): an ``M' x C' x R x S`` multiplier array fed by
an activation line buffer, computing ``K`` output rows per weight load
(weight-stationary dataflow), with the channel-wise fixed-point alignment
datapath of paper Sec. 3.3:

    psum  = sum_{c,r,s} (x[c] << lshift[c]) * w[m,c,r,s]      (32/64-bit)
    out_m = saturate( (psum + bias[m]) >> rshift[m] )         (8/16-bit)

Hardware adaptation (FPGA -> TPU, DESIGN.md Sec. 3): the PE array's
``(C*R*S) -> M'`` reduction is expressed as a single MXU-shaped matmul whose
contraction dimension is ``C*R*S``; the paper's ``K x W`` activation atomic
group becomes the Pallas grid's row-group axis, and the output-channel group
``M'`` becomes the second grid axis, exactly mirroring the paper's controller
schedule (rows outer, output-channel groups inner).

The kernel is lowered with ``interpret=True``: real-TPU Pallas emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are bit-exact
against the pure-jnp oracle in ``ref.py`` (pytest + hypothesis).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Integer dtypes of the paper's two quantization modes. One DSP48E1 does one
# 16-bit or two 8-bit multiplies per cycle; here the mode only selects the
# storage dtype and the accumulator width.
_ACT_DTYPE = {8: jnp.int8, 16: jnp.int16}
_ACC_DTYPE = {8: jnp.int32, 16: jnp.int64}


def _out_dim(size: int, k: int, stride: int, pad: int) -> int:
    """Output spatial size of a convolution/pool window sweep."""
    return (size + 2 * pad - k) // stride + 1


def saturate(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Clamp an accumulator to the signed ``bits``-wide range (paper's
    truncate-with-saturation on the psum -> activation conversion)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return jnp.clip(v, lo, hi).astype(_ACT_DTYPE[bits])


def _conv_ws_kernel(
    x_ref,
    w_ref,
    b_ref,
    ls_ref,
    rs_ref,
    o_ref,
    *,
    R: int,
    S: int,
    stride: int,
    K: int,
    W_out: int,
    bits: int,
):
    """One pipeline beat: compute a ``K``-row x ``M'``-channel output group.

    Refs (shapes after BlockSpec blocking):
      x_ref  : [C, H_in_padded, W_in_padded]   full padded input (line buffer)
      w_ref  : [Mp, C, R, S]                   weight-stationary block
      b_ref  : [Mp]                            int32 bias
      ls_ref : [C]                             per-input-channel left shift
      rs_ref : [Mp]                            per-output-channel right shift
      o_ref  : [Mp, K, W_out]                  output activation group
    """
    g = pl.program_id(0)  # row-group index (paper: which K-row group)
    acc_t = _ACC_DTYPE[bits]

    C = x_ref.shape[0]
    W_in = x_ref.shape[2]
    K_in = (K - 1) * stride + R  # input rows feeding K output rows

    x = x_ref[...]
    # The line buffer presents R + (K-1)*stride input rows for this group
    # (paper Sec. 3.3: R + K - 1 read rows when stride == 1).
    row0 = g * K * stride
    zero = row0 * 0  # same dtype as program_id (x64 mode mixes int widths)
    xs = jax.lax.dynamic_slice(x, (zero, row0, zero), (C, K_in, W_in))

    # Channel-wise fixed-point alignment: left-shift each input channel into
    # the common accumulator format *before* the MACs (paper Fig. 3(c)).
    ls = ls_ref[...].astype(acc_t)
    xs = xs.astype(acc_t) << ls[:, None, None]

    # im2col-free patch extraction with static strided slices: for each (r, s)
    # kernel tap, the [C, K, W_out] activation plane it multiplies.
    taps = []
    for r in range(R):
        for s in range(S):
            taps.append(
                jax.lax.slice(
                    xs,
                    (0, r, s),
                    (C, r + (K - 1) * stride + 1, s + (W_out - 1) * stride + 1),
                    (1, stride, stride),
                )
            )
    # [R*S, C, K, W_out] -> contraction layout [C*R*S, K*W_out]
    patches = jnp.stack(taps, axis=0).reshape(R * S, C, K * W_out)
    patches = patches.transpose(1, 0, 2).reshape(C * R * S, K * W_out)

    # Weight-stationary MXU matmul: [Mp, C*R*S] @ [C*R*S, K*W_out].
    w = w_ref[...].astype(acc_t).reshape(w_ref.shape[0], C * R * S)
    psum = jax.lax.dot(w, patches, preferred_element_type=acc_t)

    # Bias add, per-output-channel right shift (arithmetic = truncation
    # toward -inf, as the RTL barrel shifter does), saturate to 8/16-bit.
    psum = psum + b_ref[...].astype(acc_t)[:, None]
    psum = psum >> rs_ref[...].astype(acc_t)[:, None]
    o_ref[...] = saturate(psum, bits).reshape(w_ref.shape[0], K, W_out)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "pad", "K", "Mp", "bits", "relu", "interpret"),
)
def conv_ws(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    lshift: jnp.ndarray,
    rshift: jnp.ndarray,
    *,
    stride: int = 1,
    pad: int = 0,
    K: int = 2,
    Mp: int = 0,
    bits: int = 8,
    relu: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fixed-point convolution with the paper's weight-stationary dataflow.

    Args:
      x:      [C, H, W] int8/int16 input activations.
      w:      [M, C, R, S] int8/int16 weights.
      bias:   [M] int32 bias (already in accumulator format).
      lshift: [C] per-input-channel alignment left shifts.
      rshift: [M] per-output-channel scaling right shifts.
      stride: convolution stride G.
      pad:    symmetric zero padding (controller's zeroMac handling).
      K:      row parallelism — output rows computed per weight load.
      Mp:     output-channel parallelism M' (grid tile on M). 0 = all of M.
      bits:   8 or 16 (quantization mode).
      relu:   apply ReLU before writeback (all paper nets use ReLU convs).

    Returns: [M, H_out, W_out] int8/int16 output activations.
    """
    C, H, W = x.shape
    M, Cw, R, S = w.shape
    assert Cw == C, f"channel mismatch {Cw} != {C}"
    H_out = _out_dim(H, R, stride, pad)
    W_out = _out_dim(W, S, stride, pad)
    Mp = Mp or M
    assert M % Mp == 0, f"M'={Mp} must divide M={M}"

    # Row groups: pad H_out up to a multiple of K; the controller simply
    # runs the last group with garbage rows that are sliced off below.
    n_groups = -(-H_out // K)
    H_out_p = n_groups * K
    # Input rows the last group may touch.
    H_need = (H_out_p - 1) * stride + R
    x_p = jnp.pad(x, ((0, 0), (pad, max(0, H_need - H - pad)), (pad, pad)))

    kern = functools.partial(
        _conv_ws_kernel, R=R, S=S, stride=stride, K=K, W_out=W_out, bits=bits
    )
    out = pl.pallas_call(
        kern,
        grid=(n_groups, M // Mp),
        in_specs=[
            # Full padded input: the activation line buffer is modelled by
            # the dynamic row slice inside the kernel (overlapping windows
            # are not block-granular).
            pl.BlockSpec(x_p.shape, lambda g, mi: (0, 0, 0)),
            pl.BlockSpec((Mp, C, R, S), lambda g, mi: (mi, 0, 0, 0)),
            pl.BlockSpec((Mp,), lambda g, mi: (mi,)),
            pl.BlockSpec((C,), lambda g, mi: (0,)),
            pl.BlockSpec((Mp,), lambda g, mi: (mi,)),
        ],
        out_specs=pl.BlockSpec((Mp, K, W_out), lambda g, mi: (mi, g, 0)),
        out_shape=jax.ShapeDtypeStruct((M, H_out_p, W_out), _ACT_DTYPE[bits]),
        interpret=interpret,
    )(x_p, w, bias, lshift, rshift)

    out = out[:, :H_out, :]
    if relu:
        out = jnp.maximum(out, 0)
    return out


def _maxpool_kernel(x_ref, o_ref, *, R: int, stride: int, K: int, W_out: int):
    """Max-pool one K-row output group (paper: pooling layers are their own
    pipeline stages fed by the same line-buffer scheme)."""
    g = pl.program_id(0)
    C = x_ref.shape[0]
    W_in = x_ref.shape[2]
    K_in = (K - 1) * stride + R
    row0 = g * K * stride
    zero = row0 * 0
    xs = jax.lax.dynamic_slice(x_ref[...], (zero, row0, zero), (C, K_in, W_in))
    taps = []
    for r in range(R):
        for s in range(R):
            taps.append(
                jax.lax.slice(
                    xs,
                    (0, r, s),
                    (C, r + (K - 1) * stride + 1, s + (W_out - 1) * stride + 1),
                    (1, stride, stride),
                )
            )
    o_ref[...] = jnp.max(jnp.stack(taps, axis=0), axis=0)


@functools.partial(
    jax.jit, static_argnames=("R", "stride", "K", "interpret")
)
def maxpool(
    x: jnp.ndarray,
    *,
    R: int = 2,
    stride: int = 2,
    K: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fixed-point max pooling over ``R x R`` windows. [C,H,W] -> [C,H',W']."""
    C, H, W = x.shape
    H_out = _out_dim(H, R, stride, 0)
    W_out = _out_dim(W, R, stride, 0)
    n_groups = -(-H_out // K)
    H_out_p = n_groups * K
    H_need = (H_out_p - 1) * stride + R
    lo = int(jnp.iinfo(x.dtype).min)
    x_p = jnp.pad(x, ((0, 0), (0, max(0, H_need - H)), (0, 0)), constant_values=lo)

    kern = functools.partial(
        _maxpool_kernel, R=R, stride=stride, K=K, W_out=W_out
    )
    out = pl.pallas_call(
        kern,
        grid=(n_groups,),
        in_specs=[pl.BlockSpec(x_p.shape, lambda g: (0, 0, 0))],
        out_specs=pl.BlockSpec((C, K, W_out), lambda g: (0, g, 0)),
        out_shape=jax.ShapeDtypeStruct((C, H_out_p, W_out), x.dtype),
        interpret=interpret,
    )(x_p)
    return out[:, :H_out, :]


def _fc_kernel(x_ref, w_ref, b_ref, rs_ref, o_ref, *, bits: int):
    """Fully-connected stage: 1x1xN 'convolution' (paper treats FC layers as
    pipeline stages with R=S=1, H=W=1)."""
    acc_t = _ACC_DTYPE[bits]
    x = x_ref[...].astype(acc_t)
    w = w_ref[...].astype(acc_t)
    psum = jax.lax.dot(w, x, preferred_element_type=acc_t)
    psum = psum + b_ref[...].astype(acc_t)
    psum = psum >> rs_ref[...].astype(acc_t)
    o_ref[...] = saturate(psum, bits)


@functools.partial(jax.jit, static_argnames=("bits", "relu", "interpret"))
def fc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    rshift: jnp.ndarray,
    *,
    bits: int = 8,
    relu: bool = False,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fixed-point fully-connected layer. x: [N_in], w: [N_out, N_in]."""
    kern = functools.partial(_fc_kernel, bits=bits)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((w.shape[0],), _ACT_DTYPE[bits]),
        interpret=interpret,
    )(x, w, bias, rshift)
    if relu:
        out = jnp.maximum(out, 0)
    return out
