"""Pure-jnp oracle for the fixed-point kernels in ``conv_ws.py``.

Deliberately takes an *independent* compute path (XLA's own integer
convolution / reduce_window / dot — no Pallas, no strided-slice patch
extraction) so a bug in the kernel's dataflow cannot cancel out in the test.

For 8-bit mode the accumulator is int32 and XLA's native integer convolution
is exact. For 16-bit mode products reach 2^30 and reductions can overflow
int32, so the oracle computes in float64, which is exact for |v| < 2^53 —
the worst case here is C*R*S * 2^30 ≈ 2^43 (C=512, 3x3 kernel), with margin.
Arithmetic right shift of a negative int equals floor division by 2^s, which
is ``jnp.floor_divide`` in both domains.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .conv_ws import _ACT_DTYPE


def _shift_sat(psum: jnp.ndarray, rdiv: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Floor-divide by 2^rshift (== arithmetic right shift), saturate, cast."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return jnp.clip(jnp.floor_divide(psum, rdiv), lo, hi).astype(_ACT_DTYPE[bits])


def conv_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    lshift: jnp.ndarray,
    rshift: jnp.ndarray,
    *,
    stride: int = 1,
    pad: int = 0,
    bits: int = 8,
    relu: bool = True,
) -> jnp.ndarray:
    """Reference fixed-point conv. Same semantics as ``conv_ws.conv_ws``."""
    acc = jnp.int32 if bits == 8 else jnp.float64
    xs = x.astype(acc) * (2 ** lshift.astype(acc))[:, None, None]
    y = jax.lax.conv_general_dilated(
        xs[None],
        w.astype(acc),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    y = y + bias.astype(acc)[:, None, None]
    out = _shift_sat(y, (2 ** rshift.astype(acc))[:, None, None], bits)
    if relu:
        out = jnp.maximum(out, 0)
    return out


def maxpool_ref(x: jnp.ndarray, *, R: int = 2, stride: int = 2) -> jnp.ndarray:
    """Reference max pooling via XLA reduce_window."""
    lo = int(jnp.iinfo(x.dtype).min)
    return jax.lax.reduce_window(
        x,
        jnp.array(lo, x.dtype),
        jax.lax.max,
        window_dimensions=(1, R, R),
        window_strides=(1, stride, stride),
        padding="VALID",
    )


def fc_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    rshift: jnp.ndarray,
    *,
    bits: int = 8,
    relu: bool = False,
) -> jnp.ndarray:
    """Reference fixed-point fully-connected layer. x: [N_in], w: [N_out,N_in]."""
    acc = jnp.int32 if bits == 8 else jnp.float64
    y = w.astype(acc) @ x.astype(acc) + bias.astype(acc)
    out = _shift_sat(y, 2 ** rshift.astype(acc), bits)
    if relu:
        out = jnp.maximum(out, 0)
    return out
