"""Fixed-point calibration for the paper's channel-wise quantization scheme.

The paper (Sec. 3.3) stores weights and activations as 8/16-bit fixed point
with *channel-wise different formats*: products of different input channels
are aligned by left shifts before accumulation, and the 32-bit partial sum is
right-shifted and truncated back to the activation width.

This module picks those shifts. Given integer weights and a sample of input
activations, it chooses per-output-channel right shifts so the post-shift
activations use the full 8/16-bit range without systematic saturation —
the software analogue of the bit-width allocation a hardware flow would do
offline. Determinism matters: the same seed must give the same artifact and
golden files on every run (`make artifacts` idempotency).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Per-layer fixed-point parameters fed to the kernels."""

    lshift: np.ndarray  # [C]  per-input-channel alignment shifts
    rshift: np.ndarray  # [M]  per-output-channel scaling shifts
    bias: np.ndarray    # [M]  int32 bias in accumulator format


def weight_range(bits: int) -> int:
    """Symmetric weight magnitude for ``bits``-wide storage."""
    return (1 << (bits - 1)) - 1


def rand_weights(key, shape: Sequence[int], bits: int, spread: int = 4) -> np.ndarray:
    """Deterministic small-magnitude integer weights.

    Magnitudes are kept well under the storage range so accumulated psums
    exercise the shift/saturate epilogue without being pure saturation noise.
    """
    lim = max(1, weight_range(bits) // spread)
    w = jax.random.randint(key, shape, -lim, lim + 1, dtype=jnp.int32)
    return np.asarray(w, dtype=np.int8 if bits == 8 else np.int16)


def calibrate_rshift(
    psum_sample: np.ndarray, bits: int, percentile: float = 99.9
) -> np.ndarray:
    """Per-output-channel right shift from a sample of raw partial sums.

    Picks the smallest shift such that the chosen percentile of |psum| maps
    inside the signed ``bits`` range — i.e. rare outliers saturate (the
    hardware clips them too), the bulk does not.
    """
    m = psum_sample.shape[0]
    flat = np.abs(psum_sample.reshape(m, -1)).astype(np.float64)
    hi = np.percentile(flat, percentile, axis=1)
    limit = float((1 << (bits - 1)) - 1)
    rs = np.ceil(np.log2(np.maximum(hi, 1.0) / limit))
    return np.clip(rs, 0, 31).astype(np.int32)


def default_lshift(c: int, channel_spread: int = 0, seed: int = 0) -> np.ndarray:
    """Per-input-channel alignment shifts.

    ``channel_spread`` > 0 emulates genuinely heterogeneous channel formats
    (the paper's motivating case); 0 gives a uniform format. Deterministic in
    the seed.
    """
    if channel_spread == 0:
        return np.zeros(c, dtype=np.int32)
    rng = np.random.default_rng(seed)
    return rng.integers(0, channel_spread + 1, size=c, dtype=np.int32)


def fold_lshift_into_psum_bound(
    c: int, r: int, s: int, bits: int, lshift: np.ndarray
) -> int:
    """Worst-case |psum| bound for overflow analysis (mirrors the Rust
    ``quant::psum_bound`` used by the engine model's width checks)."""
    amax = 1 << (bits - 1)
    wmax = weight_range(bits)
    per_tap = int(amax) * int(wmax)
    return int(np.sum((2.0 ** lshift.astype(np.float64))) * r * s * per_tap)
