"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest + goldens.

This is the only Python entry point in the build (``make artifacts``); the
Rust runtime (rust/src/runtime) loads the emitted files and Python never
runs again.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted per artifact:
  artifacts/<name>.hlo.txt          HLO text (weights baked as constants —
                                    the paper keeps weights in DDR; for the
                                    functional path constants are the
                                    equivalent "already loaded" state)
  artifacts/<name>.golden.in.bin    little-endian int8/int16 frames
  artifacts/<name>.golden.out.bin   oracle outputs for those frames
  artifacts/manifest.json           shapes/dtypes/batch/paths for Rust
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with ``to_tuple1``).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``constant({...})``, which the Rust side's HLO
    parser silently fills with garbage — the baked weights would vanish.
    (Found the hard way; regression-tested in test_aot.py.)"""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


@dataclasses.dataclass
class ArtifactSpec:
    """One compiled executable variant: a net at a fixed batch size."""

    net: str
    batch: int
    bits: int = 8
    K: int = 2          # row parallelism baked into the schedule (numerics-neutral)
    golden_frames: int = 8
    seed: int = 0

    @property
    def name(self) -> str:
        return f"{self.net}_b{self.batch}_{self.bits}b"


# The artifact set the Rust coordinator serves. Batch variants let the
# dynamic batcher pick the largest compiled batch <= queue depth.
ARTIFACTS: List[ArtifactSpec] = [
    ArtifactSpec("tinycnn", 1),
    ArtifactSpec("tinycnn", 4),
    ArtifactSpec("tinycnn", 8),
    ArtifactSpec("lenet", 1),
    ArtifactSpec("lenet", 4),
    ArtifactSpec("vgg_micro", 1),
    ArtifactSpec("vgg_micro", 4),
]


def _dtype(bits: int):
    return np.int8 if bits == 8 else np.int16


def build_artifact(spec: ArtifactSpec, out_dir: str) -> dict:
    """Lower one artifact, write HLO + goldens, return its manifest entry."""
    net = M.NETS[spec.net]
    assert net.bits == spec.bits, "zoo nets are built per-bit-width"
    params = M.build_params(net, seed=spec.seed)
    fn = M.batched_forward(net, params, spec.batch, K=spec.K)

    in_shape = (spec.batch, *net.in_shape)
    in_spec = jax.ShapeDtypeStruct(in_shape, _dtype(spec.bits))
    lowered = jax.jit(fn).lower(in_spec)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    # Golden frames: deterministic inputs, oracle (ref-path) outputs.
    rng = np.random.default_rng(spec.seed + 1234)
    lim = (1 << (spec.bits - 1)) // 2
    n = spec.golden_frames
    frames = rng.integers(-lim, lim, (n, *net.in_shape)).astype(_dtype(spec.bits))
    outs = np.stack([
        np.asarray(M.forward_ref(net, params, jnp.asarray(f))) for f in frames
    ])
    in_path = os.path.join(out_dir, f"{spec.name}.golden.in.bin")
    out_path = os.path.join(out_dir, f"{spec.name}.golden.out.bin")
    frames.tofile(in_path)
    outs.tofile(out_path)

    return {
        "name": spec.name,
        "net": spec.net,
        "batch": spec.batch,
        "bits": spec.bits,
        "row_parallelism": spec.K,
        "hlo": os.path.basename(hlo_path),
        "input_shape": list(in_shape),
        "output_shape": [spec.batch, int(outs.shape[1])],
        "dtype": f"s{spec.bits}",
        "golden": {
            "frames": n,
            "input": os.path.basename(in_path),
            "output": os.path.basename(out_path),
            "frame_elems": int(np.prod(net.in_shape)),
            "out_elems": int(outs.shape[1]),
        },
        "hlo_sha256": hashlib.sha256(hlo.encode()).hexdigest(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to rebuild")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for spec in ARTIFACTS:
        if only and spec.name not in only and spec.net not in only:
            continue
        print(f"[aot] lowering {spec.name} ...", flush=True)
        entries.append(build_artifact(spec, args.out_dir))
        print(f"[aot]   wrote {entries[-1]['hlo']} "
              f"({entries[-1]['hlo_sha256'][:12]})", flush=True)

    manifest = {
        "version": 1,
        "generator": "python/compile/aot.py",
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
