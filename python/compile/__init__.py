"""Build-time compile package: L1 Pallas kernels, L2 JAX graphs, AOT lowering.

Python in this package runs exactly once per build (``make artifacts``) and
never on the Rust request path.
"""

import jax

# 16-bit mode accumulates in int64 and the oracle computes in float64; both
# require x64 support, which jax disables by default.
jax.config.update("jax_enable_x64", True)
