"""Layer-2: quantized CNN graphs built from the Layer-1 Pallas kernels.

Mirrors the Rust model zoo (``rust/src/model/zoo.rs``): every network is a
list of Conv / Pool / Fc stages — exactly the pipeline-stage granularity of
the paper's architecture (Sec. 3.2: "Major layers, including convolution
layers, pooling layers and full-connected layers, are implemented as
individual pipeline stages").

The *artifact* nets compiled by ``aot.py`` are the small ones (TinyCNN,
LeNet, VGG-micro): the full paper nets (VGG16 @224², YOLO @448²) exist in
the Rust zoo for the allocator/simulator, while the functional PJRT path
runs scaled-down nets — same code path, laptop-scale shapes (DESIGN.md §2).

Weights are deterministic in the seed; per-layer right shifts are calibrated
on a sample batch (see ``quantize.py``) so activations neither vanish nor
saturate systematically.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv_ws as kn
from .kernels import ref
from . import quantize as q


# --------------------------------------------------------------------------
# Net specification (mirror of rust/src/model/mod.rs)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    m: int
    r: int = 3
    s: int = 3
    stride: int = 1
    pad: int = 1
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class Pool:
    r: int = 2
    stride: int = 2


@dataclasses.dataclass(frozen=True)
class Fc:
    n_out: int
    relu: bool = False


Layer = Union[Conv, Pool, Fc]


@dataclasses.dataclass(frozen=True)
class NetSpec:
    name: str
    in_shape: Tuple[int, int, int]  # (C, H, W)
    layers: Tuple[Layer, ...]
    bits: int = 8


def tinycnn(bits: int = 8) -> NetSpec:
    """3-conv CIFAR-scale net — the e2e serving artifact."""
    return NetSpec(
        "tinycnn",
        (3, 32, 32),
        (
            Conv(16), Pool(),
            Conv(32), Pool(),
            Conv(32), Pool(),
            Fc(10),
        ),
        bits,
    )


def lenet(bits: int = 8) -> NetSpec:
    """LeNet-5-shaped net on 28x28 single-channel input."""
    return NetSpec(
        "lenet",
        (1, 28, 28),
        (
            Conv(6, r=5, s=5, pad=2), Pool(),
            Conv(16, r=5, s=5, pad=0), Pool(),
            Fc(120, relu=True),
            Fc(84, relu=True),
            Fc(10),
        ),
        bits,
    )


def vgg_micro(bits: int = 8) -> NetSpec:
    """VGG-shaped 6-conv net on 32x32 — the deep-pipeline artifact.

    Same 3x3/stride-1/pad-1 + 2x2-pool rhythm as VGG16, scaled so the
    interpret-mode Pallas path stays laptop-fast."""
    return NetSpec(
        "vgg_micro",
        (3, 32, 32),
        (
            Conv(16), Conv(16), Pool(),
            Conv(32), Conv(32), Pool(),
            Conv(48), Conv(48), Pool(),
            Fc(10),
        ),
        bits,
    )


NETS = {n.name: n for n in (tinycnn(), lenet(), vgg_micro())}


# --------------------------------------------------------------------------
# Parameter generation + calibration
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ConvParams:
    w: np.ndarray
    bias: np.ndarray
    lshift: np.ndarray
    rshift: np.ndarray


@dataclasses.dataclass
class FcParams:
    w: np.ndarray
    bias: np.ndarray
    rshift: np.ndarray


def _sample_inputs(spec: NetSpec, n: int, seed: int) -> np.ndarray:
    key = jax.random.PRNGKey(seed ^ 0xA5A5)
    lim = 1 << (spec.bits - 1)
    x = jax.random.randint(
        key, (n, *spec.in_shape), -lim // 2, lim // 2, dtype=jnp.int32
    )
    return np.asarray(x, dtype=np.int8 if spec.bits == 8 else np.int16)


def build_params(spec: NetSpec, seed: int = 0, calib_frames: int = 4):
    """Generate deterministic weights and calibrate shifts layer by layer.

    Runs the *reference* ops on a calibration batch to size each layer's
    right shift; the returned params are consumed by both the kernel path
    and the oracle path (they must agree bit-exactly — tested).
    """
    key = jax.random.PRNGKey(seed)
    xs = _sample_inputs(spec, calib_frames, seed)  # [B, C, H, W]
    params: List[Union[ConvParams, FcParams, None]] = []
    c_in = spec.in_shape[0]

    for li, layer in enumerate(spec.layers):
        key, kw, kb = jax.random.split(key, 3)
        if isinstance(layer, Conv):
            w = q.rand_weights(kw, (layer.m, c_in, layer.r, layer.s), spec.bits)
            lshift = q.default_lshift(c_in, channel_spread=1, seed=seed + li)
            # Raw psums on the calibration batch (float64 is exact here).
            xs64 = xs.astype(np.float64) * (2.0 ** lshift)[None, :, None, None]
            raw = jax.lax.conv_general_dilated(
                jnp.asarray(xs64), jnp.asarray(w, jnp.float64),
                window_strides=(layer.stride, layer.stride),
                padding=[(layer.pad, layer.pad), (layer.pad, layer.pad)],
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
            raw = np.asarray(raw)
            rshift = q.calibrate_rshift(raw.transpose(1, 0, 2, 3), spec.bits)
            bmag = np.maximum(
                1, np.percentile(np.abs(raw), 90, axis=(0, 2, 3)) / 8
            ).astype(np.int32)
            bias = np.asarray(
                jax.random.randint(kb, (layer.m,), -1, 2, dtype=jnp.int32)
            ) * bmag
            p = ConvParams(w, bias, lshift, rshift)
            params.append(p)
            # Quantized outputs feed the next layer's calibration.
            xs = np.stack([
                np.asarray(ref.conv_ref(
                    jnp.asarray(f), jnp.asarray(w), jnp.asarray(bias),
                    jnp.asarray(lshift), jnp.asarray(rshift),
                    stride=layer.stride, pad=layer.pad, bits=spec.bits,
                    relu=layer.relu,
                )) for f in xs
            ])
            c_in = layer.m
        elif isinstance(layer, Pool):
            params.append(None)
            xs = np.stack([
                np.asarray(ref.maxpool_ref(jnp.asarray(f), R=layer.r,
                                           stride=layer.stride))
                for f in xs
            ])
        elif isinstance(layer, Fc):
            n_in = int(np.prod(xs.shape[1:]))
            w = q.rand_weights(kw, (layer.n_out, n_in), spec.bits)
            xf = xs.reshape(xs.shape[0], -1)
            raw = xf.astype(np.float64) @ np.asarray(w, np.float64).T
            rshift = q.calibrate_rshift(raw.T, spec.bits)
            bias = np.zeros(layer.n_out, dtype=np.int32)
            p = FcParams(w, bias, rshift)
            params.append(p)
            xs = np.stack([
                np.asarray(ref.fc_ref(
                    jnp.asarray(f), jnp.asarray(w), jnp.asarray(bias),
                    jnp.asarray(rshift), bits=spec.bits, relu=layer.relu,
                )) for f in xf
            ])
        else:  # pragma: no cover
            raise TypeError(layer)
    return params


# --------------------------------------------------------------------------
# Forward graphs
# --------------------------------------------------------------------------


def forward_kernel(spec: NetSpec, params, frame: jnp.ndarray, *, K: int = 2,
                   interpret: bool = True) -> jnp.ndarray:
    """Single-frame forward through the Pallas kernel path.

    ``K`` is the paper's row parallelism; it changes the schedule, never the
    numerics (property-tested in test_model.py)."""
    x = frame
    for layer, p in zip(spec.layers, params):
        if isinstance(layer, Conv):
            x = kn.conv_ws(
                x, jnp.asarray(p.w), jnp.asarray(p.bias),
                jnp.asarray(p.lshift), jnp.asarray(p.rshift),
                stride=layer.stride, pad=layer.pad, K=K,
                bits=spec.bits, relu=layer.relu, interpret=interpret,
            )
        elif isinstance(layer, Pool):
            x = kn.maxpool(x, R=layer.r, stride=layer.stride, K=1,
                           interpret=interpret)
        elif isinstance(layer, Fc):
            x = kn.fc(x.reshape(-1), jnp.asarray(p.w), jnp.asarray(p.bias),
                      jnp.asarray(p.rshift), bits=spec.bits, relu=layer.relu,
                      interpret=interpret)
    return x


def forward_ref(spec: NetSpec, params, frame: jnp.ndarray) -> jnp.ndarray:
    """Single-frame forward through the oracle path."""
    x = frame
    for layer, p in zip(spec.layers, params):
        if isinstance(layer, Conv):
            x = ref.conv_ref(
                x, jnp.asarray(p.w), jnp.asarray(p.bias),
                jnp.asarray(p.lshift), jnp.asarray(p.rshift),
                stride=layer.stride, pad=layer.pad, bits=spec.bits,
                relu=layer.relu,
            )
        elif isinstance(layer, Pool):
            x = ref.maxpool_ref(x, R=layer.r, stride=layer.stride)
        elif isinstance(layer, Fc):
            x = ref.fc_ref(x.reshape(-1), jnp.asarray(p.w),
                           jnp.asarray(p.bias), jnp.asarray(p.rshift),
                           bits=spec.bits, relu=layer.relu)
    return x


def batched_forward(spec: NetSpec, params, batch: int, *, K: int = 2,
                    interpret: bool = True):
    """Build the batched inference function that gets AOT-lowered.

    The batch loop is unrolled at trace time (batch sizes are small, fixed
    per artifact) — vmap over interpret-mode pallas_call is avoided on
    purpose. Returns fn: int[batch,C,H,W] -> (int[batch,n_out],)."""

    def fn(frames):
        outs = [
            forward_kernel(spec, params, frames[i], K=K, interpret=interpret)
            for i in range(batch)
        ]
        return (jnp.stack(outs),)

    return fn
