//! End-to-end driver (DESIGN.md §6): serve batched inference requests
//! through the full stack — Pallas-kernel HLO artifacts, PJRT runtime,
//! dynamic batcher — on a real small model, verify every response against
//! the Python oracle's golden outputs, and report latency/throughput.
//!
//! This is the "demo system" of paper Fig. 4 with the FPGA replaced by the
//! AOT-compiled functional datapath. Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example serve_frames [-- <frames> <net>]
//! ```

use flexipipe::coordinator::{BatchPolicy, Coordinator};
use flexipipe::runtime::{read_i8, Manifest};
use std::time::{Duration, Instant};

fn main() -> flexipipe::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let frames: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let net = args.get(1).map(|s| s.as_str()).unwrap_or("tinycnn").to_string();
    let dir = flexipipe::runtime::default_artifact_dir();

    // Golden data (host side — no PJRT needed here).
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let variants = manifest.variants(&net, 8);
    anyhow::ensure!(!variants.is_empty(), "no artifacts — run `make artifacts`");
    let art = variants[0];
    let elems = art.golden.frame_elems;
    let out_elems = art.golden.out_elems;
    let golden_in = read_i8(dir.join(&art.golden.input))?;
    let golden_out = read_i8(dir.join(&art.golden.output))?;
    let n_golden = art.golden.frames;

    println!(
        "serving {net} ({} artifact variants, batch sizes {:?})",
        variants.len(),
        variants.iter().map(|a| a.batch).collect::<Vec<_>>()
    );
    let coord = Coordinator::start(
        &dir,
        &net,
        8,
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            link_latency: Duration::ZERO,
        },
    )?;

    // Offered load: all frames up-front (throughput mode), golden frames
    // round-robin so every response is verifiable.
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(frames);
    for i in 0..frames {
        let g = i % n_golden;
        pending.push((g, coord.submit(golden_in[g * elems..(g + 1) * elems].to_vec())?));
    }
    let mut verified = 0usize;
    for (g, rx) in pending {
        let out = rx.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
        anyhow::ensure!(
            out == golden_out[g * out_elems..(g + 1) * out_elems],
            "response for golden frame {g} mismatched the Python oracle"
        );
        verified += 1;
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();

    println!(
        "\n{verified}/{frames} responses verified bit-exact against the Python oracle"
    );
    println!(
        "throughput: {:.1} frames/s  ({} batches, mix {:?}, {} padded slots)",
        frames as f64 / dt.as_secs_f64(),
        stats.batches,
        stats.batch_sizes,
        stats.padded_frames
    );
    println!(
        "latency: p50 {} µs  p95 {} µs  p99 {} µs",
        stats.latency_us(50.0),
        stats.latency_us(95.0),
        stats.latency_us(99.0)
    );

    // Interactive mode: one-at-a-time requests (latency-bound, batch 1).
    let coord = Coordinator::start(&dir, &net, 8, BatchPolicy::default())?;
    let t0 = Instant::now();
    let solo = 64.min(frames);
    for i in 0..solo {
        let g = i % n_golden;
        let out = coord.infer(golden_in[g * elems..(g + 1) * elems].to_vec())?;
        anyhow::ensure!(out == golden_out[g * out_elems..(g + 1) * out_elems]);
    }
    let dt = t0.elapsed();
    let st = coord.shutdown();
    println!(
        "interactive (batch=1): {:.2} ms/frame median, {:.1} fps",
        st.latency_us(50.0) as f64 / 1000.0,
        solo as f64 / dt.as_secs_f64()
    );
    Ok(())
}
