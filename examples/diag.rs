fn main() {
    use flexipipe::*;
    let net = model::zoo::alexnet();
    let board = board::zc706();
    let a = alloc::allocator_for(alloc::ArchKind::FlexPipeline).allocate(&net, &board, quant::QuantMode::W16A16).unwrap();
    let r = a.evaluate();
    println!("t_frame={} fps={:.1} demand={:.2}GB/s", r.t_frame_cycles, r.fps, r.ddr_demand_bytes_per_sec/1e9);
    for (s, c) in a.stages.iter().zip(&r.stage_cycles) {
        if net.layers[s.layer_idx].uses_dsps() {
            println!("  {:14} k={:3} cycles={:9} wbytes/frame={:.2}MB", net.layers[s.layer_idx].label(), s.cfg.k, c, s.figures.weight_bytes_per_frame() as f64/1e6);
        }
    }
}
