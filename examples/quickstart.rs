//! Quickstart: allocate the paper's flagship design point (VGG16 on ZC706)
//! and inspect what the framework produced.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use flexipipe::alloc::{allocator_for, ArchKind};
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::power::PowerModel;
use flexipipe::quant::QuantMode;
use flexipipe::sim;

fn main() -> flexipipe::Result<()> {
    // 1. Pick a network and a board from the zoo.
    let net = zoo::vgg16();
    let board = zc706();
    println!(
        "network: {} ({:.2} GOP, {} layers)  board: {} ({} DSPs, {} BRAM36)",
        net.name,
        net.gops(),
        net.layers.len(),
        board.name,
        board.dsps,
        board.bram36
    );

    // 2. Run the paper's allocator (Algorithm 1 + Algorithm 2).
    let alloc =
        allocator_for(ArchKind::FlexPipeline).allocate(&net, &board, QuantMode::W16A16)?;
    let r = alloc.evaluate();
    println!("\nper-layer engine parameters (the paper's C', M', K):");
    for (s, c) in alloc.stages.iter().zip(&r.stage_cycles) {
        if alloc.net.layers[s.layer_idx].uses_dsps() {
            println!(
                "  {:<14} C'={:<3} M'={:<3} K={:<2} mults={:<4} cycles/frame={}",
                alloc.net.layers[s.layer_idx].label(),
                s.cfg.cp,
                s.cfg.mp,
                s.cfg.k,
                s.figures.mults,
                c
            );
        }
    }

    // 3. Closed-form performance (Eq. 2–4 of the paper).
    println!(
        "\nclosed-form: {:.1} fps, {:.0} GOPS, {} DSPs, {:.1}% DSP efficiency",
        r.fps,
        r.gops,
        r.dsps,
        r.dsp_efficiency * 100.0
    );

    // 4. Confirm with the stall-accurate cycle simulator.
    let s = sim::simulate(&alloc, 3);
    println!(
        "simulated:   {:.1} fps, {:.0} GOPS, {:.1}% DSP efficiency, {:.0}% DDR utilization",
        s.fps,
        s.gops,
        s.dsp_efficiency * 100.0,
        s.ddr_utilization * 100.0
    );

    // 5. Power estimate (the paper uses Vivado's estimate; ours is a
    //    calibrated analytical model).
    let p = PowerModel::default().estimate(&alloc, &r);
    println!(
        "power: {:.2} W (static {:.2} + DSP {:.2} + BRAM {:.2} + logic {:.2} + DDR {:.2}) → {:.1} GOPS/W",
        p.total(),
        p.static_w,
        p.dsp_w,
        p.bram_w,
        p.logic_w,
        p.ddr_w,
        r.gops / p.total()
    );
    println!("\npaper Table I (This Work, VGG16): 11.3 fps, 353 GOPS, 900 DSPs, 98.0%, 7.2 W");
    Ok(())
}
