//! Regenerate the paper's Table I (utilization + performance, four nets ×
//! four architectures on ZC706) with the published values interleaved, and
//! print the Sec. 5.2 headline speedups.
//!
//! ```bash
//! cargo run --release --example table1_report
//! ```

use flexipipe::report;

fn main() -> flexipipe::Result<()> {
    let rows = report::table1()?;
    println!("{}", report::render(&rows, true));
    if let Some((r1, r2, r3)) = report::vgg16_speedups(&rows) {
        println!("VGG16 speedups vs baselines (paper: 2.58x / 1.53x / 1.35x):");
        println!("  vs [1] recurrent:  {r1:.2}x");
        println!("  vs [2] fusion:     {r2:.2}x");
        println!("  vs [3] DNNBuilder: {r3:.2}x");
    }
    // Simulator cross-check column.
    println!("\nclosed-form vs simulated DSP efficiency (flex rows):");
    for r in rows.iter().filter(|r| r.arch == flexipipe::alloc::ArchKind::FlexPipeline) {
        println!(
            "  {:<8} closed-form {:>5.1}%  simulated {:>5.1}%",
            r.net,
            r.dsp_efficiency * 100.0,
            r.sim_dsp_efficiency * 100.0
        );
    }
    Ok(())
}
