//! Design-space exploration: the framework's raison d'être (paper Sec. 4 —
//! "customize flexible pipeline accelerator for given NN model and FPGA
//! board"). Sweeps boards × models × precisions and prints the frontier,
//! plus a DSP-budget sweep showing where each architecture's allocation
//! quality crosses over.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use flexipipe::alloc::{allocator_for, ArchKind};
use flexipipe::board::{vc707, zc706, zcu102, zedboard};
use flexipipe::model::zoo;
use flexipipe::power::PowerModel;
use flexipipe::quant::QuantMode;

fn main() -> flexipipe::Result<()> {
    // 1. Board × model matrix at both precisions.
    println!("== board x model frontier (flex allocator) ==");
    println!(
        "{:<10} {:<9} {:>5} {:>9} {:>8} {:>8} {:>7}",
        "board", "model", "bits", "fps", "GOPS", "DSPeff%", "W"
    );
    for board in [zedboard(), zc706(), zcu102(), vc707()] {
        for net in zoo::paper_nets() {
            for mode in [QuantMode::W16A16, QuantMode::W8A8] {
                let alloc =
                    allocator_for(ArchKind::FlexPipeline).allocate(&net, &board, mode)?;
                let r = alloc.evaluate();
                let w = PowerModel::default().estimate(&alloc, &r).total();
                println!(
                    "{:<10} {:<9} {:>5} {:>9.1} {:>8.0} {:>8.1} {:>7.2}",
                    board.name,
                    net.name,
                    mode.bits(),
                    r.fps,
                    r.gops,
                    r.dsp_efficiency * 100.0,
                    w
                );
            }
        }
    }

    // 2. DSP-budget sweep on VGG16: where flexibility pays.
    println!("\n== DSP sweep, vgg16 @16b: flex vs dnnbuilder GOPS ==");
    println!("{:>6} {:>10} {:>12} {:>7}", "DSPs", "flex", "dnnbuilder", "ratio");
    let net = zoo::vgg16();
    for dsps in [128, 192, 256, 384, 512, 680, 768, 900, 1100, 1400] {
        let mut b = zc706();
        b.dsps = dsps;
        let f = allocator_for(ArchKind::FlexPipeline)
            .allocate(&net, &b, QuantMode::W16A16)?
            .evaluate();
        let d = allocator_for(ArchKind::DnnBuilder)
            .allocate(&net, &b, QuantMode::W16A16)?
            .evaluate();
        println!(
            "{:>6} {:>10.0} {:>12.0} {:>7.2}",
            dsps,
            f.gops,
            d.gops,
            f.gops / d.gops
        );
    }

    // 3. Bandwidth sweep: Algorithm 2 trading BRAM for bandwidth.
    println!("\n== DDR bandwidth sweep, vgg16 @16b (flex) ==");
    println!(
        "{:>9} {:>9} {:>8} {:>9} {:>7}",
        "GB/s", "fps", "BRAM18", "B (GB/s)", "max K"
    );
    for gbps in [2.0, 3.0, 4.0, 6.0, 8.0, 12.8] {
        let mut b = zc706();
        b.ddr_bytes_per_sec = gbps * 1e9;
        let alloc = allocator_for(ArchKind::FlexPipeline).allocate(&net, &b, QuantMode::W16A16)?;
        let r = alloc.evaluate();
        let max_k = alloc.stages.iter().map(|s| s.cfg.k).max().unwrap_or(1);
        println!(
            "{:>9.1} {:>9.1} {:>8} {:>9.2} {:>7}",
            gbps,
            r.fps,
            r.bram18,
            r.ddr_bytes_per_sec / 1e9,
            max_k
        );
    }
    Ok(())
}
