//! Design-space exploration: the framework's raison d'être (paper Sec. 4 —
//! "customize flexible pipeline accelerator for given NN model and FPGA
//! board"). Runs on the [`flexipipe::search`] engine: the board × model ×
//! precision matrix fans out across worker threads with the per-model
//! decomposition tables shared, then reduces to a Pareto frontier; the
//! DSP-budget and bandwidth sweeps reuse the same API with budget
//! overrides / mutated boards.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use flexipipe::alloc::ArchKind;
use flexipipe::board::{vc707, zc706, zcu102, zedboard};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, TenantSpec, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::search::{frontier_by_workload, DesignSpace};
use flexipipe::shard::{Regime, ScheduleMode};
use flexipipe::sim::{Simulate, Simulator};
use flexipipe::util::json::{self, Value};

fn main() -> flexipipe::Result<()> {
    // 1. Board × model matrix at both precisions — one parallel sweep.
    let t0 = std::time::Instant::now();
    let ds = DesignSpace {
        boards: vec![zedboard(), zc706(), zcu102(), vc707()],
        models: zoo::paper_nets(),
        modes: vec![QuantMode::W16A16, QuantMode::W8A8],
        ..Default::default()
    };
    let points = ds.sweep()?;
    println!(
        "== board x model frontier (flex allocator, {} points in {:.2?}) ==",
        points.len(),
        t0.elapsed()
    );
    println!(
        "{:<10} {:<9} {:>5} {:>9} {:>8} {:>8} {:>7}",
        "board", "model", "bits", "fps", "GOPS", "DSPeff%", "W"
    );
    for p in &points {
        println!(
            "{:<10} {:<9} {:>5} {:>9.1} {:>8.0} {:>8.1} {:>7.2}",
            p.board,
            p.model,
            p.mode.bits(),
            p.report.fps,
            p.report.gops,
            p.report.dsp_efficiency * 100.0,
            p.power_w
        );
    }
    // Pareto frontier per workload: which board/precision points are
    // worth building at all?
    for ((model, bits), front) in frontier_by_workload(&points) {
        let names: Vec<&str> = front.iter().map(|&i| points[i].board.as_str()).collect();
        println!("pareto {model:<9} @{bits:>2}b: {}", names.join(", "));
    }

    // 2. DSP-budget sweep on VGG16: where flexibility pays. Two archs on
    // the same budget grid in one sweep — the flex jobs share one set of
    // VGG16 decomposition tables.
    println!("\n== DSP sweep, vgg16 @16b: flex vs dnnbuilder GOPS ==");
    println!("{:>6} {:>10} {:>12} {:>7}", "DSPs", "flex", "dnnbuilder", "ratio");
    let budgets = [128, 192, 256, 384, 512, 680, 768, 900, 1100, 1400];
    let ds = DesignSpace {
        boards: vec![zc706()],
        models: vec![zoo::vgg16()],
        archs: vec![ArchKind::FlexPipeline, ArchKind::DnnBuilder],
        dsp_budgets: budgets.iter().map(|&d| Some(d)).collect(),
        ..Default::default()
    };
    let points = ds.sweep()?;
    // Job order: archs outer-loop before budgets — regroup per budget.
    for (bi, dsps) in budgets.iter().enumerate() {
        let f = &points[bi]; // flex comes first in `archs`
        let d = &points[budgets.len() + bi];
        println!(
            "{:>6} {:>10.0} {:>12.0} {:>7.2}",
            dsps,
            f.report.gops,
            d.report.gops,
            f.report.gops / d.report.gops
        );
    }

    // 3. Bandwidth sweep: Algorithm 2 trading BRAM for bandwidth. Boards
    // are arbitrary values — mutate the DDR rate per point.
    println!("\n== DDR bandwidth sweep, vgg16 @16b (flex) ==");
    println!(
        "{:>9} {:>9} {:>8} {:>9} {:>7}",
        "GB/s", "fps", "BRAM18", "B (GB/s)", "max K"
    );
    let gbps = [2.0, 3.0, 4.0, 6.0, 8.0, 12.8];
    let ds = DesignSpace {
        boards: gbps
            .iter()
            .map(|&g| {
                let mut b = zc706();
                b.ddr_bytes_per_sec = g * 1e9;
                b
            })
            .collect(),
        models: vec![zoo::vgg16()],
        ..Default::default()
    };
    for (p, g) in ds.sweep()?.iter().zip(&gbps) {
        println!(
            "{:>9.1} {:>9.1} {:>8} {:>9.2} {:>7}",
            g,
            p.report.fps,
            p.report.bram18,
            p.report.ddr_bytes_per_sec / 1e9,
            p.max_k
        );
    }

    // 4. Multi-tenant sharding: one ZC706 serving two co-resident models.
    // The sharder partitions Θ (DSP/LUT/FF/β) and α (BRAM) on independent
    // axes, reuses each model's decomposition staircases across all
    // candidate splits, and reduces to the per-tenant-fps Pareto frontier;
    // the frontier is confirmed by the shared-DDR multi-pipeline DES.
    println!("\n== shard zc706 across vgg16 + alexnet (8b) ==");
    let ds = DesignSpace {
        boards: vec![zc706()],
        tenant_groups: vec![vec![zoo::vgg16(), zoo::alexnet()]],
        modes: vec![QuantMode::W8A8],
        shard_steps: 8,
        sim_frames: 2,
        ..Default::default()
    };
    for point in ds.sweep_shards()? {
        let r = &point.result;
        println!(
            "{} on {}: {} feasible splits, {} on the frontier",
            point.models.join("+"),
            point.board,
            r.plans.len(),
            r.frontier.len()
        );
        for &i in &r.frontier {
            let p = &r.plans[i];
            let desc: Vec<String> = p
                .tenants
                .iter()
                .enumerate()
                .map(|(ti, t)| {
                    let sim = p
                        .sim
                        .as_ref()
                        .map(|s| format!(" (sim {:.1})", s[ti].fps))
                        .unwrap_or_default();
                    format!(
                        "{} Θ{}/8 α{}/8 {:.1} fps{}",
                        t.alloc.net.name, t.dsp_parts, t.bram_parts, p.fps[ti], sim
                    )
                })
                .collect();
            println!("  {}", desc.join(" | "));
        }
    }

    // 5. Spatial vs time-multiplexed sharding, merged: `--schedule auto`
    // also enumerates cyclic full-board schedules (each tenant gets the
    // whole board in a time slice, paying a partial-reconfiguration cost
    // per switch) and reduces both regimes to one per-tenant-fps frontier.
    println!("\n== shard zc706 across vgg16 + alexnet (8b, schedule=auto) ==");
    let ds = DesignSpace {
        boards: vec![zc706()],
        tenant_groups: vec![vec![zoo::vgg16(), zoo::alexnet()]],
        modes: vec![QuantMode::W8A8],
        shard_steps: 8,
        schedule: ScheduleMode::Auto,
        ..Default::default()
    };
    for point in ds.sweep_shards()? {
        let r = &point.result;
        let temporal = r.plans.iter().filter(|p| p.regime.is_temporal()).count();
        println!(
            "{} on {}: {} plans ({} temporal), {} on the merged frontier",
            point.models.join("+"),
            point.board,
            r.plans.len(),
            temporal,
            r.frontier.len()
        );
        for &i in &r.frontier {
            let p = &r.plans[i];
            let shape = match &p.regime {
                Regime::Spatial => "spatial".to_string(),
                Regime::Temporal(info) => format!(
                    "temporal {:?} ({:.0}% dead)",
                    info.time_parts,
                    info.dead_frac * 100.0
                ),
            };
            let fps: Vec<String> = p
                .tenants
                .iter()
                .zip(&p.fps)
                .map(|(t, f)| format!("{} {:.1}", t.alloc.net.name, f))
                .collect();
            println!("  {shape}: {}", fps.join(" | "));
        }
    }

    // 6. Latency-aware temporal scheduling: a per-tenant sojourn SLO
    // (`--slo`) plus interleaving (`--interleave`) — the planner may cut a
    // tenant's quanta into k sub-slices per period, trading extra
    // (drain-overlapped) reconfiguration switches for a k-fold tighter
    // worst-case frame sojourn. The overlay regime (`--overlay`) is the
    // zero-reconfiguration limit: one shared superset datapath, switches
    // pay only weight re-streaming.
    println!("\n== SLO-interleaved + overlay schedules, lenet ×2 on zc706 (8b) ==");
    let ds = DesignSpace {
        boards: vec![zc706()],
        tenant_groups: vec![vec![zoo::lenet(), zoo::lenet()]],
        modes: vec![QuantMode::W8A8],
        shard_steps: 4,
        schedule: ScheduleMode::Auto,
        max_period_s: 0.1,
        max_interleave: 2,
        slos: vec![("lenet".to_string(), 0.080)],
        ..Default::default()
    };
    for point in ds.sweep_shards()? {
        let r = &point.result;
        println!(
            "{} on {}: {} SLO-satisfying plans, {} on the (fps, latency) frontier",
            point.models.join("+"),
            point.board,
            r.plans.len(),
            r.frontier.len()
        );
        for &i in &r.frontier {
            let p = &r.plans[i];
            let shape = match &p.regime {
                Regime::Spatial => "spatial".to_string(),
                Regime::Temporal(info) => format!(
                    "{} {:?}×{:?}",
                    p.regime.label(),
                    info.time_parts,
                    info.interleave
                ),
            };
            let obj: Vec<String> = p
                .fps
                .iter()
                .zip(&p.latency_s)
                .map(|(f, l)| format!("{f:.1} fps / {:.1} ms", l * 1e3))
                .collect();
            println!("  {shape}: {}", obj.join(" | "));
        }
        // The JSON view carries the same axes (machine-readable).
        let Value::Obj(_) = point.to_json(4) else {
            unreachable!("shard points encode as JSON objects")
        };
    }

    // 7. The plan-centric flow: everything above condenses into one spine —
    // a Workload (tenants + constraints + objective) goes through the
    // Planner facade into a versioned, serializable DeploymentPlan that
    // the Simulate trait executes and the serving runtime consumes
    // (`flexipipe plan … --json plan.json` is the CLI spelling).
    println!("\n== plan-centric flow: Workload → Planner → DeploymentPlan → Simulate ==");
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant_spec(TenantSpec::new(zoo::lenet()).weight(2.0));
    let set = Planner::on(zedboard()).steps(8).validate(2).plan(&workload)?;
    let best = &set.plans[set.best];
    println!(
        "{} feasible plans, {} on the frontier; best ({} objective): {} regime on {}",
        set.plans.len(),
        set.frontier.len(),
        set.objective.label(),
        best.regime.label(),
        best.board.name
    );
    for t in &best.tenants {
        if let Some(r) = &t.record {
            println!(
                "  {:<10} Θ {}/{}  α {}/{}: {:.1} fps planned",
                t.net.name, t.dsp_parts, best.steps, t.bram_parts, best.steps, r.fps
            );
        }
    }
    // The plan is the deployment artifact: JSON round-trips bit-exactly,
    // and the DES executes the rehydrated plan.
    let text = best.to_json().to_pretty();
    let back = DeploymentPlan::from_json(&json::parse(&text)?)?;
    assert_eq!(text, back.to_json().to_pretty());
    let report = Simulator { frames: 2 }.simulate(&back)?;
    println!(
        "  DES confirms (via the JSON round trip): {:?} fps",
        report
            .tenant_fps()
            .iter()
            .map(|f| (f * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    Ok(())
}
