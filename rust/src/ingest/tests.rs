//! Unit tests for the ingestion layer: histogram bucketing, seeded
//! arrival generation, trace-spec codec, the slice gate, and the
//! deterministic queue models. Live-service integration lives in
//! `tests/ingest_serve.rs`.

use super::*;
use crate::shard::SliceSpec;

fn poisson_spec(seed: u64) -> TraceSpec {
    TraceSpec {
        seed,
        duration_s: 10.0,
        queue_capacity: 0,
        tenants: vec![
            TenantTrace {
                tenant: "vgg16".into(),
                process: ArrivalProcess::Poisson { rate_fps: 40.0 },
            },
            TenantTrace {
                tenant: "alexnet".into(),
                process: ArrivalProcess::Diurnal {
                    base_fps: 10.0,
                    peak_fps: 60.0,
                    period_s: 2.0,
                },
            },
            TenantTrace {
                tenant: "zfnet".into(),
                process: ArrivalProcess::Bursty {
                    rate_fps: 30.0,
                    burst: 5,
                    gap_s: 0.001,
                },
            },
        ],
    }
}

/// A two-tenant schedule with interleaved sub-slices, shaped like the
/// planner's output (tenant 0 twice per period, tenant 1 once).
fn two_tenant_info() -> TemporalInfo {
    let slice = |tenant, parts, frames, reconfig, overlap| SliceSpec {
        tenant,
        parts,
        frames,
        reconfig_cycles: reconfig,
        overlap_cycles: overlap,
    };
    TemporalInfo {
        time_parts: vec![8, 8],
        interleave: vec![2, 1],
        slices: vec![
            slice(0, 4, 2, 100, 20),
            slice(1, 8, 3, 50, 0),
            slice(0, 4, 2, 100, 20),
        ],
        quantum_cycles: 1_000,
        period_cycles: 16_000,
        frames: vec![4, 3],
        reconfig_cycles: vec![100, 50],
        fill_cycles: vec![300, 200],
        beat_cycles: vec![150, 100],
        latency_cycles: vec![9_000, 17_000],
        overlay: false,
        dead_frac: 0.0,
    }
}

// -- LatencyHistogram -------------------------------------------------------

#[test]
fn histogram_small_values_are_exact() {
    let mut h = LatencyHistogram::new();
    for v in [0u64, 1, 2, 3] {
        h.record(v);
    }
    assert_eq!(h.count(), 4);
    assert_eq!(h.min(), 0);
    assert_eq!(h.max(), 3);
    assert_eq!(h.quantile(25.0), 0);
    assert_eq!(h.quantile(50.0), 1);
    assert_eq!(h.quantile(75.0), 2);
    assert_eq!(h.quantile(100.0), 3);
}

#[test]
fn histogram_quantiles_overestimate_by_at_most_a_quarter() {
    // The log-bucket contract: quantile ≥ true value, and within 25%.
    let mut h = LatencyHistogram::new();
    let mut rng = Rng::new(7);
    let mut samples: Vec<u64> = (0..10_000).map(|_| rng.urange(1, 1 << 40) as u64).collect();
    for &s in &samples {
        h.record(s);
    }
    samples.sort_unstable();
    for p in [50.0, 90.0, 99.0, 99.9] {
        let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize - 1;
        let truth = samples[rank];
        let est = h.quantile(p);
        assert!(est >= truth, "p{p}: {est} < exact {truth}");
        assert!(
            est as f64 <= truth as f64 * 1.25,
            "p{p}: {est} overestimates exact {truth} by more than 25%"
        );
    }
    assert_eq!(h.quantile(100.0), *samples.last().unwrap(), "p100 is exact");
}

#[test]
fn histogram_empty_is_all_zero() {
    let h = LatencyHistogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.max(), 0);
    assert_eq!(h.min(), 0);
    assert_eq!(h.quantile(99.0), 0);
}

#[test]
fn histogram_bucket_bounds_cover_the_whole_range() {
    // upper(bucket(v)) ≥ v for any v, including the extremes.
    let mut rng = Rng::new(11);
    let mut probe = vec![0u64, 1, 3, 4, 5, 7, 8, u64::MAX - 1, u64::MAX];
    for _ in 0..1_000 {
        probe.push(rng.next_u64());
    }
    for &v in &probe {
        let idx = LatencyHistogram::bucket(v);
        assert!(
            LatencyHistogram::upper(idx) >= v,
            "bucket {idx} upper bound below sample {v}"
        );
        if idx > 0 {
            assert!(
                LatencyHistogram::upper(idx - 1) < v,
                "sample {v} belongs in bucket {}",
                idx - 1
            );
        }
    }
}

// -- Arrival generation -----------------------------------------------------

#[test]
fn arrivals_are_deterministic_per_seed_and_sorted() {
    let spec = poisson_spec(42);
    let a = spec.arrivals(200e6).unwrap();
    let b = spec.arrivals(200e6).unwrap();
    assert_eq!(a, b, "same seed must generate identical arrivals");
    let horizon = (spec.duration_s * 200e6) as u64;
    for (t, arr) in a.iter().enumerate() {
        assert!(!arr.is_empty(), "tenant {t} generated no arrivals");
        assert!(arr.windows(2).all(|w| w[0] <= w[1]), "tenant {t} unsorted");
        assert!(*arr.last().unwrap() < horizon, "tenant {t} beyond horizon");
    }
    let c = poisson_spec(43).arrivals(200e6).unwrap();
    assert_ne!(a, c, "different seeds must diverge");
}

#[test]
fn arrival_counts_track_the_offered_rate() {
    let spec = poisson_spec(1);
    let arr = spec.arrivals(200e6).unwrap();
    // Expected counts over 10 s: poisson 400, diurnal mean 35 fps → 350,
    // bursty 300. Allow ±40% — these are stochastic but seeded (so the
    // assertion is deterministic), and gross rate bugs (off by burst, off
    // by the thinning majorant) land far outside the window.
    for (t, expect) in [(0usize, 400.0f64), (1, 350.0), (2, 300.0)] {
        let got = arr[t].len() as f64;
        assert!(
            (got - expect).abs() < expect * 0.4,
            "tenant {t}: {got} arrivals vs expected ≈{expect}"
        );
    }
}

#[test]
fn tenant_substreams_are_independent() {
    // Dropping a later tenant must not perturb an earlier one's stream.
    let full = poisson_spec(9);
    let mut solo = full.clone();
    solo.tenants.truncate(1);
    assert_eq!(full.arrivals(200e6).unwrap()[0], solo.arrivals(200e6).unwrap()[0]);
}

// -- TraceSpec codec --------------------------------------------------------

#[test]
fn trace_spec_roundtrips_through_json() {
    let spec = poisson_spec(77);
    let back = TraceSpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(spec, back);
    // And the serialized form itself is stable.
    assert_eq!(spec.to_json().to_pretty(), back.to_json().to_pretty());
}

#[test]
fn unknown_trace_version_is_rejected_with_supported_range() {
    let mut v = poisson_spec(1).to_json();
    if let Value::Obj(m) = &mut v {
        m.insert("version".into(), num(99));
    }
    let err = TraceSpec::from_json(&v).unwrap_err().to_string();
    assert!(
        err.contains("unsupported trace-spec version 99") && err.contains("1..=1"),
        "{err}"
    );
}

#[test]
fn trace_spec_validation_rejects_bad_shapes() {
    let mut spec = poisson_spec(1);
    spec.duration_s = 0.0;
    assert!(spec.validate().is_err());

    let mut spec = poisson_spec(1);
    spec.tenants.clear();
    assert!(spec.validate().is_err());

    let mut spec = poisson_spec(1);
    spec.tenants[1].tenant = "vgg16".into();
    let err = spec.validate().unwrap_err().to_string();
    assert!(err.contains("twice"), "{err}");

    let mut spec = poisson_spec(1);
    spec.tenants[0].process = ArrivalProcess::Poisson { rate_fps: -1.0 };
    assert!(spec.validate().is_err());

    let mut spec = poisson_spec(1);
    spec.tenants[1].process = ArrivalProcess::Diurnal {
        base_fps: 50.0,
        peak_fps: 10.0,
        period_s: 1.0,
    };
    let err = spec.validate().unwrap_err().to_string();
    assert!(err.contains("peak_fps"), "{err}");
}

// -- CLI arrival parsing ----------------------------------------------------

#[test]
fn parse_arrivals_accepts_all_three_processes() {
    let list = "vgg16=poisson:2.5, alexnet=diurnal:1:4:5s, zfnet=bursty:3:10:10ms";
    let got = parse_arrivals(list).unwrap();
    assert_eq!(got.len(), 3);
    assert_eq!(got[0].process, ArrivalProcess::Poisson { rate_fps: 2.5 });
    assert_eq!(
        got[1].process,
        ArrivalProcess::Diurnal {
            base_fps: 1.0,
            peak_fps: 4.0,
            period_s: 5.0,
        }
    );
    assert_eq!(got[2].process, ArrivalProcess::Bursty { rate_fps: 3.0, burst: 10, gap_s: 0.01 });
}

#[test]
fn parse_arrivals_requires_duration_suffixes() {
    // The same unit rigor as --slo: a bare number is not a duration.
    let err = parse_arrivals("a=diurnal:1:4:5").unwrap_err().to_string();
    assert!(err.contains("s, ms, us, m, or h"), "{err}");
    let err = parse_arrivals("a=bursty:3:10:7").unwrap_err().to_string();
    assert!(err.contains("s, ms, us, m, or h"), "{err}");
}

#[test]
fn parse_arrivals_rejects_malformed_entries() {
    assert!(parse_arrivals("").is_err());
    assert!(parse_arrivals("vgg16").is_err());
    assert!(parse_arrivals("vgg16=uniform:3").is_err());
    assert!(parse_arrivals("vgg16=poisson:abc").is_err());
    assert!(parse_arrivals("vgg16=poisson:0").is_err());
    assert!(parse_arrivals("vgg16=bursty:3:0:1ms").is_err());
}

// -- RejectReason -----------------------------------------------------------

#[test]
fn reject_reasons_are_typed_and_labeled() {
    let full = RejectReason::QueueFull { depth: 4, capacity: 4 };
    assert_eq!(full.label(), "queue-full");
    assert!(full.to_string().contains("capacity 4"));
    assert_eq!(RejectReason::Shedding.label(), "shedding");
    assert_eq!(RejectReason::Closed.label(), "closed");
}

// -- Slice gate -------------------------------------------------------------

#[test]
fn slice_gate_opens_only_inside_a_tenants_charged_sub_slices() {
    let info = two_tenant_info();
    // Slice layout: [0: cycles 0..4000), [1: 4000..12000), [0: 12000..16000).
    // Tenant 0's charged window is 80 cycles (100 − 20 overlap).
    assert!(!slice_open(&info, 0, 0), "charged window is closed");
    assert!(slice_open(&info, 0, 80));
    assert!(slice_open(&info, 0, 3_999));
    assert!(!slice_open(&info, 0, 4_000), "tenant 1's slice");
    assert!(!slice_open(&info, 1, 3_999));
    assert!(slice_open(&info, 1, 4_050), "after tenant 1's 50-cycle charge");
    assert!(!slice_open(&info, 1, 4_020), "inside tenant 1's charge");
    assert!(slice_open(&info, 0, 12_080));
    // Periodicity: the same pattern one period later.
    assert!(slice_open(&info, 0, 16_000 + 80));
    assert!(!slice_open(&info, 0, 16_000 + 4_000));
}

#[test]
fn degenerate_solo_schedule_is_always_open() {
    let mut info = two_tenant_info();
    info.period_cycles = 0;
    assert!(slice_open(&info, 0, 0));
    assert!(slice_open(&info, 0, 123_456));
}

// -- Deterministic queue models ---------------------------------------------

#[test]
fn resident_model_respects_the_fill_plus_beat_bound_at_capacity_one() {
    // cap = 1 is the premise of the solo fill+beat bound: every admitted
    // request starts at most one beat after arrival.
    let (fill, beat) = (300u64, 150u64);
    let mut rng = Rng::new(5);
    let mut arrivals: Vec<u64> = Vec::new();
    let mut t = 0u64;
    for _ in 0..500 {
        t += rng.urange(0, 400) as u64;
        arrivals.push(t);
    }
    let mut tally = TenantTally::default();
    serve_resident(fill, beat, &arrivals, 1, &mut tally);
    assert_eq!(tally.admitted + tally.rejected_full, arrivals.len() as u64);
    assert!(tally.admitted > 0);
    assert!(
        tally.hist.max() <= fill + beat,
        "p100 {} exceeds fill+beat {}",
        tally.hist.max(),
        fill + beat
    );
}

#[test]
fn resident_model_rejects_under_sustained_overload() {
    // Offered inter-arrival 10 ≪ beat 150: almost everything must be
    // rejected once the single waiting slot fills.
    let arrivals: Vec<u64> = (0..1_000u64).map(|i| i * 10).collect();
    let mut tally = TenantTally::default();
    serve_resident(300, 150, &arrivals, 1, &mut tally);
    assert!(tally.rejected_full > 900, "rejected {}", tally.rejected_full);
    assert!(tally.hist.max() <= 450);
}

/// A small real plan (the existing test idiom) to exercise the replay
/// against genuine planner output + DES calibration. Temporal mode on a
/// lone tenant yields the degenerate solo schedule, whose analytic bound
/// is exactly `fill + beat` — the bound the resident queue model
/// preserves by construction.
fn lenet_plan() -> crate::plan::DeploymentPlan {
    let w = crate::plan::Workload::new(crate::quant::QuantMode::W8A8)
        .tenant(crate::model::zoo::lenet());
    let set = crate::plan::Planner::on(crate::board::zedboard())
        .steps(4)
        .schedule(crate::shard::ScheduleMode::Temporal)
        .plan(&w)
        .unwrap();
    set.plans[set.best].clone()
}

#[test]
fn solo_plan_replay_stays_within_the_fill_plus_beat_bound() {
    let plan = lenet_plan();
    let spec = TraceSpec {
        seed: 3,
        duration_s: 2.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "lenet".into(),
            process: ArrivalProcess::Poisson { rate_fps: 5.0 },
        }],
    };
    let report = serve_trace(&plan, &spec).unwrap();
    let t = &report.tenants[0];
    assert!(t.offered > 0);
    assert_eq!(t.offered, t.admitted + t.rejected_full);
    let bound = t.worst_sojourn_cycles.expect("solo plan carries fill+beat");
    assert!(
        t.p100_cycles <= bound,
        "p100 {} exceeds analytic bound {bound}",
        t.p100_cycles
    );
    assert_eq!(t.within_bound, Some(true));
    // Determinism: byte-identical on a second run.
    let again = serve_trace(&plan, &spec).unwrap();
    assert_eq!(report.to_json().to_pretty(), again.to_json().to_pretty());
}

// -- Closed-loop clients ----------------------------------------------------

#[test]
fn closed_loop_process_labels_validates_and_roundtrips() {
    let p = ArrivalProcess::ClosedLoop {
        clients: 8,
        think_time_s: 0.005,
    };
    assert_eq!(p.label(), "closed");
    // Zero-service-time ceiling: 8 clients / 5 ms think.
    assert!((p.mean_fps() - 1600.0).abs() < 1e-9);
    let spec = TraceSpec {
        seed: 5,
        duration_s: 1.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "lenet".into(),
            process: p,
        }],
    };
    let back = TraceSpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(spec, back);

    let mut bad = spec.clone();
    bad.tenants[0].process = ArrivalProcess::ClosedLoop {
        clients: 0,
        think_time_s: 0.005,
    };
    assert!(bad.validate().is_err(), "zero clients is not a loop");
    let mut bad = spec.clone();
    bad.tenants[0].process = ArrivalProcess::ClosedLoop {
        clients: 2,
        think_time_s: 0.0,
    };
    assert!(bad.validate().is_err(), "think time must be positive");
}

#[test]
fn closed_loop_tenants_have_empty_open_loop_streams() {
    // arrivals() yields nothing for a closed tenant (its arrivals are
    // completion-coupled), and — substream independence — swapping a
    // co-tenant's process never perturbs another tenant's stream.
    let mk = |p0: ArrivalProcess| TraceSpec {
        seed: 21,
        duration_s: 5.0,
        queue_capacity: 0,
        tenants: vec![
            TenantTrace {
                tenant: "a".into(),
                process: p0,
            },
            TenantTrace {
                tenant: "b".into(),
                process: ArrivalProcess::Poisson { rate_fps: 20.0 },
            },
        ],
    };
    let with_closed = mk(ArrivalProcess::ClosedLoop {
        clients: 4,
        think_time_s: 0.01,
    })
    .arrivals(200e6)
    .unwrap();
    assert!(with_closed[0].is_empty(), "closed tenants pre-generate nothing");
    assert!(!with_closed[1].is_empty());
    let with_open = mk(ArrivalProcess::Poisson { rate_fps: 1.0 }).arrivals(200e6).unwrap();
    assert_eq!(with_closed[1], with_open[1], "tenant substreams are independent");
}

#[test]
fn closed_loop_replay_is_deterministic_and_stays_in_bound() {
    let plan = lenet_plan();
    let spec = TraceSpec {
        seed: 11,
        duration_s: 2.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "lenet".into(),
            process: ArrivalProcess::ClosedLoop {
                clients: 4,
                think_time_s: 0.01,
            },
        }],
    };
    let report = serve_trace(&plan, &spec).unwrap();
    let t = &report.tenants[0];
    assert!(t.offered > 0, "clients must generate traffic");
    assert_eq!(t.offered, t.admitted + t.rejected_full);
    assert!(t.admitted > 0);
    assert_eq!(t.within_bound, Some(true), "admitted work keeps the analytic bound");
    // Self-limiting: offered load cannot exceed the zero-service-time
    // ceiling (clients/think × duration) by more than the seeded draws'
    // slack — 2× is far outside any plausible exponential-sum excursion.
    let ceiling = spec.tenants[0].process.mean_fps() * spec.duration_s;
    assert!(
        (t.offered as f64) < 2.0 * ceiling,
        "offered {} vs closed-loop ceiling {ceiling}",
        t.offered
    );
    // Byte-determinism, and seeds actually matter.
    let again = serve_trace(&plan, &spec).unwrap();
    assert_eq!(report.to_json().to_pretty(), again.to_json().to_pretty());
    let mut other = spec.clone();
    other.seed = 12;
    let diverged = serve_trace(&plan, &other).unwrap();
    assert_ne!(
        report.to_json().to_pretty(),
        diverged.to_json().to_pretty(),
        "different seeds must draw different think times"
    );
}

#[test]
fn single_closed_client_never_trips_queue_full() {
    // The defining closed-loop property: one client's next arrival is
    // gated on its previous completion, so it can never race itself into
    // a full queue — unlike any open-loop process at the same mean rate.
    let plan = lenet_plan();
    let spec = TraceSpec {
        seed: 3,
        duration_s: 2.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "lenet".into(),
            process: ArrivalProcess::ClosedLoop {
                clients: 1,
                think_time_s: 0.001,
            },
        }],
    };
    let report = serve_trace(&plan, &spec).unwrap();
    let t = &report.tenants[0];
    assert!(t.offered > 0);
    assert_eq!(t.rejected_full, 0, "a lone closed client is completion-gated");
    assert_eq!(t.offered, t.admitted);
}

#[test]
fn parse_arrivals_accepts_closed_loops() {
    let got = parse_arrivals("lenet=closed:8:5ms").unwrap();
    assert_eq!(
        got[0].process,
        ArrivalProcess::ClosedLoop {
            clients: 8,
            think_time_s: 0.005,
        }
    );
    assert!(parse_arrivals("a=closed:x:5ms").is_err());
    assert!(parse_arrivals("a=closed:3").is_err());
    assert!(parse_arrivals("a=closed:3:junk").is_err());
    // Think times carry the same unit rigor as every other duration.
    let err = parse_arrivals("a=closed:3:5").unwrap_err().to_string();
    assert!(err.contains("s, ms, us, m, or h"), "{err}");
}

// -- Deadlines ---------------------------------------------------------------

#[test]
fn deadline_expired_rejections_are_typed_and_labeled() {
    let r = RejectReason::DeadlineExpired {
        missed_by_cycles: 1234,
    };
    assert_eq!(r.label(), "deadline-expired");
    let msg = r.to_string();
    assert!(msg.contains("1234 cycles"), "{msg}");
    assert!(msg.contains("dropped"), "{msg}");
}

#[test]
fn serve_trace_rejects_unknown_tenants() {
    let plan = lenet_plan();
    let spec = TraceSpec {
        seed: 1,
        duration_s: 1.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "resnet152".into(),
            process: ArrivalProcess::Poisson { rate_fps: 1.0 },
        }],
    };
    let err = serve_trace(&plan, &spec).unwrap_err().to_string();
    assert!(err.contains("resnet152"), "{err}");
}
