//! Power estimation.
//!
//! The paper reports Vivado's post-implementation power estimate (Sec. 5.2
//! explicitly notes it is an estimate, not a meter reading). We substitute
//! an activity-based analytical model of the same structure Vivado uses —
//! static + per-resource dynamic terms — with coefficients calibrated so
//! the four Table I design points land on the paper's numbers (7.2 W VGG16,
//! 6.9 W AlexNet, 7.1 W ZF, 7.3 W YOLO on ZC706 @ 200 MHz, 16-bit):
//! that calibration is checked by unit test.

use crate::alloc::{AllocReport, Allocation};

/// Power model coefficients (Watts per unit at 200 MHz reference clock).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Static + PS-side power (Zynq PS runs the demo system's driver).
    pub static_w: f64,
    /// Per active DSP slice at reference clock.
    pub per_dsp: f64,
    /// Per BRAM18 block.
    pub per_bram18: f64,
    /// Per LUT (toggling fabric).
    pub per_lut: f64,
    /// Per GB/s of DDR traffic.
    pub per_gbps: f64,
    /// Reference clock the coefficients are normalized to.
    pub ref_hz: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibrated against Table I (see module docs + tests).
        PowerModel {
            static_w: 2.3,
            per_dsp: 0.00305,
            per_bram18: 0.00145,
            per_lut: 6.0e-6,
            per_gbps: 0.055,
            ref_hz: 200e6,
        }
    }
}

/// Power estimate breakdown.
#[derive(Debug, Clone)]
pub struct PowerEstimate {
    /// Device static power (W).
    pub static_w: f64,
    /// DSP dynamic power (W).
    pub dsp_w: f64,
    /// BRAM dynamic power (W).
    pub bram_w: f64,
    /// LUT/FF dynamic power (W).
    pub logic_w: f64,
    /// DDR interface power (W).
    pub ddr_w: f64,
}

impl PowerEstimate {
    /// Total Watts.
    pub fn total(&self) -> f64 {
        self.static_w + self.dsp_w + self.bram_w + self.logic_w + self.ddr_w
    }
}

impl PowerModel {
    /// Estimate power for an evaluated allocation. DSP activity scales with
    /// the measured efficiency (idle DSP slices clock-gate their MAC regs).
    pub fn estimate(&self, alloc: &Allocation, report: &AllocReport) -> PowerEstimate {
        let clock_scale = alloc.freq_hz / self.ref_hz;
        let activity = 0.3 + 0.7 * report.dsp_efficiency; // idle ≠ free
        PowerEstimate {
            static_w: self.static_w,
            dsp_w: self.per_dsp * report.dsps as f64 * activity * clock_scale,
            bram_w: self.per_bram18 * report.bram18 as f64 * clock_scale,
            logic_w: self.per_lut * report.luts as f64 * clock_scale,
            ddr_w: self.per_gbps * report.ddr_bytes_per_sec / 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flex::FlexAllocator;
    use crate::alloc::Allocator;
    use crate::board::zc706;
    use crate::model::zoo;
    use crate::quant::QuantMode;

    /// Paper Table I power rows ("This Work", Vivado estimates).
    const PAPER: &[(&str, f64)] = &[
        ("vgg16", 7.2),
        ("alexnet", 6.9),
        ("zf", 7.1),
        ("yolo", 7.3),
    ];

    #[test]
    fn calibration_lands_on_table1_power() {
        let pm = PowerModel::default();
        for &(name, watts) in PAPER {
            let net = zoo::by_name(name).unwrap();
            let alloc = FlexAllocator::default()
                .allocate(&net, &zc706(), QuantMode::W16A16)
                .unwrap();
            let est = pm.estimate(&alloc, &alloc.evaluate()).total();
            let err = (est - watts).abs() / watts;
            assert!(
                err < 0.15,
                "{name}: estimated {est:.2} W vs paper {watts} W ({:.0}% off)",
                err * 100.0
            );
        }
    }

    #[test]
    fn idle_design_draws_less() {
        let pm = PowerModel::default();
        let net = zoo::vgg16();
        let alloc = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        let mut r = alloc.evaluate();
        let busy = pm.estimate(&alloc, &r).total();
        r.dsp_efficiency = 0.1;
        let idle = pm.estimate(&alloc, &r).total();
        assert!(idle < busy);
    }

    #[test]
    fn lower_clock_draws_less() {
        let pm = PowerModel::default();
        let net = zoo::zf();
        let mut alloc = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        let at200 = pm.estimate(&alloc, &r).total();
        alloc.freq_hz = 100e6;
        let at100 = pm.estimate(&alloc, &r).total();
        assert!(at100 < at200);
    }
}
