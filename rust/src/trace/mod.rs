//! Simulation traces: per-stage occupancy/stall series in CSV, the raw
//! material for the paper's Fig. 1(b)-style timing diagrams and for
//! debugging allocations (`flexipipe simulate --trace out.csv`).

use crate::alloc::Allocation;
use crate::sim::SimReport;
use std::fmt::Write as _;

/// One CSV row per stage with identity, configuration and measured cycles.
pub fn stage_csv(alloc: &Allocation, sim: &SimReport) -> String {
    let mut out = String::from(
        "stage,layer,kind,cp,mp,k,mults,busy_cycles,weight_stall_cycles,groups,busy_frac\n",
    );
    for (i, (s, st)) in alloc.stages.iter().zip(&sim.stages).enumerate() {
        let layer = &alloc.net.layers[s.layer_idx];
        let busy_frac = st.busy_cycles as f64 / sim.makespan.max(1) as f64;
        let _ = writeln!(
            out,
            "{i},{},{},{},{},{},{},{},{},{},{:.4}",
            layer.label(),
            match layer {
                crate::model::Layer::Conv(_) => "conv",
                crate::model::Layer::Pool(_) => "pool",
                crate::model::Layer::Fc(_) => "fc",
            },
            s.cfg.cp,
            s.cfg.mp,
            s.cfg.k,
            s.figures.mults,
            st.busy_cycles,
            st.stall_weights,
            st.groups_done,
            busy_frac
        );
    }
    out
}

/// Aggregate allocation summary as a CSV row (for sweep scripts).
pub fn summary_csv_header() -> &'static str {
    "net,board,arch,bits,fps,gops,dsps,dsp_eff,bram18,luts,ffs,ddr_gbps\n"
}

/// One summary row.
pub fn summary_csv_row(alloc: &Allocation) -> String {
    let r = alloc.evaluate();
    format!(
        "{},{},{},{},{:.3},{:.1},{},{:.4},{},{},{},{:.3}\n",
        alloc.net.name,
        alloc.board.name,
        alloc.arch.label(),
        alloc.mode.bits(),
        r.fps,
        r.gops,
        r.dsps,
        r.dsp_efficiency,
        r.bram18,
        r.luts,
        r.ffs,
        r.ddr_bytes_per_sec / 1e9
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{allocator_for, ArchKind};
    use crate::board::zc706;
    use crate::model::zoo;
    use crate::quant::QuantMode;
    use crate::sim;

    #[test]
    fn stage_csv_has_row_per_stage() {
        let alloc = allocator_for(ArchKind::FlexPipeline)
            .allocate(&zoo::tinycnn(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let s = sim::simulate(&alloc, 2);
        let csv = stage_csv(&alloc, &s);
        assert_eq!(csv.lines().count(), 1 + alloc.stages.len());
        assert!(csv.lines().nth(1).unwrap().contains("conv"));
    }

    #[test]
    fn summary_row_parses_back() {
        let alloc = allocator_for(ArchKind::FlexPipeline)
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let row = summary_csv_row(&alloc);
        let fields: Vec<&str> = row.trim().split(',').collect();
        assert_eq!(
            fields.len(),
            summary_csv_header().trim().split(',').count()
        );
        assert_eq!(fields[0], "lenet");
        assert!(fields[4].parse::<f64>().unwrap() > 0.0);
    }
}
