//! Time-multiplexed sharding: give each tenant the *whole* board in turn.
//!
//! Spatial sharding ([`crate::shard`]) keeps every tenant resident at once,
//! but the paper's layer-wise pipeline only clears its >90% DSP-efficiency
//! band when a tenant holds enough multipliers to balance its stages —
//! small slices starve (the single-engine/multi-CLP trade-off of the
//! partitioning literature). This module is the other regime: each tenant
//! runs its **full-board** Sec. 4 allocation inside a time slice of a
//! cyclic schedule, paying a partial-reconfiguration cost at every switch.
//! Per-tenant fps vectors are directly comparable across the two regimes,
//! so [`crate::shard::Sharder::search`] merges both plan sets into one
//! Pareto frontier (`--schedule auto`).
//!
//! # The schedule
//!
//! A period of `steps` quanta is cut into per-tenant slices by the same
//! composition machinery the spatial axis uses. A slice executes:
//! *drain* (the previous tenant's pipeline empties) → *reconfigure*
//! ([`ReconfigModel`]: partial-bitstream bytes derived from the incoming
//! tenant's LUT/DSP/BRAM footprint, loaded through the configuration
//! port) → *refill + run* (the tenant's pipeline fills and processes its
//! admitted batch). Reconfiguration and refill are dead time charged
//! against the schedule, which is why slice *quantum* matters: longer
//! periods amortize the dead time, at the cost of per-tenant service
//! latency (bounded by [`crate::shard::Sharder::max_period_s`]). The
//! planner sweeps the quantum over halvings of that bound together with
//! all slice compositions and lets the frontier reduction pick; cyclic
//! tenant *order* is throughput-neutral under this cost model (each
//! period pays every tenant's swap-in exactly once, whatever the
//! rotation), so plans keep the caller's tenant order.
//!
//! # Analytic schedule vs. simulated confirmation
//!
//! Admission (how many frames fit a slice) is decided analytically from a
//! one-time DES calibration of each tenant's solo pipeline: the exact
//! makespans of the first `calib` frames plus a conservative (max-gap)
//! steady-state beat for extrapolation — conservative because the
//! completion-time prefix property ([`SimReport::frame_done`]) makes
//! over-estimating a batch's makespan safe (idle tail) while
//! under-estimating would stretch the period. The sharder's validation
//! pass then *executes* frontier schedules with
//! [`crate::sim::simulate_timeshared`] — drain, reconfigure, refill, dead
//! cycles charged — and the acceptance tests pin the simulated per-tenant
//! fps to the analytic schedule within 1%.
//!
//! [`SimReport::frame_done`]: crate::sim::SimReport::frame_done

use crate::alloc::flex::{FlexAllocator, NetTables};
use crate::alloc::{AllocReport, Allocation};
use crate::shard::{binomial, compositions, suggest_steps, Regime, ShardPlan, Sharder, TenantAlloc};
use crate::sim;
use std::sync::Arc;

/// Partial-reconfiguration cost model: configuration bytes proportional to
/// the fabric footprint of the incoming tenant's region, loaded through
/// the configuration port.
///
/// The per-resource byte weights are calibrated so a region covering a
/// full XC7Z045 (ZC706: 218.6k LUTs, 900 DSPs, 1090 BRAM18) costs ≈13 MB
/// — that device's full-bitstream size — and the default port rate is the
/// Zynq-7000 PCAP's ≈145 MB/s, giving ≈60–90 ms for a VGG16-sized region.
/// Weight preloads are deliberately *not* billed here: the DES already
/// charges each pipeline's first weight-buffer fill per slice (the
/// group-0 weight service in [`crate::sim`]), so adding them would double
/// count the DDR side of a swap.
#[derive(Debug, Clone)]
pub struct ReconfigModel {
    /// Configuration bytes per LUT in the region.
    pub bytes_per_lut: f64,
    /// Configuration bytes per DSP slice.
    pub bytes_per_dsp: f64,
    /// Configuration bytes per BRAM18 (frame config + content init).
    pub bytes_per_bram18: f64,
    /// Fixed per-swap overhead (headers, region clearing, port setup).
    pub base_bytes: f64,
    /// Configuration port throughput (PCAP ≈145 MB/s; ICAP ≈400 MB/s).
    pub port_bytes_per_sec: f64,
}

impl Default for ReconfigModel {
    fn default() -> Self {
        ReconfigModel {
            bytes_per_lut: 45.0,
            bytes_per_dsp: 600.0,
            bytes_per_bram18: 2_304.0,
            base_bytes: 65_536.0,
            port_bytes_per_sec: 145e6,
        }
    }
}

impl ReconfigModel {
    /// Free reconfiguration: the limit where tenants share one overlay and
    /// a swap is pure state (also what the temporal-vs-spatial dominance
    /// property tests pin down).
    pub fn zero() -> ReconfigModel {
        ReconfigModel {
            bytes_per_lut: 0.0,
            bytes_per_dsp: 0.0,
            bytes_per_bram18: 0.0,
            base_bytes: 0.0,
            ..Default::default()
        }
    }

    /// Partial-bitstream bytes for the region a tenant's allocation
    /// occupies.
    pub fn bitstream_bytes(&self, r: &AllocReport) -> f64 {
        self.base_bytes
            + self.bytes_per_lut * r.luts as f64
            + self.bytes_per_dsp * r.dsps as f64
            + self.bytes_per_bram18 * r.bram18 as f64
    }

    /// Seconds to swap the tenant's region in.
    pub fn seconds(&self, r: &AllocReport) -> f64 {
        self.bitstream_bytes(r) / self.port_bytes_per_sec
    }

    /// Dead cycles at the board clock.
    pub fn cycles(&self, r: &AllocReport, freq_hz: f64) -> u64 {
        (self.seconds(r) * freq_hz).ceil() as u64
    }
}

/// The temporal half of a [`ShardPlan`]: how the period is cut and what
/// the analytic schedule admits.
///
/// A lone tenant degenerates to continuous solo operation (no switches, no
/// reconfiguration): `period_cycles == 0` marks that case and the plan's
/// fps is the closed-form solo fps, bit-identical to the plain
/// [`FlexAllocator`] (property-tested).
#[derive(Debug, Clone)]
pub struct TemporalInfo {
    /// Per-tenant time quanta (out of the sharder's `steps`).
    pub time_parts: Vec<usize>,
    /// Slice quantum in cycles; a tenant's slice is `time_parts · quantum`.
    pub quantum_cycles: u64,
    /// Schedule period in cycles (`steps · quantum`).
    pub period_cycles: u64,
    /// Frames the analytic schedule admits per tenant per period.
    pub frames: Vec<usize>,
    /// Per-tenant reconfiguration dead cycles at the head of each slice.
    pub reconfig_cycles: Vec<u64>,
    /// Calibrated first-frame latency (pipeline refill) per tenant.
    pub fill_cycles: Vec<u64>,
    /// Calibrated steady-state beat per tenant (max completion gap — the
    /// conservative extrapolation base).
    pub beat_cycles: Vec<u64>,
    /// Fraction of the period not covered by steady-state frame beats
    /// (reconfiguration + refill + idle tails), analytic. Stricter than
    /// the executed-schedule [`TimeshareReport::dead_frac`], which counts
    /// a batch's whole makespan (refill included) as busy.
    ///
    /// [`TimeshareReport::dead_frac`]: crate::sim::TimeshareReport::dead_frac
    pub dead_frac: f64,
}

/// One tenant's full-board solo allocation plus its DES calibration.
struct SoloTenant {
    alloc: Arc<Allocation>,
    report: Arc<AllocReport>,
    /// Dead cycles to swap this tenant's region in.
    reconfig: u64,
    /// Exact batch makespans for 1..=calib frames (prefix property of
    /// [`crate::sim::SimReport::frame_done`]).
    frame_done: Vec<u64>,
    /// Conservative steady beat: the largest completion gap observed.
    beat: u64,
}

impl SoloTenant {
    /// Over-approximate DES makespan of an `n`-frame batch: exact inside
    /// the calibration window, max-gap extrapolation beyond it.
    fn est_makespan(&self, n: usize) -> u64 {
        match n {
            0 => 0,
            n if n <= self.frame_done.len() => self.frame_done[n - 1],
            n => {
                self.frame_done[self.frame_done.len() - 1]
                    + (n - self.frame_done.len()) as u64 * self.beat
            }
        }
    }

    /// Largest batch whose estimated makespan, after the reconfiguration
    /// swap, fits a `slice`-cycle provision (capped at `max_frames`).
    fn admit(&self, slice: u64, max_frames: usize) -> usize {
        let budget = slice.saturating_sub(self.reconfig);
        if budget < self.frame_done[0] {
            return 0;
        }
        let last = self.frame_done[self.frame_done.len() - 1];
        let n = if budget < last {
            self.frame_done.iter().take_while(|&&m| m <= budget).count()
        } else {
            self.frame_done.len() + ((budget - last) / self.beat) as usize
        };
        let n = n.min(max_frames);
        // Admission invariant: the batch's (over-approximated) makespan
        // fits the post-reconfiguration budget.
        debug_assert!(n == 0 || self.est_makespan(n) <= budget);
        n
    }
}

/// Build each tenant's full-board allocation and calibrate its pipeline
/// with a short solo DES run. `Ok(None)` means the temporal regime is
/// infeasible for this tenant set (some tenant's pipeline does not fit the
/// board even alone).
fn solo_tenants(sh: &Sharder, tables: &[NetTables]) -> crate::Result<Option<Vec<SoloTenant>>> {
    let n = sh.tenants.len();
    let mut solos = Vec::with_capacity(n);
    for (i, t) in sh.tenants.iter().enumerate() {
        let Ok(alloc) =
            FlexAllocator::default().allocate_with(&t.net, &sh.board, t.mode, &tables[i])
        else {
            return Ok(None);
        };
        let report = alloc.evaluate();
        if report.dsps > sh.board.dsps || report.bram18 > sh.board.bram18() {
            return Ok(None);
        }
        let calib = sim::simulate(&alloc, sh.calib_frames.max(2));
        let beat = calib
            .frame_done
            .windows(2)
            .map(|w| w[1] - w[0])
            .max()
            .unwrap_or(1)
            .max(1);
        // A lone tenant never switches, so it pays no reconfiguration.
        let reconfig = if n == 1 {
            0
        } else {
            sh.reconfig.cycles(&report, sh.board.freq_hz)
        };
        solos.push(SoloTenant {
            alloc: Arc::new(alloc),
            report: Arc::new(report),
            reconfig,
            frame_done: calib.frame_done,
            beat,
        });
    }
    Ok(Some(solos))
}

/// Enumerate the temporal plan space for a sharder: slice quantum
/// (halvings of the period bound) × slice compositions, each scored by the
/// analytic schedule. Returns an empty vec when the regime is infeasible
/// (a tenant's full-board pipeline doesn't fit, or no composition gives
/// every tenant at least one frame per period).
pub(crate) fn temporal_plans(
    sh: &Sharder,
    tables: &[NetTables],
) -> crate::Result<Vec<ShardPlan>> {
    let n = sh.tenants.len();
    let Some(solos) = solo_tenants(sh, tables)? else {
        return Ok(vec![]);
    };
    let tenant_alloc = |s: &SoloTenant| TenantAlloc {
        // Each tenant owns the whole board during its slice.
        dsp_parts: sh.steps,
        bram_parts: sh.steps,
        alloc: Arc::clone(&s.alloc),
        report: Arc::clone(&s.report),
    };

    // Degenerate single-tenant schedule: continuous solo operation at the
    // closed-form fps — bit-identical to the plain FlexAllocator.
    if n == 1 {
        let fps = solos[0].report.fps;
        return Ok(vec![ShardPlan {
            tenants: vec![tenant_alloc(&solos[0])],
            fps: vec![fps],
            min_fps: fps,
            weighted_fps: fps * sh.tenants[0].weight,
            sim: None,
            regime: Regime::Temporal(TemporalInfo {
                time_parts: vec![sh.steps],
                quantum_cycles: 0,
                period_cycles: 0,
                frames: vec![0],
                reconfig_cycles: vec![0],
                fill_cycles: vec![solos[0].frame_done[0]],
                beat_cycles: vec![solos[0].beat],
                dead_frac: 0.0,
            }),
        }]);
    }

    anyhow::ensure!(
        sh.max_period_s > 0.0,
        "shard: temporal schedule needs max_period_s > 0"
    );
    // Same explosion guard as the spatial path: the plan space is
    // C(steps−1, n−1) compositions × 4 quanta, and the frontier reduction
    // downstream is O(plans²) — fail fast with guidance instead of
    // grinding for hours at fine granularity.
    let space = binomial(sh.steps - 1, n - 1).saturating_mul(4);
    anyhow::ensure!(
        space <= 50_000,
        "shard: temporal plan space too large ({space} candidate schedules for {n} \
         tenants at {} steps) — lower `steps` (e.g. `--shard-steps {}`)",
        sh.steps,
        suggest_steps(n),
    );
    let freq = sh.board.freq_hz;
    let q_max = ((sh.max_period_s * freq / sh.steps as f64) as u64).max(1);
    // Quantum candidates: halvings of the period bound. Longer periods
    // amortize reconfiguration better, but floor effects (whole frames per
    // slice) keep shorter quanta occasionally non-dominated — the frontier
    // reduction decides.
    let mut quanta: Vec<u64> = (0..4).map(|i| q_max >> i).filter(|&q| q > 0).collect();
    quanta.dedup();

    let comps = compositions(sh.steps, n);
    let mut plans: Vec<ShardPlan> = Vec::new();
    for &quantum in &quanta {
        let period = quantum * sh.steps as u64;
        for comp in &comps {
            let frames: Vec<usize> = comp
                .iter()
                .zip(&solos)
                .map(|(&parts, s)| s.admit(parts as u64 * quantum, sh.max_slice_frames))
                .collect();
            // Every tenant must make progress each period.
            if frames.iter().any(|&f| f == 0) {
                continue;
            }
            let fps: Vec<f64> = frames
                .iter()
                .map(|&f| f as f64 * freq / period as f64)
                .collect();
            // Dedup: a shorter quantum often lands on the same per-tenant
            // frame rates; keep the first (largest-quantum) representative.
            if plans.iter().any(|p| {
                p.fps.len() == fps.len()
                    && p.fps.iter().zip(&fps).all(|(a, b)| a.to_bits() == b.to_bits())
            }) {
                continue;
            }
            let min_fps = fps.iter().copied().fold(f64::INFINITY, f64::min);
            let weighted_fps = fps
                .iter()
                .zip(&sh.tenants)
                .map(|(f, t)| f * t.weight)
                .sum();
            let beats: Vec<u64> = solos.iter().map(|s| s.beat).collect();
            let useful: u64 = frames
                .iter()
                .zip(&beats)
                .map(|(&f, &b)| f as u64 * b)
                .sum();
            plans.push(ShardPlan {
                tenants: solos.iter().map(tenant_alloc).collect(),
                fps,
                min_fps,
                weighted_fps,
                sim: None,
                regime: Regime::Temporal(TemporalInfo {
                    time_parts: comp.clone(),
                    quantum_cycles: quantum,
                    period_cycles: period,
                    frames,
                    reconfig_cycles: solos.iter().map(|s| s.reconfig).collect(),
                    fill_cycles: solos.iter().map(|s| s.frame_done[0]).collect(),
                    beat_cycles: beats,
                    dead_frac: 1.0 - useful.min(period) as f64 / period as f64,
                }),
            });
        }
    }
    Ok(plans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zc706;
    use crate::model::zoo;
    use crate::quant::QuantMode;
    use crate::shard::Tenant;

    #[test]
    fn reconfig_model_calibration_matches_full_device() {
        // A region covering the whole ZC706 fabric should cost about the
        // device's 13 MB full bitstream.
        let m = ReconfigModel::default();
        let full = AllocReport {
            t_frame_cycles: 1,
            bottleneck: 0,
            fps: 0.0,
            gops: 0.0,
            mults: 900,
            dsps: 900,
            dsp_efficiency: 0.0,
            bram18: 1090,
            luts: 218_600,
            ffs: 437_200,
            ddr_bytes_per_sec: 0.0,
            ddr_demand_bytes_per_sec: 0.0,
            stage_cycles: vec![],
        };
        let mb = m.bitstream_bytes(&full) / 1e6;
        assert!((10.0..16.0).contains(&mb), "full-device estimate {mb:.1} MB");
        // ≈13 MB at 145 MB/s is ~90 ms; at 200 MHz that is ~1.8e7 cycles.
        let cyc = m.cycles(&full, 200e6);
        assert!((1.0e7..2.5e7).contains(&(cyc as f64)), "{cyc} cycles");
        // The zero model really is free.
        assert_eq!(ReconfigModel::zero().cycles(&full, 200e6), 0);
    }

    #[test]
    fn reconfig_grows_with_footprint() {
        let m = ReconfigModel::default();
        let mut small = AllocReport {
            t_frame_cycles: 1,
            bottleneck: 0,
            fps: 0.0,
            gops: 0.0,
            mults: 0,
            dsps: 32,
            dsp_efficiency: 0.0,
            bram18: 40,
            luts: 10_000,
            ffs: 0,
            ddr_bytes_per_sec: 0.0,
            ddr_demand_bytes_per_sec: 0.0,
            stage_cycles: vec![],
        };
        let s = m.seconds(&small);
        small.luts *= 4;
        small.bram18 *= 4;
        small.dsps *= 4;
        assert!(m.seconds(&small) > s);
    }

    #[test]
    fn admission_is_exact_in_window_and_monotone() {
        let solo = SoloTenant {
            alloc: Arc::new(
                FlexAllocator::default()
                    .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
                    .unwrap(),
            ),
            report: Arc::new(
                FlexAllocator::default()
                    .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
                    .unwrap()
                    .evaluate(),
            ),
            reconfig: 100,
            frame_done: vec![1_000, 1_800, 2_600, 3_400],
            beat: 800,
        };
        assert_eq!(solo.admit(1_099, usize::MAX), 0); // budget 999 < fill
        assert_eq!(solo.admit(1_100, usize::MAX), 1);
        assert_eq!(solo.admit(2_699, usize::MAX), 2); // budget 2599 < 2600
        assert_eq!(solo.admit(2_700, usize::MAX), 3);
        // Beyond the window: max-gap extrapolation.
        assert_eq!(solo.admit(3_500, usize::MAX), 4);
        assert_eq!(solo.admit(3_500 + 800, usize::MAX), 5);
        assert_eq!(solo.admit(3_500 + 1_599, usize::MAX), 5);
        // Cap applies.
        assert_eq!(solo.admit(1_000_000, 7), 7);
        // est_makespan is exact inside the window, linear past it.
        assert_eq!(solo.est_makespan(0), 0);
        assert_eq!(solo.est_makespan(3), 2_600);
        assert_eq!(solo.est_makespan(6), 3_400 + 2 * 800);
        // Monotone in the slice budget.
        let mut prev = 0;
        for slice in (0..20_000).step_by(137) {
            let n = solo.admit(slice, usize::MAX);
            assert!(n >= prev);
            prev = n;
        }
    }

    #[test]
    fn temporal_plans_respect_the_latency_bound() {
        let sh = Sharder {
            steps: 4,
            max_period_s: 0.1,
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                ],
            )
        };
        let tables: Vec<NetTables> =
            sh.tenants.iter().map(|t| NetTables::build(&t.net)).collect();
        let plans = temporal_plans(&sh, &tables).unwrap();
        assert!(!plans.is_empty());
        let bound = (0.1 * sh.board.freq_hz) as u64;
        for p in &plans {
            let Regime::Temporal(info) = &p.regime else {
                panic!("temporal planner emitted a spatial plan")
            };
            assert!(info.period_cycles <= bound, "{} > {bound}", info.period_cycles);
            assert_eq!(info.time_parts.iter().sum::<usize>(), sh.steps);
            assert_eq!(info.period_cycles, info.quantum_cycles * sh.steps as u64);
            assert!(info.frames.iter().all(|&f| f >= 1));
            assert!((0.0..1.0).contains(&info.dead_frac));
        }
    }
}
