//! Time-multiplexed sharding: give each tenant the *whole* board in turn.
//!
//! Spatial sharding ([`crate::shard`]) keeps every tenant resident at once,
//! but the paper's layer-wise pipeline only clears its >90% DSP-efficiency
//! band when a tenant holds enough multipliers to balance its stages —
//! small slices starve (the single-engine/multi-CLP trade-off of the
//! partitioning literature). This module is the other regime: each tenant
//! runs its **full-board** Sec. 4 allocation inside a time slice of a
//! cyclic schedule, paying a partial-reconfiguration cost at every switch.
//! Per-tenant fps vectors are directly comparable across the regimes, so
//! [`crate::shard::Sharder::search`] merges the plan sets into one Pareto
//! frontier (`--schedule auto`).
//!
//! # The schedule
//!
//! A period of `steps` quanta is cut into per-tenant slices by the same
//! composition machinery the spatial axis uses, and — new in the
//! latency-aware planner — a tenant's quanta may be **interleaved** as
//! `k > 1` sub-slices spread round-robin across the period
//! (`--interleave`). A sub-slice executes: *drain* (the previous tenant's
//! pipeline empties) → *reconfigure* ([`ReconfigModel`]: partial-bitstream
//! bytes derived from the incoming tenant's LUT/DSP/BRAM footprint, loaded
//! through the configuration port) → *refill + run* (the tenant's pipeline
//! fills and processes its admitted batch). Reconfiguration is
//! **drain-overlapped**: once the outgoing tenant's input-side stages go
//! idle ([`crate::sim::SimReport::input_done`]), their region can be
//! rewritten while the remaining stages drain, so only
//! `max(0, reconfig − predecessor's drain)` is charged as dead time
//! (zero-depth pipelines have no drain window and degenerate to the PR-3
//! serial cost — regression-tested). Throughput still favors long, whole
//! slices (dead time amortizes, and every extra sub-slice pays another
//! swap); **latency** favors interleaving: a tenant's worst-case frame
//! sojourn is bounded by its largest start-to-start gap plus one charged
//! swap plus one batch makespan, and `k` sub-slices cut the gap roughly
//! `k`-fold. Per-tenant latency SLOs ([`crate::shard::Tenant::slo_s`],
//! `--slo vgg16=33ms`) turn that bound into an admission constraint: a
//! tenant infeasible under one-slice-per-period planning can become
//! admissible with `k > 1` (acceptance-tested). The planner sweeps the
//! quantum over halvings of the period bound
//! ([`crate::shard::Sharder::max_period_s`]) together with all slice
//! compositions and per-tenant interleave factors and lets the frontier
//! reduction — now over (fps ↑, worst-case latency ↓) vectors — pick.
//! Sub-slice order within a round follows the caller's tenant order;
//! interleaving, not rotation, is the planner's ordering lever (a pure
//! rotation changes neither gaps nor, for equal drains, overlap credits).
//!
//! # Sharing regimes
//!
//! Three regimes feed the merged frontier:
//!
//! - **Spatial** ([`crate::shard`]): disjoint (Θ, α) slices, all tenants
//!   resident, no switching.
//! - **Temporal** (this module): full-board allocations, partial
//!   reconfiguration per switch, drain-overlapped.
//! - **Overlay** (`--overlay`): all tenants share one synthesized
//!   static-region superset datapath, so a switch reprograms *state*, not
//!   fabric — the [`ReconfigModel::zero`] limit. The only switch cost is
//!   re-streaming the incoming tenant's weights, which the DES already
//!   bills through its group-0 weight service (each batch's first group
//!   pays the weight-buffer fill), so overlay slices charge zero
//!   reconfiguration dead cycles. The static region is sized at the
//!   element-wise maximum of the tenants' footprints — the optimistic
//!   full-reuse bound, checked against the board.
//!
//! # Analytic schedule vs. simulated confirmation
//!
//! Admission (how many frames fit a sub-slice) is decided analytically
//! from a one-time DES calibration of each tenant's solo pipeline: the
//! exact makespans of the first `calib` frames plus a conservative
//! (max-gap) steady-state beat for extrapolation — conservative because
//! the completion-time prefix property ([`SimReport::frame_done`]) makes
//! over-estimating a batch's makespan safe (idle tail) while
//! under-estimating would stretch the period. Debug builds *spot-check*
//! that conservativeness against a slightly longer solo run instead of
//! assuming it outright (and `tests/slo_props.rs` property-tests it out
//! to 12 frames); drift beyond the probed horizon still surfaces as DES
//! `overrun` / below-analytic fps in validation. The drain-overlap credit
//! is likewise conservative: the planner credits the smallest drain
//! observed in the calibration window (under-crediting idles the port;
//! over-crediting would stretch the period). The sharder's validation
//! pass then *executes* frontier schedules with the crate-private
//! `sim::simulate_schedule` engine — drain-overlapped reconfiguration,
//! dead cycles charged — and the acceptance tests pin the simulated
//! per-tenant fps within 1% and the measured worst-case sojourn within 5%
//! of the analytic schedule.
//!
//! [`SimReport::frame_done`]: crate::sim::SimReport::frame_done

use crate::alloc::flex::{FlexAllocator, NetTables};
use crate::alloc::{AllocReport, Allocation};
use crate::shard::{binomial, compositions, suggest_steps, Regime, ShardPlan, Sharder, TenantAlloc};
use crate::sim;
use std::sync::Arc;

/// Partial-reconfiguration cost model: configuration bytes proportional to
/// the fabric footprint of the incoming tenant's region, loaded through
/// the configuration port.
///
/// The per-resource byte weights are calibrated so a region covering a
/// full XC7Z045 (ZC706: 218.6k LUTs, 900 DSPs, 1090 BRAM18) costs ≈13 MB
/// — that device's full-bitstream size — and the default port rate is the
/// Zynq-7000 PCAP's ≈145 MB/s, giving ≈60–90 ms for a VGG16-sized region.
/// Weight preloads are deliberately *not* billed here: the DES already
/// charges each pipeline's first weight-buffer fill per slice (the
/// group-0 weight service in [`crate::sim`]), so adding them would double
/// count the DDR side of a swap.
#[derive(Debug, Clone)]
pub struct ReconfigModel {
    /// Configuration bytes per LUT in the region.
    pub bytes_per_lut: f64,
    /// Configuration bytes per DSP slice.
    pub bytes_per_dsp: f64,
    /// Configuration bytes per BRAM18 (frame config + content init).
    pub bytes_per_bram18: f64,
    /// Fixed per-swap overhead in bytes (headers, region clearing, port
    /// setup).
    pub base_bytes: f64,
    /// Configuration port throughput in bytes/second (PCAP ≈145 MB/s;
    /// ICAP ≈400 MB/s).
    pub port_bytes_per_sec: f64,
    /// Synthesis overhead factor for the static-region overlay: the
    /// shared superset datapath is sized at `overlay_overhead ×` the
    /// element-wise maximum of the tenants' DSP/BRAM footprints before
    /// the board-fit check. `1.0` (the default, calibrated to the pinned
    /// PR-4 overlay invariants) is the optimistic full-reuse bound —
    /// every tenant's engines fold perfectly into the superset; real
    /// overlays pay muxing/packing logic, so calibrate ≥ 1.0 against
    /// synthesis reports (values below 1.0 are rejected at search time).
    /// Scaling only gates overlay *feasibility*: an admitted overlay's
    /// schedule and rates are unchanged.
    pub overlay_overhead: f64,
}

impl Default for ReconfigModel {
    fn default() -> Self {
        ReconfigModel {
            bytes_per_lut: 45.0,
            bytes_per_dsp: 600.0,
            bytes_per_bram18: 2_304.0,
            base_bytes: 65_536.0,
            port_bytes_per_sec: 145e6,
            overlay_overhead: 1.0,
        }
    }
}

impl ReconfigModel {
    /// Free reconfiguration: the limit where tenants share one overlay and
    /// a swap is pure state — what the overlay regime models structurally
    /// (also what the temporal-vs-spatial dominance property tests pin
    /// down).
    pub fn zero() -> ReconfigModel {
        ReconfigModel {
            bytes_per_lut: 0.0,
            bytes_per_dsp: 0.0,
            bytes_per_bram18: 0.0,
            base_bytes: 0.0,
            ..Default::default()
        }
    }

    /// Partial-bitstream bytes for the region a tenant's allocation
    /// occupies.
    pub fn bitstream_bytes(&self, r: &AllocReport) -> f64 {
        self.base_bytes
            + self.bytes_per_lut * r.luts as f64
            + self.bytes_per_dsp * r.dsps as f64
            + self.bytes_per_bram18 * r.bram18 as f64
    }

    /// Seconds to swap the tenant's region in.
    pub fn seconds(&self, r: &AllocReport) -> f64 {
        self.bitstream_bytes(r) / self.port_bytes_per_sec
    }

    /// Dead cycles at the board clock (`freq_hz` in Hz).
    pub fn cycles(&self, r: &AllocReport, freq_hz: f64) -> u64 {
        (self.seconds(r) * freq_hz).ceil() as u64
    }
}

/// One sub-slice of a temporal schedule, in period order — the planner's
/// counterpart of [`crate::sim::ScheduleSlice`] (cycles there, quanta
/// here).
#[derive(Debug, Clone)]
pub struct SliceSpec {
    /// Tenant served (index into the sharder's tenant list).
    pub tenant: usize,
    /// Quanta this sub-slice holds (its length is `parts · quantum`).
    pub parts: usize,
    /// Frames the analytic schedule admits into this sub-slice.
    pub frames: usize,
    /// Full partial-bitstream swap cost in cycles (0 when no fabric swap
    /// happens: overlay plans, lone tenants, or a cyclic predecessor
    /// serving the same tenant).
    pub reconfig_cycles: u64,
    /// Cycles of that swap the planner credits to the predecessor's drain
    /// window; the dead cycles charged are
    /// `reconfig_cycles − overlap_cycles`.
    pub overlap_cycles: u64,
}

impl SliceSpec {
    /// Dead cycles actually charged at this sub-slice's start boundary
    /// (`reconfig_cycles − overlap_cycles`): the window between the
    /// slice's start and the first cycle its tenant's pipeline can ingest
    /// a frame. The ingestion dispatcher ([`crate::ingest`]) charges this
    /// before draining the tenant's queue, mirroring the analytic sojourn
    /// bound term by term.
    pub fn charged_cycles(&self) -> u64 {
        self.reconfig_cycles - self.overlap_cycles
    }
}

/// The temporal half of a [`ShardPlan`]: how the period is cut and what
/// the analytic schedule admits.
///
/// A lone tenant degenerates to continuous solo operation (no switches, no
/// reconfiguration): `period_cycles == 0` marks that case and the plan's
/// fps is the closed-form solo fps, bit-identical to the plain
/// [`FlexAllocator`] (property-tested).
#[derive(Debug, Clone)]
pub struct TemporalInfo {
    /// Per-tenant time quanta per period (out of the sharder's `steps`),
    /// summed over all of a tenant's sub-slices.
    pub time_parts: Vec<usize>,
    /// Sub-slices per tenant per period (`1` = the PR-3 whole-slice
    /// layout; `k > 1` spreads the tenant's quanta round-robin).
    pub interleave: Vec<usize>,
    /// The schedule itself: every sub-slice in period order.
    pub slices: Vec<SliceSpec>,
    /// Slice quantum in cycles.
    pub quantum_cycles: u64,
    /// Schedule period in cycles (`steps · quantum`).
    pub period_cycles: u64,
    /// Frames the analytic schedule admits per tenant per period (summed
    /// over the tenant's sub-slices).
    pub frames: Vec<usize>,
    /// Modeled full swap cost per tenant in cycles (before drain-overlap
    /// credit; the per-sub-slice charge lives in [`SliceSpec`]).
    pub reconfig_cycles: Vec<u64>,
    /// Calibrated first-frame latency (pipeline refill) per tenant, in
    /// cycles.
    pub fill_cycles: Vec<u64>,
    /// Calibrated steady-state beat per tenant in cycles (max completion
    /// gap — the conservative extrapolation base).
    pub beat_cycles: Vec<u64>,
    /// Analytic worst-case frame sojourn per tenant, in cycles: the
    /// largest start-to-start gap between the tenant's consecutive
    /// sub-slices plus the next sub-slice's charged reconfiguration plus
    /// its batch's (over-approximated) makespan. What `--slo` admissions
    /// check, and what [`crate::sim::TimeshareReport::worst_sojourn`]
    /// confirms within 5%.
    pub latency_cycles: Vec<u64>,
    /// Is this an overlay-regime plan (shared static-region superset
    /// datapath, zero reconfiguration)?
    pub overlay: bool,
    /// Fraction of the period not covered by steady-state frame beats
    /// (reconfiguration + refill + idle tails), analytic. Stricter than
    /// the executed-schedule [`TimeshareReport::dead_frac`], which counts
    /// a batch's whole makespan (refill included) as busy.
    ///
    /// [`TimeshareReport::dead_frac`]: crate::sim::TimeshareReport::dead_frac
    pub dead_frac: f64,
}

impl TemporalInfo {
    /// The executable form of this schedule: one
    /// [`crate::sim::ScheduleSlice`] per sub-slice, in period order —
    /// exactly what the schedule-execution engine behind
    /// [`crate::sim::Simulate`] consumes. The single source of the
    /// planner→simulator slice conversion (the validation pass, the
    /// benches, and the acceptance tests all go through here).
    pub fn schedule_slices(&self) -> Vec<crate::sim::ScheduleSlice> {
        self.slices
            .iter()
            .map(|s| crate::sim::ScheduleSlice {
                tenant: s.tenant,
                frames: s.frames,
                slice_cycles: s.parts as u64 * self.quantum_cycles,
                reconfig_cycles: s.reconfig_cycles,
            })
            .collect()
    }

    /// Start offset of every sub-slice within the planned period, in
    /// cycles (the running sum of `parts × quantum` — the *planned*
    /// timeline the analytic sojourn bound is computed on, before any
    /// executed-schedule overrun). Indexed like [`TemporalInfo::slices`].
    /// The slice-aware ingestion dispatcher ([`crate::ingest`]) maps
    /// arrival times onto these boundaries; for the degenerate solo
    /// schedule (`period_cycles == 0`) the single start is `0`.
    pub fn slice_starts(&self) -> Vec<u64> {
        self.slices
            .iter()
            .scan(0u64, |cum, s| {
                let here = *cum;
                *cum += s.parts as u64 * self.quantum_cycles;
                Some(here)
            })
            .collect()
    }

    /// Slice-admissible queue depth for `tenant`: the smallest admitted
    /// frame count over the tenant's sub-slices. Bounding a tenant's
    /// waiting requests at this depth guarantees the queue fully drains
    /// at the tenant's *next* sub-slice occurrence, which is exactly the
    /// single-gap premise of the analytic [`TemporalInfo::latency_cycles`]
    /// bound — it is the default admission capacity of the ingestion
    /// layer. `None` when the schedule admits no frames for the tenant
    /// (the degenerate solo schedule, or an index the schedule does not
    /// serve).
    pub fn slice_admissible_depth(&self, tenant: usize) -> Option<usize> {
        self.slices
            .iter()
            .filter(|s| s.tenant == tenant && s.frames > 0)
            .map(|s| s.frames)
            .min()
    }
}

/// One tenant's full-board solo allocation plus its DES calibration.
/// Built once per search by [`solo_tenants`] and shared by the temporal
/// and overlay enumerations (`--schedule auto` calibrates once, not per
/// regime).
pub(crate) struct SoloTenant {
    alloc: Arc<Allocation>,
    report: Arc<AllocReport>,
    /// Full dead cycles to swap this tenant's region in (before any
    /// drain-overlap credit).
    reconfig: u64,
    /// Exact batch makespans for 1..=calib frames (prefix property of
    /// [`crate::sim::SimReport::frame_done`]).
    frame_done: Vec<u64>,
    /// Conservative steady beat: the largest completion gap observed.
    beat: u64,
    /// Conservative drain-overlap credit: the *smallest* drain tail
    /// (`frame_done − input_done`) observed in the calibration window.
    /// Under-crediting only idles the configuration port; over-crediting
    /// would stretch the period.
    drain_min: u64,
}

impl SoloTenant {
    /// Over-approximate DES makespan of an `n`-frame batch: exact inside
    /// the calibration window, max-gap extrapolation beyond it.
    fn est_makespan(&self, n: usize) -> u64 {
        match n {
            0 => 0,
            n if n <= self.frame_done.len() => self.frame_done[n - 1],
            n => {
                self.frame_done[self.frame_done.len() - 1]
                    + (n - self.frame_done.len()) as u64 * self.beat
            }
        }
    }

    /// Largest batch whose estimated makespan, after `reconfig` charged
    /// swap cycles, fits a `slice`-cycle provision (capped at
    /// `max_frames`).
    fn admit(&self, slice: u64, reconfig: u64, max_frames: usize) -> usize {
        let budget = slice.saturating_sub(reconfig);
        if budget < self.frame_done[0] {
            return 0;
        }
        let last = self.frame_done[self.frame_done.len() - 1];
        let n = if budget < last {
            self.frame_done.iter().take_while(|&&m| m <= budget).count()
        } else {
            self.frame_done.len() + ((budget - last) / self.beat) as usize
        };
        let n = n.min(max_frames);
        // Admission invariant: the batch's (over-approximated) makespan
        // fits the post-reconfiguration budget.
        debug_assert!(n == 0 || self.est_makespan(n) <= budget);
        n
    }

    /// Debug-build spot-check of the calibration's core assumptions,
    /// which the admission arithmetic otherwise takes on faith: (a) the
    /// max-gap beat extrapolated past the window never undershoots a
    /// longer solo run's true makespans, and (b) the drain-overlap
    /// credit's symmetric claim — no later batch's drain tail dips below
    /// the window's minimum (the DES charges the *actual* predecessor
    /// drain, so a dip would charge more swap than the planner budgeted).
    ///
    /// This probes a window + 2 horizon (and `tests/slo_props.rs`
    /// property-tests the same claims out to 12 frames) — a smoke test
    /// that catches broken calibration cheaply, **not** a proof over the
    /// full `max_slice_frames` extrapolation range. Longer-horizon drift
    /// is not silent either: it surfaces as slice `overrun` / below-
    /// analytic fps in the DES validation pass.
    #[cfg(debug_assertions)]
    fn assert_extrapolation_conservative(&self, alloc: &Allocation) {
        let long = sim::simulate(alloc, self.frame_done.len() + 2);
        for n in 1..=long.frame_done.len() {
            debug_assert!(
                self.est_makespan(n) >= long.frame_done[n - 1],
                "max-gap extrapolation undershoots at n={n}: est {} < true {}",
                self.est_makespan(n),
                long.frame_done[n - 1]
            );
        }
        for (n, (f, i)) in long.frame_done.iter().zip(&long.input_done).enumerate() {
            debug_assert!(
                f - i >= self.drain_min,
                "drain tail dips below the calibrated credit at n={}: {} < {}",
                n + 1,
                f - i,
                self.drain_min
            );
        }
    }
}

/// Build each tenant's full-board allocation and calibrate its pipeline
/// with a short solo DES run. `Ok(None)` means the temporal regime is
/// infeasible for this tenant set (some tenant's pipeline does not fit the
/// board even alone). The calibration DES dominates temporal planning
/// cost, so [`crate::shard::Sharder::search`] runs this once and hands
/// the result to every regime enumeration.
/// Conservative drain-overlap credit of one calibrated batch: the
/// smallest `frame_done − input_done` tail observed — the window in which
/// the pipeline's input-side stages are already idle and its region can
/// be rewritten while the rest drains. Taking the minimum over the whole
/// batch keeps the credit safe for any admitted frame count.
pub(crate) fn min_drain_tail(r: &sim::SimReport) -> u64 {
    r.frame_done
        .iter()
        .zip(&r.input_done)
        .map(|(&f, &i)| f - i)
        .min()
        .unwrap_or(0)
}

/// Measure one pipeline's drain-overlap credit in cycles with a short
/// (`window_frames`, minimum 2) solo DES run — the same conservative
/// minimum-over-window rule the temporal planner calibrates admission
/// with. This is the cost model behind a [`crate::fault::PlanDiff`]'s
/// reconfiguration sequence: swapping a region in can hide up to this
/// many cycles under the *outgoing* pipeline's drain.
pub fn drain_credit(alloc: &Allocation, window_frames: usize) -> u64 {
    min_drain_tail(&sim::simulate(alloc, window_frames.max(2)))
}

pub(crate) fn solo_tenants(
    sh: &Sharder,
    tables: &[NetTables],
) -> crate::Result<Option<Vec<SoloTenant>>> {
    let n = sh.tenants.len();
    let mut solos = Vec::with_capacity(n);
    for (i, t) in sh.tenants.iter().enumerate() {
        let Ok(alloc) =
            FlexAllocator::default().allocate_with(&t.net, &sh.board, t.mode, &tables[i])
        else {
            return Ok(None);
        };
        let report = alloc.evaluate();
        if report.dsps > sh.board.dsps || report.bram18 > sh.board.bram18() {
            return Ok(None);
        }
        let calib = sim::simulate(&alloc, sh.calib_frames.max(2));
        let beat = calib
            .frame_done
            .windows(2)
            .map(|w| w[1] - w[0])
            .max()
            .unwrap_or(1)
            .max(1);
        let drain_min = min_drain_tail(&calib);
        // A lone tenant never switches, so it pays no reconfiguration.
        let reconfig = if n == 1 {
            0
        } else {
            sh.reconfig.cycles(&report, sh.board.freq_hz)
        };
        let solo = SoloTenant {
            alloc: Arc::new(alloc),
            report: Arc::new(report),
            reconfig,
            frame_done: calib.frame_done,
            beat,
            drain_min,
        };
        #[cfg(debug_assertions)]
        solo.assert_extrapolation_conservative(&solo.alloc);
        solos.push(solo);
    }
    Ok(Some(solos))
}

/// Spread each tenant's quanta over `ks[i]` sub-slices by **target
/// phase**: sub-slice `j` of tenant `i` aims at period fraction
/// `(j + i/n) / ks[i]`, and sub-slices execute in target order (ties:
/// earlier sub-slice index first, then tenant order). This interleaves a
/// `k`-sliced tenant's sub-slices *between* the other tenants' blocks —
/// the property that actually shrinks its start-to-start gaps (a
/// round-robin that clusters all whole-slice tenants into one run would
/// leave one near-period gap). Chunk sizes are near-equal splits of the
/// tenant's quanta, larger chunks first; all-ones `ks` reproduces the
/// PR-3 one-slice-per-tenant caller-order layout exactly. Phases are
/// compared as exact rationals (`(j·n + i) / (n·ks[i])`), so the layout
/// is deterministic.
fn interleave_layout(comp: &[usize], ks: &[usize]) -> Vec<(usize, usize)> {
    let n = comp.len();
    // (numerator, denominator, sub-slice index, tenant, parts).
    let mut subs: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
    for i in 0..n {
        let k = ks[i];
        for j in 0..k {
            let parts = comp[i] / k + usize::from(j < comp[i] % k);
            subs.push((j * n + i, n * k, j, i, parts));
        }
    }
    subs.sort_by(|a, b| {
        (a.0 * b.1)
            .cmp(&(b.0 * a.1))
            .then(a.2.cmp(&b.2))
            .then(a.3.cmp(&b.3))
    });
    let seq: Vec<(usize, usize)> = subs
        .into_iter()
        .map(|(_, _, _, i, parts)| (i, parts))
        .collect();
    debug_assert_eq!(
        seq.iter().map(|&(_, p)| p).sum::<usize>(),
        comp.iter().sum::<usize>(),
        "interleaved chunks must partition the composition"
    );
    seq
}

/// Every per-tenant interleave vector with `1 ≤ k_i ≤ min(max_k,
/// comp[i])` (each sub-slice needs at least one quantum), lowest factors
/// first so the dedup keeps the simplest representative of equal plans.
fn interleave_choices(comp: &[usize], max_k: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![Vec::new()];
    for &p in comp {
        let cap = max_k.max(1).min(p);
        out = out
            .into_iter()
            .flat_map(|v| {
                (1..=cap).map(move |k| {
                    let mut w = v.clone();
                    w.push(k);
                    w
                })
            })
            .collect();
    }
    out
}

/// Enumerate the temporal (or, with `overlay`, the static-region overlay)
/// plan space for a sharder over the calibrated [`solo_tenants`]: slice
/// quantum (halvings of the period bound) × slice compositions ×
/// per-tenant interleave factors, each scored by the analytic schedule
/// and filtered against the tenants' latency SLOs. Survivors are appended
/// to the caller's shared plan list and offered to the shared incremental
/// frontier; nothing is appended when the regime is infeasible (no
/// composition gives every sub-slice at least one frame per period, or no
/// SLO-satisfying schedule exists).
///
/// An always-on **exact** skip retires a (quantum, composition) pair
/// before touching its interleave layouts when some tenant admits zero
/// frames even into its *undivided, reconfiguration-free* slice
/// (admission is monotone in the cycle budget, every sub-slice's budget
/// is smaller, and charged swap cycles only shrink it further — so every
/// layout of that pair would have failed the progress check). With
/// [`Sharder::prune`] set, [`temporal_bound_prunes`] additionally applies
/// the branch-and-bound frontier test to the pair's admissible bound
/// vector.
pub(crate) fn temporal_plans(
    sh: &Sharder,
    solos: &[SoloTenant],
    overlay: bool,
    plans: &mut Vec<ShardPlan>,
    merge: &mut crate::shard::FrontierMerge,
    stats: &mut crate::shard::ShardStats,
) -> crate::Result<()> {
    let n = sh.tenants.len();
    // Objective-duplicate scan window: this call's appended range only —
    // the regimes' plan lists must not dedup against each other (a
    // temporal plan landing on a spatial plan's objective point is still
    // a distinct plan in the exhaustive listing).
    let base = plans.len();
    let freq = sh.board.freq_hz;
    let tenant_alloc = |s: &SoloTenant| TenantAlloc {
        // Each tenant owns the whole board during its slice.
        dsp_parts: sh.steps,
        bram_parts: sh.steps,
        alloc: Arc::clone(&s.alloc),
        report: Arc::clone(&s.report),
    };

    if overlay {
        // A lone tenant has nothing to share an overlay with; the plain
        // temporal degenerate covers that case.
        if n == 1 {
            return Ok(());
        }
        // The static region hosts the superset datapath: size it at the
        // element-wise maximum of the tenants' footprints scaled by the
        // configurable synthesis overhead ([`ReconfigModel::
        // overlay_overhead`]; 1.0 = the optimistic full-reuse bound,
        // under which the check is trivially true whenever every tenant
        // fits alone) and check it fits the board.
        let oh = sh.reconfig.overlay_overhead;
        let max_dsps = solos.iter().map(|s| s.report.dsps).max().unwrap_or(0);
        let max_bram = solos.iter().map(|s| s.report.bram18).max().unwrap_or(0);
        let need_dsps = (max_dsps as f64 * oh).ceil() as usize;
        let need_bram = (max_bram as f64 * oh).ceil() as usize;
        if need_dsps > sh.board.dsps || need_bram > sh.board.bram18() {
            return Ok(());
        }
    }

    // Degenerate single-tenant schedule: continuous solo operation at the
    // closed-form fps — bit-identical to the plain FlexAllocator. Worst
    // sojourn: a frame arriving just after the previous one's ingest waits
    // one beat, then traverses the full pipeline.
    if n == 1 {
        let fps = solos[0].report.fps;
        let latency = solos[0].frame_done[0] + solos[0].beat;
        if let Some(slo) = sh.tenants[0].slo_s {
            if latency as f64 > slo * freq {
                return Ok(());
            }
        }
        if sh.tenants[0].min_fps.is_some_and(|floor| fps < floor) {
            return Ok(());
        }
        plans.push(ShardPlan {
            tenants: vec![tenant_alloc(&solos[0])],
            fps: vec![fps],
            min_fps: fps,
            weighted_fps: fps * sh.tenants[0].weight,
            latency_s: vec![latency as f64 / freq],
            sim: None,
            regime: Regime::Temporal(TemporalInfo {
                time_parts: vec![sh.steps],
                interleave: vec![1],
                slices: vec![SliceSpec {
                    tenant: 0,
                    parts: sh.steps,
                    frames: 0,
                    reconfig_cycles: 0,
                    overlap_cycles: 0,
                }],
                quantum_cycles: 0,
                period_cycles: 0,
                frames: vec![0],
                reconfig_cycles: vec![0],
                fill_cycles: vec![solos[0].frame_done[0]],
                beat_cycles: vec![solos[0].beat],
                latency_cycles: vec![latency],
                overlay: false,
                dead_frac: 0.0,
            }),
        });
        merge.offer(plans, plans.len() - 1);
        return Ok(());
    }

    anyhow::ensure!(
        sh.max_period_s > 0.0,
        "shard: temporal schedule needs max_period_s > 0"
    );
    // Same explosion guard as the spatial path: the plan space is
    // C(steps−1, n−1) compositions × 4 quanta × interleave choices, and
    // the frontier reduction downstream is O(plans²) — fail fast with
    // guidance instead of grinding for hours at fine granularity.
    let k_pow = sh.max_interleave.max(1).saturating_pow(n as u32);
    let space = binomial(sh.steps - 1, n - 1)
        .saturating_mul(4)
        .saturating_mul(k_pow);
    anyhow::ensure!(
        space <= 50_000,
        "shard: temporal plan space too large ({space} candidate schedules for {n} \
         tenants at {} steps, interleave ≤ {}) — lower `steps` (e.g. `--shard-steps {}`) \
         or `--interleave`",
        sh.steps,
        sh.max_interleave.max(1),
        suggest_steps(n),
    );
    let q_max = ((sh.max_period_s * freq / sh.steps as f64) as u64).max(1);
    // Quantum candidates: halvings of the period bound. Longer periods
    // amortize reconfiguration better, but floor effects (whole frames per
    // slice) and the latency axis (shorter periods bound sojourn tighter)
    // keep shorter quanta non-dominated — the frontier reduction decides.
    let mut quanta: Vec<u64> = (0..4).map(|i| q_max >> i).filter(|&q| q > 0).collect();
    quanta.dedup();

    let comps = compositions(sh.steps, n);
    for &quantum in &quanta {
        let period = quantum * sh.steps as u64;
        for comp in &comps {
            let n_layouts: usize = comp
                .iter()
                .map(|&p| sh.max_interleave.max(1).min(p))
                .product();
            stats.lattice_nodes += n_layouts;
            // Always-on zero-admission skip (exact — see the function
            // docs): the whole undivided slice with no swap charge is the
            // most any layout can offer a tenant.
            let full_admit: Vec<usize> = (0..n)
                .map(|t| solos[t].admit(comp[t] as u64 * quantum, 0, sh.max_slice_frames))
                .collect();
            if full_admit.iter().any(|&f| f == 0) {
                stats.pruned_nodes += n_layouts;
                continue;
            }
            if sh.prune
                && temporal_bound_prunes(sh, solos, comp, &full_admit, period, plans, merge)
            {
                stats.pruned_nodes += n_layouts;
                stats.bound_skipped += n_layouts;
                continue;
            }
            for ks in interleave_choices(comp, sh.max_interleave) {
                let layout = interleave_layout(comp, &ks);
                let m = layout.len();
                // Per-sub-slice reconfiguration (drain-overlapped) and
                // admission; every sub-slice must make progress.
                let mut slices: Vec<SliceSpec> = Vec::with_capacity(m);
                for (j, &(t, parts)) in layout.iter().enumerate() {
                    let prev_t = layout[(j + m - 1) % m].0;
                    let rc = if overlay || prev_t == t {
                        0
                    } else {
                        solos[t].reconfig
                    };
                    let overlap = rc.min(solos[prev_t].drain_min);
                    let frames = solos[t].admit(
                        parts as u64 * quantum,
                        rc - overlap,
                        sh.max_slice_frames,
                    );
                    if frames == 0 {
                        break;
                    }
                    slices.push(SliceSpec {
                        tenant: t,
                        parts,
                        frames,
                        reconfig_cycles: rc,
                        overlap_cycles: overlap,
                    });
                }
                if slices.len() != m {
                    continue;
                }

                // Analytic worst-case sojourn per tenant: largest
                // start-to-start gap to the tenant's next sub-slice, plus
                // that sub-slice's charged swap and batch makespan.
                let starts: Vec<u64> = slices
                    .iter()
                    .scan(0u64, |cum, s| {
                        let here = *cum;
                        *cum += s.parts as u64 * quantum;
                        Some(here)
                    })
                    .collect();
                let mut latency_cycles = vec![0u64; n];
                for t in 0..n {
                    let js: Vec<usize> =
                        (0..m).filter(|&j| slices[j].tenant == t).collect();
                    for (a, &j_from) in js.iter().enumerate() {
                        let j_to = js[(a + 1) % js.len()];
                        let gap = if starts[j_to] > starts[j_from] {
                            starts[j_to] - starts[j_from]
                        } else {
                            period - starts[j_from] + starts[j_to]
                        };
                        let served = slices[j_to].reconfig_cycles
                            - slices[j_to].overlap_cycles
                            + solos[t].est_makespan(slices[j_to].frames);
                        latency_cycles[t] = latency_cycles[t].max(gap + served);
                    }
                }
                // SLO admission: drop schedules that violate any tenant's
                // worst-case sojourn bound.
                if sh.tenants.iter().zip(&latency_cycles).any(|(t, &lat)| {
                    t.slo_s.is_some_and(|slo| lat as f64 > slo * freq)
                }) {
                    continue;
                }

                let mut frames = vec![0usize; n];
                for s in &slices {
                    frames[s.tenant] += s.frames;
                }
                let fps: Vec<f64> = frames
                    .iter()
                    .map(|&f| f as f64 * freq / period as f64)
                    .collect();
                // Per-tenant fps floors are admission constraints like the
                // SLOs: drop schedules starving any floored tenant.
                if !crate::shard::meets_floors(&sh.tenants, &fps) {
                    continue;
                }
                let latency_s: Vec<f64> =
                    latency_cycles.iter().map(|&c| c as f64 / freq).collect();
                // Dedup on the full objective vector: a shorter quantum or
                // higher interleave often lands on the same (fps, latency)
                // point; keep the first (largest-quantum, lowest-k)
                // representative. Scan only this call's appended range —
                // see `base` above.
                if plans[base..].iter().any(|p| {
                    p.fps.len() == fps.len()
                        && p.fps.iter().zip(&fps).all(|(a, b)| a.to_bits() == b.to_bits())
                        && p.latency_s
                            .iter()
                            .zip(&latency_s)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                }) {
                    continue;
                }
                let min_fps = fps.iter().copied().fold(f64::INFINITY, f64::min);
                let weighted_fps = fps
                    .iter()
                    .zip(&sh.tenants)
                    .map(|(f, t)| f * t.weight)
                    .sum();
                let beats: Vec<u64> = solos.iter().map(|s| s.beat).collect();
                let useful: u64 = frames
                    .iter()
                    .zip(&beats)
                    .map(|(&f, &b)| f as u64 * b)
                    .sum();
                plans.push(ShardPlan {
                    tenants: solos.iter().map(tenant_alloc).collect(),
                    fps,
                    min_fps,
                    weighted_fps,
                    latency_s,
                    sim: None,
                    regime: Regime::Temporal(TemporalInfo {
                        time_parts: comp.clone(),
                        interleave: ks,
                        slices,
                        quantum_cycles: quantum,
                        period_cycles: period,
                        frames,
                        // Overlay switches reprogram state, not fabric:
                        // the per-tenant modeled swap cost is zero there,
                        // matching every slice's zero charge.
                        reconfig_cycles: solos
                            .iter()
                            .map(|s| if overlay { 0 } else { s.reconfig })
                            .collect(),
                        fill_cycles: solos.iter().map(|s| s.frame_done[0]).collect(),
                        beat_cycles: beats,
                        latency_cycles,
                        overlay,
                        dead_frac: 1.0 - useful.min(period) as f64 / period as f64,
                    }),
                });
                merge.offer(plans, plans.len() - 1);
            }
        }
    }
    Ok(())
}

/// The temporal branch-and-bound test behind [`Sharder::prune`]: an
/// admissible per-tenant *(fps upper bound, latency lower bound)* for
/// every schedule in one (quantum, composition) subtree.
///
/// Admissibility: a tenant with `comp[t]` quanta gets at most
/// `k_cap = min(max_interleave, comp[t])` sub-slices, each no larger than
/// its undivided slice and each paying a non-negative swap charge, so its
/// period frame total is at most `k_cap · admit(comp[t]·quantum, 0, ·)`
/// (admission is monotone in the budget — makespans are *not*
/// subadditive, so the per-sub-slice bound must be multiplied out, never
/// split). On the latency axis, `k` sub-slices leave some start-to-start
/// gap of at least `period / k ≥ period / k_cap`, and the serving
/// sub-slice charges at least one frame fill — a sojourn floor no layout
/// of the pair can beat. A subtree whose bound vector violates a floor or
/// SLO contains no admissible schedule; one weakly dominated by an
/// incumbent frontier plan contains only plans the tie-deduplicating
/// frontier would reject.
fn temporal_bound_prunes(
    sh: &Sharder,
    solos: &[SoloTenant],
    comp: &[usize],
    full_admit: &[usize],
    period: u64,
    plans: &[ShardPlan],
    merge: &crate::shard::FrontierMerge,
) -> bool {
    let freq = sh.board.freq_hz;
    let n = comp.len();
    let mut fps_ub = Vec::with_capacity(n);
    let mut lat_lb = Vec::with_capacity(n);
    for t in 0..n {
        let k_cap = sh.max_interleave.max(1).min(comp[t]) as u64;
        let ub = (k_cap as usize * full_admit[t]) as f64 * freq / period as f64;
        let lb = (period / k_cap + solos[t].frame_done[0]) as f64 / freq;
        if sh.tenants[t].min_fps.is_some_and(|floor| ub < floor) {
            return true;
        }
        if sh.tenants[t].slo_s.is_some_and(|slo| lb > slo) {
            return true;
        }
        fps_ub.push(ub);
        lat_lb.push(lb);
    }
    merge.members().iter().any(|&k| {
        crate::shard::vec_weakly_dominates(&plans[k].fps, &plans[k].latency_s, &fps_ub, &lat_lb)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zc706;
    use crate::model::zoo;
    use crate::quant::QuantMode;
    use crate::shard::Tenant;

    #[test]
    fn reconfig_model_calibration_matches_full_device() {
        // A region covering the whole ZC706 fabric should cost about the
        // device's 13 MB full bitstream.
        let m = ReconfigModel::default();
        let full = AllocReport {
            t_frame_cycles: 1,
            bottleneck: 0,
            fps: 0.0,
            gops: 0.0,
            mults: 900,
            dsps: 900,
            dsp_efficiency: 0.0,
            bram18: 1090,
            luts: 218_600,
            ffs: 437_200,
            ddr_bytes_per_sec: 0.0,
            ddr_demand_bytes_per_sec: 0.0,
            stage_cycles: vec![],
        };
        let mb = m.bitstream_bytes(&full) / 1e6;
        assert!((10.0..16.0).contains(&mb), "full-device estimate {mb:.1} MB");
        // ≈13 MB at 145 MB/s is ~90 ms; at 200 MHz that is ~1.8e7 cycles.
        let cyc = m.cycles(&full, 200e6);
        assert!((1.0e7..2.5e7).contains(&(cyc as f64)), "{cyc} cycles");
        // The zero model really is free.
        assert_eq!(ReconfigModel::zero().cycles(&full, 200e6), 0);
    }

    #[test]
    fn reconfig_grows_with_footprint() {
        let m = ReconfigModel::default();
        let mut small = AllocReport {
            t_frame_cycles: 1,
            bottleneck: 0,
            fps: 0.0,
            gops: 0.0,
            mults: 0,
            dsps: 32,
            dsp_efficiency: 0.0,
            bram18: 40,
            luts: 10_000,
            ffs: 0,
            ddr_bytes_per_sec: 0.0,
            ddr_demand_bytes_per_sec: 0.0,
            stage_cycles: vec![],
        };
        let s = m.seconds(&small);
        small.luts *= 4;
        small.bram18 *= 4;
        small.dsps *= 4;
        assert!(m.seconds(&small) > s);
    }

    fn lenet_solo() -> SoloTenant {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let report = alloc.evaluate();
        SoloTenant {
            alloc: Arc::new(alloc),
            report: Arc::new(report),
            reconfig: 100,
            frame_done: vec![1_000, 1_800, 2_600, 3_400],
            beat: 800,
            drain_min: 100,
        }
    }

    #[test]
    fn admission_is_exact_in_window_and_monotone() {
        let solo = lenet_solo();
        let rc = solo.reconfig;
        assert_eq!(solo.admit(1_099, rc, usize::MAX), 0); // budget 999 < fill
        assert_eq!(solo.admit(1_100, rc, usize::MAX), 1);
        assert_eq!(solo.admit(2_699, rc, usize::MAX), 2); // budget 2599 < 2600
        assert_eq!(solo.admit(2_700, rc, usize::MAX), 3);
        // Beyond the window: max-gap extrapolation.
        assert_eq!(solo.admit(3_500, rc, usize::MAX), 4);
        assert_eq!(solo.admit(3_500 + 800, rc, usize::MAX), 5);
        assert_eq!(solo.admit(3_500 + 1_599, rc, usize::MAX), 5);
        // Cap applies.
        assert_eq!(solo.admit(1_000_000, rc, 7), 7);
        // A drain-overlap credit widens the budget: charging less swap
        // admits no fewer frames.
        assert_eq!(solo.admit(1_099, 0, usize::MAX), 1);
        // est_makespan is exact inside the window, linear past it.
        assert_eq!(solo.est_makespan(0), 0);
        assert_eq!(solo.est_makespan(3), 2_600);
        assert_eq!(solo.est_makespan(6), 3_400 + 2 * 800);
        // Monotone in the slice budget.
        let mut prev = 0;
        for slice in (0..20_000).step_by(137) {
            let n = solo.admit(slice, rc, usize::MAX);
            assert!(n >= prev);
            prev = n;
        }
    }

    #[test]
    fn interleave_layout_spreads_chunks_evenly() {
        // k = 1 everywhere reproduces the PR-3 whole-slice layout.
        assert_eq!(
            interleave_layout(&[3, 5], &[1, 1]),
            vec![(0, 3), (1, 5)]
        );
        // A 2-way interleave splits the tenant's quanta into near-equal
        // chunks, larger first, with the whole-slice tenant between them.
        assert_eq!(
            interleave_layout(&[3, 5], &[2, 1]),
            vec![(0, 2), (1, 5), (0, 1)]
        );
        assert_eq!(
            interleave_layout(&[2, 2], &[2, 2]),
            vec![(0, 1), (1, 1), (0, 1), (1, 1)]
        );
        // Uneven interleave factors stay phase-spread.
        assert_eq!(
            interleave_layout(&[4, 2], &[4, 2]),
            vec![(0, 1), (1, 1), (0, 1), (0, 1), (1, 1), (0, 1)]
        );
        // Three tenants, first interleaved: its sub-slices land *between*
        // the other tenants' blocks (A B A C), never clustered — this is
        // what halves the start-to-start gap.
        assert_eq!(
            interleave_layout(&[2, 1, 3], &[2, 1, 1]),
            vec![(0, 1), (1, 1), (0, 1), (2, 3)]
        );
        assert_eq!(
            interleave_layout(&[2, 3, 3], &[2, 1, 1]),
            vec![(0, 1), (1, 3), (0, 1), (2, 3)]
        );
        // Choices respect the per-tenant quanta cap.
        let choices = interleave_choices(&[1, 3], 4);
        assert!(choices.contains(&vec![1, 1]));
        assert!(choices.contains(&vec![1, 3]));
        assert!(choices.iter().all(|ks| ks[0] == 1 && ks[1] <= 3));
        assert_eq!(choices.len(), 3);
    }

    fn run_temporal(sh: &Sharder, solos: &[SoloTenant], overlay: bool) -> Vec<ShardPlan> {
        let mut plans = Vec::new();
        let mut merge = crate::shard::FrontierMerge::default();
        let mut stats = crate::shard::ShardStats::default();
        temporal_plans(sh, solos, overlay, &mut plans, &mut merge, &mut stats).unwrap();
        plans
    }

    #[test]
    fn temporal_plans_respect_the_latency_bound() {
        let sh = Sharder {
            steps: 4,
            max_period_s: 0.1,
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                ],
            )
        };
        let tables: Vec<NetTables> =
            sh.tenants.iter().map(|t| NetTables::build(&t.net)).collect();
        let solos = solo_tenants(&sh, &tables).unwrap().expect("tenants fit solo");
        let plans = run_temporal(&sh, &solos, false);
        assert!(!plans.is_empty());
        let bound = (0.1 * sh.board.freq_hz) as u64;
        for p in &plans {
            let Regime::Temporal(info) = &p.regime else {
                panic!("temporal planner emitted a spatial plan")
            };
            assert!(info.period_cycles <= bound, "{} > {bound}", info.period_cycles);
            assert_eq!(info.time_parts.iter().sum::<usize>(), sh.steps);
            assert_eq!(info.period_cycles, info.quantum_cycles * sh.steps as u64);
            assert!(info.frames.iter().all(|&f| f >= 1));
            assert!((0.0..1.0).contains(&info.dead_frac));
            assert!(!info.overlay);
            // The sub-slice sequence is coherent with the per-tenant
            // totals, and the worst-case sojourn never beats one period
            // plus a batch (a tenant is served once per gap).
            assert_eq!(
                info.slices.iter().map(|s| s.parts).sum::<usize>(),
                sh.steps
            );
            for t in 0..2 {
                let total: usize = info
                    .slices
                    .iter()
                    .filter(|s| s.tenant == t)
                    .map(|s| s.frames)
                    .sum();
                assert_eq!(total, info.frames[t]);
                assert!(info.latency_cycles[t] > 0);
                assert_eq!(p.latency_s[t], info.latency_cycles[t] as f64 / sh.board.freq_hz);
            }
            // Drain-overlap credits never exceed the modeled swap.
            for s in &info.slices {
                assert!(s.overlap_cycles <= s.reconfig_cycles);
            }
        }
    }

    #[test]
    fn overlay_overhead_gates_feasibility_and_unity_reproduces_default() {
        let mk = |overhead: f64| Sharder {
            steps: 4,
            schedule: crate::shard::ScheduleMode::Overlay,
            max_period_s: 0.1,
            reconfig: ReconfigModel {
                overlay_overhead: overhead,
                ..ReconfigModel::default()
            },
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                ],
            )
        };
        // overhead = 1.0 (the optimistic element-wise-max bound) must be
        // bit-identical to the default model — the PR-4 behaviour.
        let unity = mk(1.0).search().unwrap();
        let default = Sharder {
            reconfig: ReconfigModel::default(),
            ..mk(1.0)
        }
        .search()
        .unwrap();
        assert_eq!(unity.plans.len(), default.plans.len());
        assert_eq!(unity.frontier, default.frontier);
        for (a, b) in unity.plans.iter().zip(&default.plans) {
            for (x, y) in a.fps.iter().zip(&b.fps) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.latency_s.iter().zip(&b.latency_s) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // A huge overhead makes the superset datapath exceed the board:
        // the overlay regime becomes infeasible (search reports it).
        assert!(mk(1e6).search().is_err());
        // Overheads below the optimistic bound are rejected outright.
        let err = mk(0.5).search().unwrap_err();
        assert!(err.to_string().contains("overlay_overhead"), "{err}");
    }

    #[test]
    fn overlay_plans_charge_zero_reconfiguration() {
        let sh = Sharder {
            steps: 4,
            max_period_s: 0.1,
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                ],
            )
        };
        let tables: Vec<NetTables> =
            sh.tenants.iter().map(|t| NetTables::build(&t.net)).collect();
        let solos = solo_tenants(&sh, &tables).unwrap().expect("tenants fit solo");
        let plans = run_temporal(&sh, &solos, true);
        assert!(!plans.is_empty());
        for p in &plans {
            let Regime::Temporal(info) = &p.regime else {
                panic!("overlay planner emitted a spatial plan")
            };
            assert!(info.overlay);
            assert!(info.slices.iter().all(|s| s.reconfig_cycles == 0));
            assert!(info.slices.iter().all(|s| s.overlap_cycles == 0));
        }
        // An overlay schedule with the same shape never admits fewer
        // frames than the reconfiguring one (zero swap can only widen
        // budgets).
        let plain = run_temporal(&sh, &solos, false);
        let best_overlay = plans
            .iter()
            .map(|p| p.min_fps)
            .fold(f64::NEG_INFINITY, f64::max);
        let best_plain = plain
            .iter()
            .map(|p| p.min_fps)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(best_overlay >= best_plain);
    }
}
