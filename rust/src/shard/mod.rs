//! Multi-tenant board sharding: one physical FPGA serving several
//! co-resident models.
//!
//! # Relation to the paper (Sec. 4)
//!
//! The paper's framework answers "what is the *balanced* flexible pipeline
//! for one model on one board?": Algorithm 1 splits the multiplier budget
//! Θ across the model's layers proportionally to workload, Algorithm 2
//! trades the BRAM budget α against the DDR bandwidth β. This module lifts
//! the same question one level up — *the board itself becomes the resource
//! being allocated*. Each tenant model receives a slice of the physical
//! (Θ, α, β) and instantiates its own flexible pipeline inside that slice
//! with the unmodified Sec. 4 machinery:
//!
//! - **Θ (DSPs)** is partitioned in `1/steps` quanta; a tenant's quantum
//!   count also scales its LUT/FF caps and its DDR bandwidth share (compute
//!   rate is what generates traffic, so β follows Θ — the share Algorithm 2
//!   balances each tenant's pipeline against).
//! - **α (BRAM)** gets an *independent* split axis: a model's buffer
//!   footprint is set by its feature-map geometry, not its compute share
//!   (VGG16 needs ~⅔ of a ZC706's BRAM18 at 16-bit whether it holds 25% or
//!   100% of the DSPs), so tying the two axes together would forfeit most
//!   of the interesting co-residence points.
//!
//! The split space is searched exhaustively at the configured granularity.
//! Per split, every tenant runs Algorithm 1 + Algorithm 2 on its sub-board
//! — warm-started by sharing each model's decomposition staircases
//! ([`NetTables`], which depend only on layer dimensions) across *all*
//! candidate splits — and infeasible splits (a tenant's pipeline cannot fit
//! its DSP or BRAM slice) are discarded. Feasible splits are reduced to the
//! Pareto frontier of per-tenant fps vectors, alongside two scalarized
//! picks: max–min fps (egalitarian) and weighted-sum fps (SLA-weighted).
//! Frontier winners are optionally validated by the multi-pipeline
//! discrete-event simulation ([`crate::sim::simulate_multi_provisioned`]),
//! which runs every tenant's event wheel against the *shared* physical DDR
//! port at the provisioned per-tenant shares — the same β split each
//! tenant's Algorithm 2 run was budgeted against.
//!
//! Consumed by the `flexipipe shard` CLI subcommand, the
//! `search::DesignSpace::sweep_shards` axis, the `design_space` example,
//! and `benches/shard.rs`.
//!
//! # Regimes
//!
//! Spatial co-residence (this module's split search) is one of two ways to
//! share a board. [`schedule`] implements the other — **time
//! multiplexing**: each tenant runs its full-board allocation in a slice
//! of a cyclic schedule, paying a partial-reconfiguration cost per switch.
//! [`Sharder::search`] enumerates either or both ([`ScheduleMode`]) and
//! merges the plan sets into one Pareto frontier: per-tenant fps vectors
//! are directly comparable across regimes, so a spatial plan beaten by a
//! temporal plan (or vice versa) drops off the merged frontier.

pub mod schedule;

pub use schedule::{ReconfigModel, TemporalInfo};

use crate::alloc::flex::{FlexAllocator, NetTables};
use crate::alloc::{AllocReport, Allocation};
use crate::board::Board;
use crate::model::Network;
use crate::quant::QuantMode;
use crate::sim::{self, SimReport};
use crate::util::json::{num, obj, Value};
use std::sync::Arc;

/// One co-resident workload: a model, its precision, and its weight in the
/// weighted-fps objective.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub net: Network,
    pub mode: QuantMode,
    /// Relative importance in the weighted-fps objective (default 1.0).
    pub weight: f64,
}

impl Tenant {
    /// Tenant with unit weight.
    pub fn new(net: Network, mode: QuantMode) -> Tenant {
        Tenant {
            net,
            mode,
            weight: 1.0,
        }
    }
}

/// The sub-board a tenant receives: `dsp_parts/steps` of the compute-side
/// resources (DSPs, LUTs, FFs, DDR bandwidth) and `bram_parts/steps` of
/// the BRAM. Integer quanta, so `parts == steps` reproduces the physical
/// board exactly — the anchor of the single-tenant bit-identity invariant.
pub fn sub_board(board: &Board, dsp_parts: usize, bram_parts: usize, steps: usize) -> Board {
    Board {
        name: board.name.clone(),
        dsps: board.dsps * dsp_parts / steps,
        luts: board.luts * dsp_parts / steps,
        ffs: board.ffs * dsp_parts / steps,
        bram36: board.bram36 * bram_parts / steps,
        ddr_bytes_per_sec: board.ddr_bytes_per_sec * (dsp_parts as f64 / steps as f64),
        freq_hz: board.freq_hz,
    }
}

/// All ways to hand `steps` quanta to `n` tenants, each receiving at least
/// one — `C(steps−1, n−1)` compositions, enumerated in lexicographic order
/// (deterministic, so plan indices are stable across runs).
pub fn compositions(steps: usize, n: usize) -> Vec<Vec<usize>> {
    fn rec(out: &mut Vec<Vec<usize>>, cur: &mut Vec<usize>, i: usize, left: usize) {
        let n = cur.len();
        if i == n - 1 {
            cur[i] = left;
            out.push(cur.clone());
            return;
        }
        // Leave at least one quantum for each remaining tenant.
        for p in 1..=(left - (n - 1 - i)) {
            cur[i] = p;
            rec(out, cur, i + 1, left - p);
        }
    }
    assert!(n >= 1 && steps >= n, "need at least one quantum per tenant");
    let mut out = Vec::new();
    rec(&mut out, &mut vec![0usize; n], 0, steps);
    out
}

/// Which plans [`Sharder::search`] enumerates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Spatial co-residence only (the PR-2 behaviour; the default).
    Spatial,
    /// Time multiplexing only.
    Temporal,
    /// Both regimes, merged into one Pareto frontier.
    Auto,
}

impl ScheduleMode {
    /// CLI/report label.
    pub fn label(&self) -> &'static str {
        match self {
            ScheduleMode::Spatial => "spatial",
            ScheduleMode::Temporal => "temporal",
            ScheduleMode::Auto => "auto",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "spatial" => Ok(ScheduleMode::Spatial),
            "temporal" | "time" => Ok(ScheduleMode::Temporal),
            "auto" | "both" => Ok(ScheduleMode::Auto),
            other => anyhow::bail!("unknown schedule '{other}' (spatial temporal auto)"),
        }
    }
}

/// Which resource-division regime produced a plan.
#[derive(Debug, Clone)]
pub enum Regime {
    /// Spatial co-residence: tenants hold disjoint (Θ, α) slices at once.
    Spatial,
    /// Time multiplexing: each tenant runs its full-board pipeline in a
    /// slice of the schedule period ([`schedule`]).
    Temporal(TemporalInfo),
}

impl Regime {
    /// Report label.
    pub fn label(&self) -> &'static str {
        match self {
            Regime::Spatial => "spatial",
            Regime::Temporal(_) => "temporal",
        }
    }

    /// Is this a time-multiplexed plan?
    pub fn is_temporal(&self) -> bool {
        matches!(self, Regime::Temporal(_))
    }
}

/// One tenant's slice of a [`ShardPlan`].
#[derive(Debug, Clone)]
pub struct TenantAlloc {
    /// DSP-side quanta this tenant holds (`dsp_parts/steps` of Θ/LUT/FF/β).
    pub dsp_parts: usize,
    /// BRAM quanta this tenant holds (`bram_parts/steps` of α).
    pub bram_parts: usize,
    /// The tenant's flexible pipeline on its sub-board. Shared (`Arc`)
    /// across every plan that gives this tenant the same slice — the
    /// per-tenant allocation depends only on its own (dsp, bram) quanta,
    /// never on how the remainder is divided among the others.
    pub alloc: Arc<Allocation>,
    /// Closed-form report for that pipeline.
    pub report: Arc<AllocReport>,
}

/// One feasible plan: a spatial split of the board, or one temporal
/// schedule of it (see [`Regime`]).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Per-tenant slices, in the sharder's tenant order. For temporal
    /// plans every tenant holds the whole board (`parts == steps`) during
    /// its time slice.
    pub tenants: Vec<TenantAlloc>,
    /// Per-tenant effective fps (closed-form for spatial plans, analytic
    /// schedule for temporal ones — same order as `tenants`).
    pub fps: Vec<f64>,
    /// `min_i fps_i` — the egalitarian objective.
    pub min_fps: f64,
    /// `Σ_i weight_i · fps_i` — the SLA-weighted objective.
    pub weighted_fps: f64,
    /// DES confirmation, one report per tenant (frontier plans only, when
    /// `sim_frames > 0`): the shared-port multi-pipeline wheel for spatial
    /// plans, [`sim::simulate_timeshared`] for temporal ones (fps is the
    /// effective over-the-period rate).
    pub sim: Option<Vec<SimReport>>,
    /// Which regime produced this plan.
    pub regime: Regime,
}

/// The searched split space for one board + tenant set.
#[derive(Debug, Clone)]
pub struct Sharder {
    /// The physical board being shared.
    pub board: Board,
    /// Co-resident workloads.
    pub tenants: Vec<Tenant>,
    /// Split granularity: resources move between tenants in `1/steps`
    /// quanta. Default 16 — fine enough to separate VGG16-class BRAM
    /// footprints from AlexNet-class ones, coarse enough that a two-tenant
    /// search is a few hundred allocator runs.
    pub steps: usize,
    /// Frames for the multi-pipeline DES validation of frontier plans
    /// (0 = closed-form only).
    pub sim_frames: usize,
    /// Which plan regimes to enumerate (spatial splits, temporal
    /// schedules, or both merged — default [`ScheduleMode::Spatial`]).
    pub schedule: ScheduleMode,
    /// Partial-reconfiguration cost model for temporal schedules.
    pub reconfig: ReconfigModel,
    /// Latency bound for temporal schedules: the cyclic period never
    /// exceeds this many seconds (a tenant waits at most one period
    /// between slices). Longer periods amortize reconfiguration dead time
    /// better. Default 0.5 s.
    pub max_period_s: f64,
    /// Solo DES frames used to calibrate each tenant's fill latency and
    /// steady beat for the analytic temporal schedule. Default 6. The
    /// max-gap extrapolation assumes the window sees the pipeline's
    /// largest completion gap (true for steady-periodic pipelines — the
    /// shipped workloads settle within 2 frames, mirror-checked); raise
    /// this for pipelines whose gaps oscillate with a longer period.
    /// Mis-calibration is never silent: over-admitted slices surface as
    /// DES `overrun` / below-analytic fps in the validation pass.
    pub calib_frames: usize,
    /// Admission-control ceiling on frames per slice (bounds the queue
    /// depth a tenant needs and the DES validation cost for very fast
    /// models). Default 4096.
    pub max_slice_frames: usize,
}

/// Search output: every feasible plan plus the interesting subsets.
#[derive(Debug, Clone)]
pub struct ShardResult {
    /// All feasible plans, in deterministic enumeration order
    /// (DSP composition outer, BRAM composition inner, lexicographic).
    pub plans: Vec<ShardPlan>,
    /// Indices of the non-dominated per-tenant fps vectors.
    pub frontier: Vec<usize>,
    /// Index of the plan maximizing `min_fps` (first wins ties).
    pub best_min: usize,
    /// Index of the plan maximizing `weighted_fps` (first wins ties).
    pub best_weighted: usize,
}

impl Sharder {
    /// Sharder with default granularity, spatial regime, and no DES
    /// validation.
    pub fn new(board: Board, tenants: Vec<Tenant>) -> Sharder {
        Sharder {
            board,
            tenants,
            steps: 16,
            sim_frames: 0,
            schedule: ScheduleMode::Spatial,
            reconfig: ReconfigModel::default(),
            max_period_s: 0.5,
            calib_frames: 6,
            max_slice_frames: 4096,
        }
    }

    /// Enumerate the plan space of the selected regime(s) — spatial
    /// splits, temporal schedules, or both — keep the feasible plans,
    /// reduce the union to the per-tenant-fps Pareto frontier, and
    /// (optionally) confirm frontier plans with the matching DES
    /// (shared-port multi-pipeline wheel for spatial plans,
    /// [`sim::simulate_timeshared`] for temporal ones).
    pub fn search(&self) -> crate::Result<ShardResult> {
        let n = self.tenants.len();
        anyhow::ensure!(n >= 1, "shard: no tenants given");
        anyhow::ensure!(
            self.steps >= n,
            "shard: {} tenants need at least {} split steps (have {})",
            n,
            n,
            self.steps
        );
        for t in &self.tenants {
            t.net.validate()?;
        }

        // Shared precomputation: each model's decomposition staircases
        // depend only on its layer dimensions, so they are built once and
        // warm-start every allocator run of either regime.
        let tables: Vec<NetTables> = self.tenants.iter().map(|t| NetTables::build(&t.net)).collect();

        let mut plans: Vec<ShardPlan> = Vec::new();
        if self.schedule != ScheduleMode::Temporal {
            plans.extend(self.spatial_plans(&tables)?);
        }
        if self.schedule != ScheduleMode::Spatial {
            plans.extend(schedule::temporal_plans(self, &tables)?);
        }
        anyhow::ensure!(
            !plans.is_empty(),
            "shard: no feasible {} plan for {} across {} tenants at {} steps \
             (board too small for the tenant set — try fewer tenants, 8-bit \
             mode, `--schedule auto`, or a larger board)",
            self.schedule.label(),
            self.board.name,
            n,
            self.steps
        );

        let frontier = frontier(&plans);
        let best_min = argmax(&plans, |p| p.min_fps);
        let best_weighted = argmax(&plans, |p| p.weighted_fps);

        let mut result = ShardResult {
            plans,
            frontier,
            best_min,
            best_weighted,
        };
        if self.sim_frames > 0 {
            for idx in result.frontier.clone() {
                let sims = self.validate_plan(&result.plans[idx]);
                result.plans[idx].sim = Some(sims);
            }
        }
        Ok(result)
    }

    /// DES confirmation of one frontier plan, regime-matched.
    fn validate_plan(&self, plan: &ShardPlan) -> Vec<SimReport> {
        let refs: Vec<&Allocation> = plan.tenants.iter().map(|t| t.alloc.as_ref()).collect();
        match &plan.regime {
            // Validate against the *provisioned* port split (each tenant
            // gets the dsp_parts/steps of β its Algorithm 2 run was
            // budgeted), not the demand-converged split — the plan was
            // ranked on the former.
            Regime::Spatial => {
                let shares: Vec<f64> = plan
                    .tenants
                    .iter()
                    .map(|t| t.dsp_parts as f64 / self.steps as f64)
                    .collect();
                sim::simulate_multi_provisioned(&refs, &shares, &self.board, self.sim_frames)
            }
            // Degenerate single-tenant schedule: continuous solo run.
            Regime::Temporal(info) if info.period_cycles == 0 => {
                sim::simulate_multi_provisioned(&refs, &[1.0], &self.board, self.sim_frames)
            }
            // Execute one schedule period: drain → reconfigure → refill,
            // dead cycles charged. Per-tenant fps becomes the effective
            // over-the-period rate (analytic-schedule-comparable).
            Regime::Temporal(info) => {
                let slices: Vec<u64> = info
                    .time_parts
                    .iter()
                    .map(|&p| p as u64 * info.quantum_cycles)
                    .collect();
                let ts =
                    sim::simulate_timeshared(&refs, &info.frames, &slices, &info.reconfig_cycles);
                let period = ts.period_cycles;
                ts.slices
                    .into_iter()
                    .map(|s| {
                        let mut r = s.sim.expect("feasible temporal plans admit ≥1 frame");
                        // Re-base the batch report to the effective
                        // over-the-period view so the struct stays
                        // coherent: gops/dsp_efficiency are linear in fps,
                        // the port is only drawn during this slice's
                        // makespan, and fps == freq/cycles_per_frame again
                        // after both are rewritten. `makespan` keeps the
                        // slice's own execution window.
                        let rate = s.fps / r.fps;
                        r.gops *= rate;
                        r.dsp_efficiency *= rate;
                        r.ddr_utilization *= r.makespan as f64 / period as f64;
                        r.fps = s.fps;
                        r.cycles_per_frame = period as f64 / s.frames.max(1) as f64;
                        r
                    })
                    .collect()
            }
        }
    }

    /// Enumerate the spatial split space and keep the feasible plans (the
    /// PR-2 search, factored out of [`Sharder::search`]).
    fn spatial_plans(&self, tables: &[NetTables]) -> crate::Result<Vec<ShardPlan>> {
        let n = self.tenants.len();
        // The plan space is C(steps−1, n−1)² and the frontier reduction is
        // O(plans²): bound it so a 4-tenant run at fine granularity fails
        // fast with guidance instead of grinding for hours.
        let splits_per_axis = binomial(self.steps - 1, n - 1);
        let space = splits_per_axis.saturating_mul(splits_per_axis);
        anyhow::ensure!(
            space <= 50_000,
            "shard: split space too large ({splits_per_axis}² = {space} candidate plans for \
             {n} tenants at {} steps) — lower `steps` (e.g. `--shard-steps {}`)",
            self.steps,
            suggest_steps(n),
        );

        // A tenant's allocation depends only on its own slice, so the
        // split space factorizes: allocate each tenant once per
        // (dsp_parts, bram_parts) it can receive, then assemble plans by
        // table lookup. `None` = that slice is infeasible for the tenant.
        let max_parts = self.steps - (n - 1);
        let slot = |p: usize, q: usize| (p - 1) * max_parts + (q - 1);
        // Slice sizes any composition can actually hand out (a lone tenant
        // always gets the whole board — no point allocating the rest).
        let parts_range: Vec<usize> = if n == 1 {
            vec![self.steps]
        } else {
            (1..=max_parts).collect()
        };
        let mut cells: Vec<Vec<Option<TenantAlloc>>> = Vec::with_capacity(n);
        for (i, t) in self.tenants.iter().enumerate() {
            let mut row: Vec<Option<TenantAlloc>> = vec![None; max_parts * max_parts];
            for &p in &parts_range {
                for &q in &parts_range {
                    let sub = sub_board(&self.board, p, q, self.steps);
                    if sub.dsps == 0 || sub.bram36 == 0 {
                        continue;
                    }
                    let Ok(alloc) =
                        FlexAllocator::default().allocate_with(&t.net, &sub, t.mode, &tables[i])
                    else {
                        continue;
                    };
                    let report = alloc.evaluate();
                    // Feasible iff the pipeline fits the slice's Θ and α
                    // (the paper's partitioned budgets; LUT/FF are reported
                    // but interconnect-dominated, not partition-enforced).
                    if report.dsps > sub.dsps || report.bram18 > sub.bram18() {
                        continue;
                    }
                    row[slot(p, q)] = Some(TenantAlloc {
                        dsp_parts: p,
                        bram_parts: q,
                        alloc: Arc::new(alloc),
                        report: Arc::new(report),
                    });
                }
            }
            cells.push(row);
        }

        // Assemble: every (DSP composition × BRAM composition) whose
        // tenant cells all exist is a feasible plan.
        let dsp_splits = compositions(self.steps, n);
        let bram_splits = compositions(self.steps, n);
        let mut plans: Vec<ShardPlan> = Vec::new();
        for dsp in &dsp_splits {
            for bram in &bram_splits {
                let mut slices = Vec::with_capacity(n);
                for i in 0..n {
                    match &cells[i][slot(dsp[i], bram[i])] {
                        Some(cell) => slices.push(cell.clone()),
                        None => {
                            slices.clear();
                            break;
                        }
                    }
                }
                if slices.len() != n {
                    continue;
                }
                let fps: Vec<f64> = slices.iter().map(|s| s.report.fps).collect();
                let min_fps = fps.iter().copied().fold(f64::INFINITY, f64::min);
                let weighted_fps = fps
                    .iter()
                    .zip(&self.tenants)
                    .map(|(f, t)| f * t.weight)
                    .sum();
                plans.push(ShardPlan {
                    tenants: slices,
                    fps,
                    min_fps,
                    weighted_fps,
                    sim: None,
                    regime: Regime::Spatial,
                });
            }
        }
        Ok(plans)
    }
}

/// `C(n, k)` with saturation (plan-space sizing only).
pub(crate) fn binomial(n: usize, k: usize) -> usize {
    let k = k.min(n - k);
    let mut acc: usize = 1;
    for i in 0..k {
        acc = acc.saturating_mul(n - i) / (i + 1);
    }
    acc
}

/// Largest `steps` whose split space `C(steps−1, n−1)²` stays within the
/// search bound for `n` tenants (the error message's suggestion).
pub(crate) fn suggest_steps(n: usize) -> usize {
    if n <= 1 {
        return 64; // a lone tenant has one split at any granularity
    }
    let fits = |s: usize| {
        let b = binomial(s - 1, n - 1);
        b.saturating_mul(b) <= 50_000
    };
    let mut s = n;
    while s < 1024 && fits(s + 1) {
        s += 1;
    }
    s
}

/// `a` dominates `b` when it is ≥ on every tenant's fps and > on one —
/// the canonical predicate behind [`frontier`] (public so tests assert
/// against the same definition the search uses).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x >= y) && a.iter().zip(b).any(|(x, y)| x > y)
}

/// Indices of the non-dominated fps vectors.
pub fn frontier(plans: &[ShardPlan]) -> Vec<usize> {
    (0..plans.len())
        .filter(|&i| {
            !(0..plans.len()).any(|j| j != i && dominates(&plans[j].fps, &plans[i].fps))
        })
        .collect()
}

fn argmax(plans: &[ShardPlan], key: impl Fn(&ShardPlan) -> f64) -> usize {
    let mut best = 0;
    for i in 1..plans.len() {
        if key(&plans[i]) > key(&plans[best]) {
            best = i;
        }
    }
    best
}

/// JSON encoding of one plan: per-tenant allocation (slice sizes, resource
/// use, per-stage `(C', M', K)`) plus the objective values.
pub fn plan_to_json(plan: &ShardPlan) -> Value {
    let tenants: Vec<Value> = plan
        .tenants
        .iter()
        .zip(&plan.fps)
        .enumerate()
        .map(|(i, (t, &fps))| {
            let stages: Vec<Value> = t
                .alloc
                .stages
                .iter()
                .map(|s| {
                    obj(vec![
                        ("layer", Value::Str(t.alloc.net.layers[s.layer_idx].label())),
                        ("cp", num(s.cfg.cp)),
                        ("mp", num(s.cfg.mp)),
                        ("k", num(s.cfg.k)),
                    ])
                })
                .collect();
            let mut pairs = vec![
                ("model", Value::Str(t.alloc.net.name.clone())),
                ("bits", num(t.alloc.mode.bits())),
                ("dsp_parts", num(t.dsp_parts)),
                ("bram_parts", num(t.bram_parts)),
                ("dsps", num(t.report.dsps)),
                ("bram18", num(t.report.bram18)),
                ("fps", Value::Num(fps)),
                ("gops", Value::Num(t.report.gops)),
                ("stages", Value::Arr(stages)),
            ];
            if let Some(sims) = &plan.sim {
                pairs.push(("sim_fps", Value::Num(sims[i].fps)));
                pairs.push((
                    "sim_cycles_per_frame",
                    Value::Num(sims[i].cycles_per_frame),
                ));
            }
            obj(pairs)
        })
        .collect();
    let mut pairs = vec![
        ("schedule", Value::Str(plan.regime.label().to_string())),
        ("min_fps", Value::Num(plan.min_fps)),
        ("weighted_fps", Value::Num(plan.weighted_fps)),
        ("tenants", Value::Arr(tenants)),
    ];
    match &plan.regime {
        Regime::Spatial => {}
        // Degenerate lone-tenant schedule: continuous solo operation — the
        // slice/period numbers would be 0/0 noise, so mark it instead.
        Regime::Temporal(info) if info.period_cycles == 0 => {
            pairs.push(("continuous_solo", Value::Bool(true)));
        }
        Regime::Temporal(info) => {
            pairs.push((
                "time_parts",
                Value::Arr(info.time_parts.iter().map(|&p| num(p)).collect()),
            ));
            pairs.push(("quantum_cycles", Value::Num(info.quantum_cycles as f64)));
            pairs.push(("period_cycles", Value::Num(info.period_cycles as f64)));
            pairs.push((
                "frames_per_slice",
                Value::Arr(info.frames.iter().map(|&f| num(f)).collect()),
            ));
            pairs.push((
                "reconfig_cycles",
                Value::Arr(info.reconfig_cycles.iter().map(|&c| Value::Num(c as f64)).collect()),
            ));
            pairs.push(("dead_frac", Value::Num(info.dead_frac)));
        }
    }
    obj(pairs)
}

/// JSON encoding of a whole search: the frontier plans plus the two
/// scalarized picks (`flexipipe shard --json`).
pub fn result_to_json(r: &ShardResult, steps: usize) -> Value {
    obj(vec![
        ("steps", num(steps)),
        ("feasible_plans", num(r.plans.len())),
        (
            "frontier",
            Value::Arr(r.frontier.iter().map(|&i| plan_to_json(&r.plans[i])).collect()),
        ),
        ("best_min_fps", plan_to_json(&r.plans[r.best_min])),
        ("best_weighted_fps", plan_to_json(&r.plans[r.best_weighted])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;

    #[test]
    fn compositions_count_and_sum() {
        // C(steps-1, n-1): 2 tenants over 16 steps → 15 splits.
        let c = compositions(16, 2);
        assert_eq!(c.len(), 15);
        assert!(c.iter().all(|v| v.iter().sum::<usize>() == 16));
        assert!(c.iter().all(|v| v.iter().all(|&p| p >= 1)));
        assert_eq!(compositions(6, 3).len(), 10); // C(5,2)
        assert_eq!(compositions(4, 1), vec![vec![4]]);
    }

    #[test]
    fn sub_board_full_share_is_identity() {
        let b = zc706();
        let s = sub_board(&b, 16, 16, 16);
        assert_eq!(s, b);
    }

    #[test]
    fn sub_board_partitions_never_oversubscribe() {
        let b = zc706();
        for splits in compositions(16, 3) {
            let subs: Vec<Board> = splits.iter().map(|&p| sub_board(&b, p, p, 16)).collect();
            assert!(subs.iter().map(|s| s.dsps).sum::<usize>() <= b.dsps);
            assert!(subs.iter().map(|s| s.bram36).sum::<usize>() <= b.bram36);
            assert!(
                subs.iter().map(|s| s.ddr_bytes_per_sec).sum::<f64>()
                    <= b.ddr_bytes_per_sec * (1.0 + 1e-9)
            );
        }
    }

    #[test]
    fn two_small_tenants_shard_a_zedboard() {
        let sh = Sharder {
            steps: 8,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        assert!(!r.plans.is_empty());
        assert!(!r.frontier.is_empty());
        for p in &r.plans {
            assert_eq!(p.tenants.len(), 2);
            assert!(p.fps.iter().all(|&f| f > 0.0));
            // Partition safety: slices sum within the physical board.
            let dsps: usize = p.tenants.iter().map(|t| t.report.dsps).sum();
            let bram: usize = p.tenants.iter().map(|t| t.report.bram18).sum();
            assert!(dsps <= zedboard().dsps, "{dsps} DSPs oversubscribed");
            assert!(bram <= zedboard().bram18(), "{bram} BRAM18 oversubscribed");
        }
        // The frontier is non-dominated.
        for &i in &r.frontier {
            for &j in &r.frontier {
                if i != j {
                    assert!(!dominates(&r.plans[j].fps, &r.plans[i].fps));
                }
            }
        }
    }

    #[test]
    fn single_tenant_shard_is_the_plain_allocator() {
        use crate::alloc::Allocator;
        let sh = Sharder::new(zc706(), vec![Tenant::new(zoo::zf(), QuantMode::W16A16)]);
        let r = sh.search().unwrap();
        assert_eq!(r.plans.len(), 1);
        let plain = FlexAllocator::default()
            .allocate(&zoo::zf(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let shard_alloc = &r.plans[0].tenants[0].alloc;
        for (a, b) in shard_alloc.stages.iter().zip(&plain.stages) {
            assert_eq!(a.cfg, b.cfg);
        }
        assert_eq!(
            r.plans[0].tenants[0].report.fps.to_bits(),
            plain.evaluate().fps.to_bits()
        );
    }

    #[test]
    fn temporal_mode_produces_consistent_schedules() {
        let sh = Sharder {
            steps: 8,
            schedule: ScheduleMode::Temporal,
            max_period_s: 0.2,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        assert!(!r.plans.is_empty());
        let freq = zedboard().freq_hz;
        for p in &r.plans {
            let Regime::Temporal(info) = &p.regime else {
                panic!("temporal mode emitted a spatial plan")
            };
            assert_eq!(info.time_parts.iter().sum::<usize>(), 8);
            // fps is exactly the analytic schedule: frames·f/period.
            for (i, &f) in info.frames.iter().enumerate() {
                assert!(f >= 1);
                let want = f as f64 * freq / info.period_cycles as f64;
                assert_eq!(p.fps[i].to_bits(), want.to_bits());
            }
            // Every tenant holds the whole board during its slice.
            assert!(p.tenants.iter().all(|t| t.dsp_parts == 8 && t.bram_parts == 8));
        }
    }

    #[test]
    fn auto_mode_merges_both_regimes_into_one_frontier() {
        let sh = Sharder {
            steps: 8,
            schedule: ScheduleMode::Auto,
            max_period_s: 0.2,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        let spatial = r.plans.iter().filter(|p| !p.regime.is_temporal()).count();
        let temporal = r.plans.iter().filter(|p| p.regime.is_temporal()).count();
        assert!(spatial > 0, "auto must include the spatial split space");
        assert!(temporal > 0, "auto must include temporal schedules");
        // The frontier is non-dominated across the *union* of regimes.
        for &i in &r.frontier {
            for (j, p) in r.plans.iter().enumerate() {
                assert!(
                    j == i || !dominates(&p.fps, &r.plans[i].fps),
                    "frontier member {i} dominated by plan {j}"
                );
            }
        }
        // And auto's frontier objectives are at least as good as either
        // regime alone.
        let solo = |mode| {
            Sharder {
                schedule: mode,
                ..sh.clone()
            }
            .search()
            .unwrap()
        };
        let s = solo(ScheduleMode::Spatial);
        let t = solo(ScheduleMode::Temporal);
        let eps = 1e-9;
        assert!(
            r.plans[r.best_min].min_fps >= s.plans[s.best_min].min_fps - eps
                && r.plans[r.best_min].min_fps >= t.plans[t.best_min].min_fps - eps
        );
    }

    #[test]
    fn weighted_objective_responds_to_weights() {
        let mk = |w1: f64, w2: f64| Sharder {
            steps: 8,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant {
                        net: zoo::tinycnn(),
                        mode: QuantMode::W8A8,
                        weight: w1,
                    },
                    Tenant {
                        net: zoo::lenet(),
                        mode: QuantMode::W8A8,
                        weight: w2,
                    },
                ],
            )
        };
        let a = mk(1.0, 1.0).search().unwrap();
        let b = mk(10.0, 1.0).search().unwrap();
        // Heavier weight on tenant 0 can only shift the weighted pick
        // toward plans serving tenant 0 at least as fast.
        assert!(
            b.plans[b.best_weighted].fps[0] >= a.plans[a.best_weighted].fps[0] - 1e-9
        );
    }
}
