//! Multi-tenant board sharding: one physical FPGA serving several
//! co-resident models.
//!
//! # Relation to the paper (Sec. 4)
//!
//! The paper's framework answers "what is the *balanced* flexible pipeline
//! for one model on one board?": Algorithm 1 splits the multiplier budget
//! Θ across the model's layers proportionally to workload, Algorithm 2
//! trades the BRAM budget α against the DDR bandwidth β. This module lifts
//! the same question one level up — *the board itself becomes the resource
//! being allocated*. Each tenant model receives a slice of the physical
//! (Θ, α, β) and instantiates its own flexible pipeline inside that slice
//! with the unmodified Sec. 4 machinery:
//!
//! - **Θ (DSPs)** is partitioned in `1/steps` quanta; a tenant's quantum
//!   count also scales its LUT/FF caps and its DDR bandwidth share (compute
//!   rate is what generates traffic, so β follows Θ — the share Algorithm 2
//!   balances each tenant's pipeline against).
//! - **α (BRAM)** gets an *independent* split axis: a model's buffer
//!   footprint is set by its feature-map geometry, not its compute share
//!   (VGG16 needs ~⅔ of a ZC706's BRAM18 at 16-bit whether it holds 25% or
//!   100% of the DSPs), so tying the two axes together would forfeit most
//!   of the interesting co-residence points.
//!
//! The split space is searched exhaustively at the configured granularity.
//! Per split, every tenant runs Algorithm 1 + Algorithm 2 on its sub-board
//! — warm-started by sharing each model's decomposition staircases
//! ([`NetTables`], which depend only on layer dimensions) across *all*
//! candidate splits — and infeasible splits (a tenant's pipeline cannot fit
//! its DSP or BRAM slice) are discarded. Feasible splits are reduced to the
//! Pareto frontier of per-tenant fps vectors, alongside two scalarized
//! picks: max–min fps (egalitarian) and weighted-sum fps (SLA-weighted).
//! Frontier winners are optionally validated by the multi-pipeline
//! discrete-event simulation (the provisioned-share engine behind
//! [`crate::sim::Simulate`]), which runs every tenant's event wheel
//! against the *shared* physical DDR port at the provisioned per-tenant
//! shares — the same β split each tenant's Algorithm 2 run was budgeted
//! against.
//!
//! Consumed by the `flexipipe shard` CLI subcommand, the
//! `search::DesignSpace::sweep_shards` axis, the `design_space` example,
//! and `benches/shard.rs`.
//!
//! # Regimes
//!
//! Spatial co-residence (this module's split search) is one of three ways
//! to share a board. [`schedule`] implements the other two — **time
//! multiplexing**: each tenant runs its full-board allocation in a slice
//! of a cyclic schedule, paying a (drain-overlapped)
//! partial-reconfiguration cost per switch; and the **static-region
//! overlay**: all tenants share one synthesized superset datapath, so a
//! switch costs only the incoming tenant's weight re-streaming.
//! [`Sharder::search`] enumerates any of them ([`ScheduleMode`]) and
//! merges the plan sets into one Pareto frontier over *(per-tenant fps ↑,
//! per-tenant worst-case latency ↓)* vectors ([`plan_dominates`]):
//! objectives are directly comparable across regimes, so a spatial plan
//! beaten by a temporal plan on both axes (or vice versa) drops off the
//! merged frontier. Per-tenant latency SLOs ([`Tenant::slo_s`], the CLI's
//! `--slo`) additionally filter every regime's plans at admission time.

pub mod schedule;

pub use schedule::{drain_credit, ReconfigModel, SliceSpec, TemporalInfo};

use crate::alloc::flex::{FlexAllocator, NetTables};
use crate::alloc::{AllocReport, Allocation, TOP_BRAM18};
use crate::board::Board;
use crate::engine::{self, EngineConfig};
use crate::model::{Layer, Network};
use crate::quant::QuantMode;
use crate::sim::{self, SimReport};
use crate::util::json::{num, obj, Value};
use std::sync::Arc;

/// One co-resident workload: a model, its precision, its weight in the
/// weighted-fps objective, and optional admission bounds (latency SLO
/// ceiling, effective-fps floor).
#[derive(Debug, Clone)]
pub struct Tenant {
    /// The model this tenant serves.
    pub net: Network,
    /// Quantization mode the tenant runs at.
    pub mode: QuantMode,
    /// Relative importance in the weighted-fps objective (default 1.0).
    pub weight: f64,
    /// Latency SLO in seconds: the tenant's worst-case frame sojourn
    /// (arrival → completion, see [`TemporalInfo::latency_cycles`]) must
    /// not exceed this. `None` (the default) leaves the tenant
    /// latency-unconstrained; plans violating a set SLO are dropped at
    /// admission in every regime. The CLI's `--slo vgg16=33ms` sets this.
    pub slo_s: Option<f64>,
    /// Throughput floor in frames/second: plans serving this tenant below
    /// the floor are dropped at admission in every regime — the guard
    /// that keeps one tenant's SLO from starving a throughput tenant.
    /// `None` (the default) leaves the tenant floor-free. The CLI's
    /// `--min-fps vgg16=25` sets this.
    pub min_fps: Option<f64>,
}

impl Tenant {
    /// Tenant with unit weight and no latency SLO.
    pub fn new(net: Network, mode: QuantMode) -> Tenant {
        Tenant {
            net,
            mode,
            weight: 1.0,
            slo_s: None,
            min_fps: None,
        }
    }

    /// Same tenant with a worst-case frame-sojourn SLO (seconds).
    pub fn with_slo(mut self, slo_s: f64) -> Tenant {
        self.slo_s = Some(slo_s);
        self
    }

    /// Same tenant with an effective-fps floor (frames/second).
    pub fn with_min_fps(mut self, min_fps: f64) -> Tenant {
        self.min_fps = Some(min_fps);
        self
    }
}

/// Do `fps` rates satisfy every tenant's `min_fps` floor? The admission
/// predicate every regime applies (crate-shared so the spatial and
/// temporal planners cannot drift).
pub(crate) fn meets_floors(tenants: &[Tenant], fps: &[f64]) -> bool {
    !tenants
        .iter()
        .zip(fps)
        .any(|(t, &f)| t.min_fps.is_some_and(|floor| f < floor))
}

/// Parse a CLI `--slo` list: comma-separated `model=duration` entries
/// where the duration **requires** an explicit `s`, `ms`, or `us` suffix
/// — e.g. `vgg16=33ms,zf=0.05s`. A bare `vgg16=33` is rejected: it used
/// to silently mean 33 *seconds*, a 1000× footgun when the author meant
/// 33 ms. Returns `(model name, seconds)` pairs.
pub fn parse_slos(s: &str) -> crate::Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((model, dur)) = entry.split_once('=') else {
            anyhow::bail!("--slo entry '{entry}' is not model=duration");
        };
        let secs = crate::util::cli::parse_duration_s(dur)
            .map_err(|e| anyhow::anyhow!("--slo entry '{entry}': {e}"))?;
        out.push((model.trim().to_string(), secs));
    }
    anyhow::ensure!(!out.is_empty(), "--slo given but names no tenants");
    Ok(out)
}

/// Apply parsed [`parse_slos`] pairs to a tenant list by model name
/// (every tenant of that model gets the SLO); errors on a name matching
/// no tenant.
pub fn apply_slos(tenants: &mut [Tenant], slos: &[(String, f64)]) -> crate::Result<()> {
    for (name, slo) in slos {
        let mut hit = false;
        for t in tenants.iter_mut().filter(|t| &t.net.name == name) {
            t.slo_s = Some(*slo);
            hit = true;
        }
        anyhow::ensure!(hit, "--slo names unknown tenant model '{name}'");
    }
    Ok(())
}

/// Parse a CLI `--min-fps` list: comma-separated `model=fps` entries —
/// e.g. `alexnet=120,vgg16=25`. Returns `(model name, fps floor)` pairs.
pub fn parse_min_fps(s: &str) -> crate::Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((model, fps)) = entry.split_once('=') else {
            anyhow::bail!("--min-fps entry '{entry}' is not model=fps");
        };
        let v: f64 = fps
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--min-fps entry '{entry}': bad fps '{}'", fps.trim()))?;
        anyhow::ensure!(
            v > 0.0 && v.is_finite(),
            "--min-fps entry '{entry}': fps must be positive and finite"
        );
        out.push((model.trim().to_string(), v));
    }
    anyhow::ensure!(!out.is_empty(), "--min-fps given but names no tenants");
    Ok(out)
}

/// Apply parsed [`parse_min_fps`] pairs to a tenant list by model name
/// (every tenant of that model gets the floor); errors on a name matching
/// no tenant.
pub fn apply_min_fps(tenants: &mut [Tenant], floors: &[(String, f64)]) -> crate::Result<()> {
    for (name, floor) in floors {
        let mut hit = false;
        for t in tenants.iter_mut().filter(|t| &t.net.name == name) {
            t.min_fps = Some(*floor);
            hit = true;
        }
        anyhow::ensure!(hit, "--min-fps names unknown tenant model '{name}'");
    }
    Ok(())
}

/// The sub-board a tenant receives: `dsp_parts/steps` of the compute-side
/// resources (DSPs, LUTs, FFs, DDR bandwidth) and `bram_parts/steps` of
/// the BRAM. Integer quanta, so `parts == steps` reproduces the physical
/// board exactly — the anchor of the single-tenant bit-identity invariant.
pub fn sub_board(board: &Board, dsp_parts: usize, bram_parts: usize, steps: usize) -> Board {
    Board {
        name: board.name.clone(),
        dsps: board.dsps * dsp_parts / steps,
        luts: board.luts * dsp_parts / steps,
        ffs: board.ffs * dsp_parts / steps,
        bram36: board.bram36 * bram_parts / steps,
        ddr_bytes_per_sec: board.ddr_bytes_per_sec * (dsp_parts as f64 / steps as f64),
        freq_hz: board.freq_hz,
    }
}

/// All ways to hand `steps` quanta to `n` tenants, each receiving at least
/// one — `C(steps−1, n−1)` compositions, enumerated in lexicographic order
/// (deterministic, so plan indices are stable across runs).
pub fn compositions(steps: usize, n: usize) -> Vec<Vec<usize>> {
    fn rec(out: &mut Vec<Vec<usize>>, cur: &mut Vec<usize>, i: usize, left: usize) {
        let n = cur.len();
        if i == n - 1 {
            cur[i] = left;
            out.push(cur.clone());
            return;
        }
        // Leave at least one quantum for each remaining tenant.
        for p in 1..=(left - (n - 1 - i)) {
            cur[i] = p;
            rec(out, cur, i + 1, left - p);
        }
    }
    assert!(n >= 1 && steps >= n, "need at least one quantum per tenant");
    let mut out = Vec::new();
    rec(&mut out, &mut vec![0usize; n], 0, steps);
    out
}

/// Which plans [`Sharder::search`] enumerates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Spatial co-residence only (the PR-2 behaviour; the default).
    Spatial,
    /// Time multiplexing only (partial reconfiguration per switch).
    Temporal,
    /// Static-region overlay only: one shared superset datapath,
    /// zero-reconfiguration switches (weight re-streaming only).
    Overlay,
    /// Every regime, merged into one Pareto frontier.
    Auto,
}

impl ScheduleMode {
    /// CLI/report label.
    pub fn label(&self) -> &'static str {
        match self {
            ScheduleMode::Spatial => "spatial",
            ScheduleMode::Temporal => "temporal",
            ScheduleMode::Overlay => "overlay",
            ScheduleMode::Auto => "auto",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "spatial" => Ok(ScheduleMode::Spatial),
            "temporal" | "time" => Ok(ScheduleMode::Temporal),
            "overlay" => Ok(ScheduleMode::Overlay),
            "auto" | "both" | "all" => Ok(ScheduleMode::Auto),
            other => anyhow::bail!("unknown schedule '{other}' (spatial temporal overlay auto)"),
        }
    }
}

/// Which resource-division regime produced a plan.
#[derive(Debug, Clone)]
pub enum Regime {
    /// Spatial co-residence: tenants hold disjoint (Θ, α) slices at once.
    Spatial,
    /// Time multiplexing: each tenant runs its full-board pipeline in
    /// sub-slices of the schedule period ([`schedule`]). Covers both the
    /// reconfiguring regime and the static-region overlay
    /// ([`TemporalInfo::overlay`]).
    Temporal(TemporalInfo),
}

impl Regime {
    /// Report label (`"spatial"`, `"temporal"`, or `"overlay"`).
    pub fn label(&self) -> &'static str {
        match self {
            Regime::Spatial => "spatial",
            Regime::Temporal(info) if info.overlay => "overlay",
            Regime::Temporal(_) => "temporal",
        }
    }

    /// Is this a time-multiplexed plan (reconfiguring or overlay)?
    pub fn is_temporal(&self) -> bool {
        matches!(self, Regime::Temporal(_))
    }
}

/// One tenant's slice of a [`ShardPlan`].
#[derive(Debug, Clone)]
pub struct TenantAlloc {
    /// DSP-side quanta this tenant holds (`dsp_parts/steps` of Θ/LUT/FF/β).
    pub dsp_parts: usize,
    /// BRAM quanta this tenant holds (`bram_parts/steps` of α).
    pub bram_parts: usize,
    /// The tenant's flexible pipeline on its sub-board. Shared (`Arc`)
    /// across every plan that gives this tenant the same slice — the
    /// per-tenant allocation depends only on its own (dsp, bram) quanta,
    /// never on how the remainder is divided among the others.
    pub alloc: Arc<Allocation>,
    /// Closed-form report for that pipeline.
    pub report: Arc<AllocReport>,
}

/// One feasible plan: a spatial split of the board, or one temporal
/// schedule of it (see [`Regime`]).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Per-tenant slices, in the sharder's tenant order. For temporal
    /// plans every tenant holds the whole board (`parts == steps`) during
    /// its time slice.
    pub tenants: Vec<TenantAlloc>,
    /// Per-tenant effective fps (closed-form for spatial plans, analytic
    /// schedule for temporal ones — same order as `tenants`).
    pub fps: Vec<f64>,
    /// `min_i fps_i` — the egalitarian objective.
    pub min_fps: f64,
    /// `Σ_i weight_i · fps_i` — the SLA-weighted objective.
    pub weighted_fps: f64,
    /// Per-tenant worst-case frame latency in seconds — the second
    /// frontier axis (lower is better). Temporal plans report the analytic
    /// worst-case sojourn ([`TemporalInfo::latency_cycles`] over the board
    /// clock). Spatial plans report the same quantity for a continuously
    /// resident pipeline at its admitted rate: one steady frame interval
    /// of queueing (`1/fps` — the *effective* rate, bandwidth cap
    /// included, not the compute beat) plus the pipeline traversal
    /// (Σ per-stage cycles, closed-form) — the definition the temporal
    /// degenerate single-tenant schedule uses with its DES-calibrated
    /// `fill + beat`, so the two regimes' latency axes are comparable and
    /// `--slo` means the same thing everywhere.
    pub latency_s: Vec<f64>,
    /// DES confirmation, one report per tenant (frontier plans only, when
    /// `sim_frames > 0`): the shared-port multi-pipeline wheel for spatial
    /// plans, the drain-overlapped schedule executor for temporal and
    /// overlay ones (fps is the effective over-the-period rate).
    pub sim: Option<Vec<SimReport>>,
    /// Which regime produced this plan.
    pub regime: Regime,
}

/// The searched split space for one board + tenant set.
#[derive(Debug, Clone)]
pub struct Sharder {
    /// The physical board being shared.
    pub board: Board,
    /// Co-resident workloads.
    pub tenants: Vec<Tenant>,
    /// Split granularity: resources move between tenants in `1/steps`
    /// quanta. Default 16 — fine enough to separate VGG16-class BRAM
    /// footprints from AlexNet-class ones, coarse enough that a two-tenant
    /// search is a few hundred allocator runs.
    pub steps: usize,
    /// Frames for the multi-pipeline DES validation of frontier plans
    /// (0 = closed-form only).
    pub sim_frames: usize,
    /// Which plan regimes to enumerate (spatial splits, temporal
    /// schedules, the static-region overlay, or all merged — default
    /// [`ScheduleMode::Spatial`]).
    pub schedule: ScheduleMode,
    /// Partial-reconfiguration cost model for temporal schedules.
    pub reconfig: ReconfigModel,
    /// Largest interleave factor the temporal planner may give one tenant:
    /// up to `max_interleave` sub-slices per tenant per period. 1 (the
    /// default) is the PR-3 whole-slice layout; higher values trade extra
    /// reconfiguration switches for a tighter worst-case frame sojourn —
    /// the lever that makes tight `--slo` bounds admissible.
    pub max_interleave: usize,
    /// Latency bound for temporal schedules: the cyclic period never
    /// exceeds this many seconds (a tenant waits at most one period
    /// between slices). Longer periods amortize reconfiguration dead time
    /// better. Default 0.5 s.
    pub max_period_s: f64,
    /// Solo DES frames used to calibrate each tenant's fill latency and
    /// steady beat for the analytic temporal schedule. Default 6. The
    /// max-gap extrapolation assumes the window sees the pipeline's
    /// largest completion gap (true for steady-periodic pipelines — the
    /// shipped workloads settle within 2 frames, mirror-checked); raise
    /// this for pipelines whose gaps oscillate with a longer period.
    /// Mis-calibration is never silent: over-admitted slices surface as
    /// DES `overrun` / below-analytic fps in the validation pass.
    pub calib_frames: usize,
    /// Admission-control ceiling on frames per slice (bounds the queue
    /// depth a tenant needs and the DES validation cost for very fast
    /// models). Default 4096.
    pub max_slice_frames: usize,
    /// Branch-and-bound pruning (the CLI's `--prune`). When set, whole
    /// DSP-composition subtrees whose admissible per-tenant bound vector
    /// (fps upper bound from the staircase tables, latency lower bound
    /// from the stage-cycle sums) already violates a floor/SLO or is
    /// weakly dominated by an incumbent frontier plan are skipped without
    /// assembling their plans. The frontier, `best_min`, and
    /// `best_weighted` plan *contents* are provably identical to the
    /// exhaustive search (property-tested); only the exhaustive `plans`
    /// listing may shrink, so the default is `false`.
    pub prune: bool,
}

/// Search-effort counters for one [`Sharder::search`] run: how much of
/// the quantum lattice was enumerated, and how much of it the exact cell
/// rules and (with [`Sharder::prune`]) the branch-and-bound assembly
/// bound skipped without a full evaluation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Quantum-lattice nodes visited: allocator cells (tenant × DSP parts
    /// × BRAM parts) plus plan assemblies (DSP × BRAM compositions).
    pub lattice_nodes: usize,
    /// Lattice nodes skipped without a full evaluation — the always-on
    /// exact cell rules (zero-resource slices, the min-DSP / min-BRAM
    /// admissible bounds, the α-saturation reuse cache) plus, with
    /// pruning on, the bound-skipped assemblies.
    pub pruned_nodes: usize,
    /// Assemblies skipped by the branch-and-bound bound specifically
    /// (always 0 when [`Sharder::prune`] is off) — the counter the CLI
    /// prints to show `--prune` engaged.
    pub bound_skipped: usize,
    /// Full allocator runs actually performed (the dominant search cost).
    pub alloc_calls: usize,
}

/// Search output: every feasible plan plus the interesting subsets.
#[derive(Debug, Clone)]
pub struct ShardResult {
    /// All feasible plans, in deterministic enumeration order
    /// (DSP composition outer, BRAM composition inner, lexicographic;
    /// temporal plans follow, quantum descending).
    pub plans: Vec<ShardPlan>,
    /// Indices of the non-dominated plans under the merged per-tenant
    /// (fps ↑, worst-case latency ↓) objective ([`plan_dominates`]),
    /// exact-tie deduplicated (first representative wins — see
    /// [`frontier`]).
    pub frontier: Vec<usize>,
    /// Index of the plan maximizing `min_fps` (first wins ties).
    pub best_min: usize,
    /// Index of the plan maximizing `weighted_fps` (first wins ties).
    pub best_weighted: usize,
    /// Lattice/pruning/allocator-call counters for this search.
    pub stats: ShardStats,
}

impl Sharder {
    /// Sharder with default granularity, spatial regime, and no DES
    /// validation.
    pub fn new(board: Board, tenants: Vec<Tenant>) -> Sharder {
        Sharder {
            board,
            tenants,
            steps: 16,
            sim_frames: 0,
            schedule: ScheduleMode::Spatial,
            reconfig: ReconfigModel::default(),
            max_interleave: 1,
            max_period_s: 0.5,
            calib_frames: 6,
            max_slice_frames: 4096,
            prune: false,
        }
    }

    /// Enumerate the plan space of the selected regime(s) — spatial
    /// splits, temporal schedules, the static-region overlay, or all of
    /// them — keep the feasible (and SLO-satisfying) plans, reduce the
    /// union to the Pareto frontier over per-tenant (fps ↑, worst-case
    /// latency ↓) vectors, and (optionally) confirm frontier plans with
    /// the matching DES (shared-port multi-pipeline wheel for spatial
    /// plans, the drain-overlapped schedule executor for temporal and
    /// overlay ones).
    ///
    /// ```
    /// use flexipipe::board::zedboard;
    /// use flexipipe::model::zoo;
    /// use flexipipe::quant::QuantMode;
    /// use flexipipe::shard::{Sharder, Tenant};
    ///
    /// let sharder = Sharder {
    ///     steps: 4,
    ///     ..Sharder::new(
    ///         zedboard(),
    ///         vec![
    ///             Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
    ///             Tenant::new(zoo::lenet(), QuantMode::W8A8),
    ///         ],
    ///     )
    /// };
    /// let result = sharder.search().unwrap();
    /// assert!(!result.frontier.is_empty());
    /// let best = &result.plans[result.best_min];
    /// assert!(best.fps.iter().all(|&fps| fps > 0.0));
    /// ```
    pub fn search(&self) -> crate::Result<ShardResult> {
        let n = self.tenants.len();
        anyhow::ensure!(n >= 1, "shard: no tenants given");
        anyhow::ensure!(
            self.steps >= n,
            "shard: {} tenants need at least {} split steps (have {})",
            n,
            n,
            self.steps
        );
        for t in &self.tenants {
            t.net.validate()?;
        }
        anyhow::ensure!(
            self.reconfig.overlay_overhead >= 1.0,
            "shard: overlay_overhead must be ≥ 1.0 — the element-wise-max footprint it \
             scales is already the optimistic full-reuse bound"
        );
        // A lone tenant has nothing to share a static region with — fail
        // with the real cause instead of the generic "no feasible plan".
        anyhow::ensure!(
            !(self.schedule == ScheduleMode::Overlay && n == 1),
            "shard: the overlay regime needs at least two tenants to share the \
             static region — a lone tenant is just the plain allocation \
             (use --schedule temporal or auto)"
        );

        // Shared precomputation: each model's decomposition staircases
        // depend only on its layer dimensions, so they are built once and
        // warm-start every allocator run of either regime.
        let tables: Vec<NetTables> = self.tenants.iter().map(|t| NetTables::build(&t.net)).collect();

        // Every regime appends into one shared plan list and offers each
        // survivor to one shared incremental frontier ([`FrontierMerge`]),
        // so the cross-regime reduction happens as plans are born — the
        // incumbents double as the branch-and-bound pruning reference.
        let mut plans: Vec<ShardPlan> = Vec::new();
        let mut merge = FrontierMerge::default();
        let mut stats = ShardStats::default();
        if matches!(self.schedule, ScheduleMode::Spatial | ScheduleMode::Auto) {
            self.spatial_plans(&tables, &mut plans, &mut merge, &mut stats)?;
        }
        if self.schedule != ScheduleMode::Spatial {
            // One full-board allocation + DES calibration per tenant,
            // shared by the temporal and overlay enumerations (`None` =
            // some tenant's pipeline doesn't fit the board even alone).
            if let Some(solos) = schedule::solo_tenants(self, &tables)? {
                if matches!(self.schedule, ScheduleMode::Temporal | ScheduleMode::Auto) {
                    schedule::temporal_plans(self, &solos, false, &mut plans, &mut merge, &mut stats)?;
                }
                if matches!(self.schedule, ScheduleMode::Overlay | ScheduleMode::Auto) {
                    schedule::temporal_plans(self, &solos, true, &mut plans, &mut merge, &mut stats)?;
                }
            }
        }
        anyhow::ensure!(
            !plans.is_empty(),
            "shard: no feasible {} plan for {} across {} tenants at {} steps \
             (board too small for the tenant set, or every schedule violates \
             an --slo or --min-fps bound — try fewer tenants, 8-bit mode, \
             `--schedule auto`, `--interleave 2`, relaxed bounds, or a larger \
             board)",
            self.schedule.label(),
            self.board.name,
            n,
            self.steps
        );

        let frontier = merge.into_indices();
        debug_assert_eq!(
            frontier,
            crate::shard::frontier(&plans),
            "incremental frontier merge diverged from the reference reduction"
        );
        let best_min = argmax(&plans, |p| p.min_fps);
        let best_weighted = argmax(&plans, |p| p.weighted_fps);

        let mut result = ShardResult {
            plans,
            frontier,
            best_min,
            best_weighted,
            stats,
        };
        if self.sim_frames > 0 {
            for idx in result.frontier.clone() {
                let sims = self.validate_plan(&result.plans[idx]);
                result.plans[idx].sim = Some(sims);
            }
        }
        Ok(result)
    }

    /// DES confirmation of one frontier plan, regime-matched (the shared
    /// [`confirm_plan`] engine with this sharder's provisioned shares).
    fn validate_plan(&self, plan: &ShardPlan) -> Vec<SimReport> {
        let refs: Vec<&Allocation> = plan.tenants.iter().map(|t| t.alloc.as_ref()).collect();
        let shares: Vec<f64> = plan
            .tenants
            .iter()
            .map(|t| t.dsp_parts as f64 / self.steps as f64)
            .collect();
        confirm_plan(&refs, &shares, &self.board, &plan.regime, self.sim_frames)
    }

    /// Enumerate the spatial split space and append the feasible plans
    /// (the PR-2 search, factored out of [`Sharder::search`]), offering
    /// each survivor to the shared incremental frontier.
    ///
    /// Four **exact** cell rules are always on — they can never change
    /// the cell table, only skip allocator runs whose outcome is already
    /// known (each is individually mirror-verified cell-by-cell):
    ///
    /// - **Rule 0** (zero slice): a slice with 0 DSPs or 0 BRAM36 cannot
    ///   host a pipeline.
    /// - **Rule 1** (min-DSP bound): a pipeline needs at least
    ///   `Σ ceil(granule_l / pack)` DSPs (one minimal `(C',M')` engine per
    ///   compute stage); a DSP slice below that is infeasible at *every*
    ///   BRAM split, so the whole `p` row is skipped.
    /// - **Rule 1b** (min-BRAM bound): every stage's BRAM18 cost is
    ///   minimized at `cp = mp = k = 1` with a minimal producer (activation,
    ///   weight, and psum words all grow monotonically in the geometry),
    ///   so a BRAM slice below `TOP_BRAM18 + Σ stage_bram18(minimal)` is
    ///   infeasible regardless of what Algorithm 1 picks.
    /// - **Rule 3** (α-saturation): the allocator's only α-dependent
    ///   decisions are `raise_k`'s BRAM rejections
    ///   ([`crate::alloc::flex::AllocOutcome`]'s `bram_clean`). A run that never hit the BRAM wall at `(p, q)` is
    ///   bit-identical at every `q' > q` (Θ and the β share depend only on
    ///   `p`), so the first clean run per `(tenant, p)` is reused for all
    ///   larger BRAM slices — only the per-`q` fit check is re-evaluated.
    ///
    /// With [`Sharder::prune`] set, the **branch-and-bound** assembly rule
    /// additionally skips whole DSP-composition subtrees whose admissible
    /// bound vector is floor/SLO-infeasible or weakly dominated by an
    /// incumbent frontier plan (see [`Sharder::assembly_bound_prunes`]).
    fn spatial_plans(
        &self,
        tables: &[NetTables],
        plans: &mut Vec<ShardPlan>,
        merge: &mut FrontierMerge,
        stats: &mut ShardStats,
    ) -> crate::Result<()> {
        let n = self.tenants.len();
        // The plan space is C(steps−1, n−1)² and the frontier reduction is
        // O(plans²): bound it so a 4-tenant run at fine granularity fails
        // fast with guidance instead of grinding for hours.
        let splits_per_axis = binomial(self.steps - 1, n - 1);
        let space = splits_per_axis.saturating_mul(splits_per_axis);
        anyhow::ensure!(
            space <= 50_000,
            "shard: split space too large ({splits_per_axis}² = {space} candidate plans for \
             {n} tenants at {} steps) — lower `steps` (e.g. `--shard-steps {}`)",
            self.steps,
            suggest_steps(n),
        );

        // A tenant's allocation depends only on its own slice, so the
        // split space factorizes: allocate each tenant once per
        // (dsp_parts, bram_parts) it can receive, then assemble plans by
        // table lookup. `None` = that slice is infeasible for the tenant.
        let max_parts = self.steps - (n - 1);
        let slot = |p: usize, q: usize| (p - 1) * max_parts + (q - 1);
        // Slice sizes any composition can actually hand out (a lone tenant
        // always gets the whole board — no point allocating the rest).
        let parts_range: Vec<usize> = if n == 1 {
            vec![self.steps]
        } else {
            (1..=max_parts).collect()
        };
        let min_dsps: Vec<usize> =
            self.tenants.iter().map(|t| min_dsps_bound(&t.net, t.mode)).collect();
        let min_bram: Vec<usize> =
            self.tenants.iter().map(|t| min_bram_bound(&t.net, t.mode)).collect();
        stats.lattice_nodes += n * parts_range.len() * parts_range.len();
        let mut cells: Vec<Vec<Option<TenantAlloc>>> = Vec::with_capacity(n);
        for (i, t) in self.tenants.iter().enumerate() {
            let mut row: Vec<Option<TenantAlloc>> = vec![None; max_parts * max_parts];
            for &p in &parts_range {
                // Rule 1: the DSP share depends only on p — below the
                // min-DSP bound the whole row is infeasible.
                if min_dsps[i] > sub_board(&self.board, p, 1, self.steps).dsps {
                    stats.pruned_nodes += parts_range.len();
                    continue;
                }
                // Rule 3 cache: the first clean allocator run at this
                // (tenant, p), reused verbatim for every larger q.
                let mut cached: Option<(Arc<Allocation>, Arc<AllocReport>)> = None;
                for &q in &parts_range {
                    let sub = sub_board(&self.board, p, q, self.steps);
                    // Rule 0: empty slice.
                    if sub.dsps == 0 || sub.bram36 == 0 {
                        stats.pruned_nodes += 1;
                        continue;
                    }
                    // Rule 1b: below the minimal-geometry BRAM footprint.
                    if min_bram[i] > sub.bram18() {
                        stats.pruned_nodes += 1;
                        continue;
                    }
                    let (alloc, report) = if let Some((a, r)) = &cached {
                        stats.pruned_nodes += 1; // Rule 3 reuse
                        (Arc::clone(a), Arc::clone(r))
                    } else {
                        stats.alloc_calls += 1;
                        let Ok((alloc, _, outcome)) = FlexAllocator::default()
                            .allocate_outcome(&t.net, &sub, t.mode, &tables[i], None)
                        else {
                            continue;
                        };
                        let report = alloc.evaluate();
                        let pair = (Arc::new(alloc), Arc::new(report));
                        if outcome.bram_clean {
                            cached = Some((Arc::clone(&pair.0), Arc::clone(&pair.1)));
                        }
                        pair
                    };
                    // Feasible iff the pipeline fits the slice's Θ and α
                    // (the paper's partitioned budgets; LUT/FF are reported
                    // but interconnect-dominated, not partition-enforced).
                    if report.dsps > sub.dsps || report.bram18 > sub.bram18() {
                        continue;
                    }
                    row[slot(p, q)] = Some(TenantAlloc {
                        dsp_parts: p,
                        bram_parts: q,
                        alloc,
                        report,
                    });
                }
            }
            cells.push(row);
        }

        // Assemble: every (DSP composition × BRAM composition) whose
        // tenant cells all exist is a feasible plan.
        let dsp_splits = compositions(self.steps, n);
        let bram_splits = compositions(self.steps, n);
        stats.lattice_nodes += dsp_splits.len() * bram_splits.len();
        for dsp in &dsp_splits {
            // Branch-and-bound (opt-in): one admissible bound evaluation
            // against the incumbent frontier retires the whole BRAM axis.
            if self.prune && self.assembly_bound_prunes(dsp, tables, plans, merge) {
                stats.pruned_nodes += bram_splits.len();
                stats.bound_skipped += bram_splits.len();
                continue;
            }
            for bram in &bram_splits {
                let mut slices = Vec::with_capacity(n);
                for i in 0..n {
                    match &cells[i][slot(dsp[i], bram[i])] {
                        Some(cell) => slices.push(cell.clone()),
                        None => {
                            slices.clear();
                            break;
                        }
                    }
                }
                if slices.len() != n {
                    continue;
                }
                let fps: Vec<f64> = slices.iter().map(|s| s.report.fps).collect();
                // Latency axis: one steady frame interval of queueing plus
                // the frame traversal of the tenant's resident pipeline
                // (see `ShardPlan::latency_s` — the same worst-case-sojourn
                // definition the temporal regime calibrates with the DES).
                // The interval is 1/fps, not the compute beat: a
                // bandwidth-capped slice serves frames at the throttled
                // rate, and under-reporting here would let `--slo` admit
                // plans whose real sojourn violates the bound.
                let latency_s: Vec<f64> = slices
                    .iter()
                    .map(|s| {
                        1.0 / s.report.fps
                            + s.report.stage_cycles.iter().sum::<u64>() as f64
                                / self.board.freq_hz
                    })
                    .collect();
                // SLO and fps-floor admission apply to every regime.
                if self
                    .tenants
                    .iter()
                    .zip(&latency_s)
                    .any(|(t, &lat)| t.slo_s.is_some_and(|slo| lat > slo))
                {
                    continue;
                }
                if !meets_floors(&self.tenants, &fps) {
                    continue;
                }
                let min_fps = fps.iter().copied().fold(f64::INFINITY, f64::min);
                let weighted_fps = fps
                    .iter()
                    .zip(&self.tenants)
                    .map(|(f, t)| f * t.weight)
                    .sum();
                plans.push(ShardPlan {
                    tenants: slices,
                    fps,
                    min_fps,
                    weighted_fps,
                    latency_s,
                    sim: None,
                    regime: Regime::Spatial,
                });
                merge.offer(plans, plans.len() - 1);
            }
        }
        Ok(())
    }

    /// The branch-and-bound test behind [`Sharder::prune`]: an admissible
    /// per-tenant *(fps upper bound, latency lower bound)* vector for
    /// every plan in the DSP composition `dsp`'s subtree, from the
    /// staircase tables alone — no allocator run.
    ///
    /// Admissibility: `cycles_at(θ)` is non-increasing in θ and a slice's
    /// Θ budget depends only on its DSP parts, so the bottleneck stage at
    /// the *full* per-tenant budget lower-bounds every real plan's frame
    /// interval (K-raising only adds cycles per weight reload, the DDR
    /// cap only lowers fps, and BRAM never raises it). Likewise the
    /// latency `1/fps + Σ stage_cycles / f` is bounded below by the
    /// optimistic interval plus the per-stage staircase minima (pool
    /// stages contribute their fixed `h·w` row scans). A subtree whose
    /// bound vector already violates a tenant's fps floor or latency SLO
    /// contains no admissible plan; one whose bound vector is weakly
    /// dominated by an incumbent frontier plan contains only plans the
    /// tie-deduplicating frontier would reject — either way the frontier
    /// and the scalarized picks are unchanged (property-tested).
    fn assembly_bound_prunes(
        &self,
        dsp: &[usize],
        tables: &[NetTables],
        plans: &[ShardPlan],
        merge: &FrontierMerge,
    ) -> bool {
        let n = self.tenants.len();
        let mut fps_ub = Vec::with_capacity(n);
        let mut lat_lb = Vec::with_capacity(n);
        for (i, t) in self.tenants.iter().enumerate() {
            // BRAM parts never enter the bound — any q gives the same Θ/β.
            let sub = sub_board(&self.board, dsp[i], dsp[i], self.steps);
            let tt = FlexAllocator::default()
                .theta_budget(tables[i].n_layers(), &sub, t.mode)
                .max(1);
            let ub = self.board.freq_hz / tables[i].bottleneck_cycles_lb(tt).max(1) as f64;
            let pool_rows: u64 = t
                .net
                .layers
                .iter()
                .map(|l| match l {
                    Layer::Pool(p) => (p.h * p.w) as u64,
                    _ => 0,
                })
                .sum();
            let lb = 1.0 / ub
                + (tables[i].stage_cycle_sum_lb(tt) + pool_rows) as f64 / self.board.freq_hz;
            if t.min_fps.is_some_and(|floor| ub < floor) {
                return true;
            }
            if t.slo_s.is_some_and(|slo| lb > slo) {
                return true;
            }
            fps_ub.push(ub);
            lat_lb.push(lb);
        }
        merge
            .members()
            .iter()
            .any(|&k| vec_weakly_dominates(&plans[k].fps, &plans[k].latency_s, &fps_ub, &lat_lb))
    }
}

/// Fewest DSPs any allocation of `net` can use: one minimal engine per
/// compute stage (`ceil(granule / pack)` — a conv stage's multiplier count
/// is a multiple of `r·s`, an FC stage's of 1). Exact lower bound behind
/// spatial cell Rule 1.
fn min_dsps_bound(net: &Network, mode: QuantMode) -> usize {
    net.compute_layers()
        .iter()
        .map(|&i| {
            let granule = match &net.layers[i] {
                Layer::Conv(cv) => cv.r * cv.s,
                _ => 1,
            };
            engine::div_ceil(granule, mode.mults_per_dsp())
        })
        .sum()
}

/// Fewest BRAM18s any allocation of `net` can use: the top-level streaming
/// buffers plus every stage at minimal geometry (`cp = mp = k = 1`,
/// minimal producer) — each buffer's word count grows monotonically in all
/// of those knobs. Exact lower bound behind spatial cell Rule 1b.
fn min_bram_bound(net: &Network, mode: QuantMode) -> usize {
    let minimal = EngineConfig { cp: 1, mp: 1, k: 1 };
    TOP_BRAM18
        + net
            .layers
            .iter()
            .map(|l| engine::stage_bram18(l, &minimal, 1, 1, mode))
            .sum::<usize>()
}

/// Regime-matched DES confirmation of one plan's per-tenant rates — the
/// single execution engine behind both [`Sharder::search`]'s validation
/// pass and the [`crate::sim::Simulate`] plan executor, so a serialized
/// [`crate::plan::DeploymentPlan`] re-simulates **bit-identically** to the
/// in-process search (acceptance-pinned). `shares` is each tenant's
/// provisioned fraction of the physical DDR port (spatial plans validate
/// against the split Algorithm 2 budgeted, not the demand-converged one);
/// temporal plans ignore it and execute one full schedule period.
pub(crate) fn confirm_plan(
    allocs: &[&Allocation],
    shares: &[f64],
    board: &Board,
    regime: &Regime,
    sim_frames: usize,
) -> Vec<SimReport> {
    match regime {
        // Validate against the *provisioned* port split (each tenant gets
        // the dsp_parts/steps of β its Algorithm 2 run was budgeted), not
        // the demand-converged split — the plan was ranked on the former.
        Regime::Spatial => sim::simulate_multi_provisioned(allocs, shares, board, sim_frames),
        // Degenerate single-tenant schedule: continuous solo run.
        Regime::Temporal(info) if info.period_cycles == 0 => {
            sim::simulate_multi_provisioned(allocs, &[1.0], board, sim_frames)
        }
        // Execute one schedule period: drain → (drain-overlapped)
        // reconfigure → refill, dead cycles charged. Per-tenant fps
        // becomes the effective over-the-period rate
        // (analytic-schedule-comparable).
        Regime::Temporal(info) => {
            let ts = sim::simulate_schedule(allocs, &info.schedule_slices(), true);
            let period = ts.period_cycles;
            (0..allocs.len())
                .map(|t| {
                    // Re-base the tenant's largest batch report to the
                    // effective over-the-period view so the struct
                    // stays coherent: gops/dsp_efficiency are linear
                    // in fps, the port draw sums every sub-slice's
                    // makespan-window draw over the period, and
                    // fps == freq/cycles_per_frame again after both
                    // are rewritten. `makespan` keeps the
                    // representative batch's own execution window.
                    let mine: Vec<&sim::TimeshareSlice> =
                        ts.slices.iter().filter(|s| s.tenant == t).collect();
                    let repr = mine
                        .iter()
                        .max_by_key(|s| s.frames)
                        .expect("every tenant holds at least one sub-slice");
                    let mut r = repr
                        .sim
                        .clone()
                        .expect("feasible temporal plans admit ≥1 frame");
                    let frames: usize = mine.iter().map(|s| s.frames).sum();
                    let util: f64 = mine
                        .iter()
                        .filter_map(|s| s.sim.as_ref())
                        .map(|s| s.ddr_utilization * s.makespan as f64)
                        .sum::<f64>()
                        / period as f64;
                    let rate = ts.tenant_fps[t] / r.fps;
                    r.gops *= rate;
                    r.dsp_efficiency *= rate;
                    r.ddr_utilization = util;
                    r.fps = ts.tenant_fps[t];
                    r.cycles_per_frame = period as f64 / frames.max(1) as f64;
                    r
                })
                .collect()
        }
    }
}

/// `C(n, k)` with saturation (plan-space sizing only).
pub(crate) fn binomial(n: usize, k: usize) -> usize {
    let k = k.min(n - k);
    let mut acc: usize = 1;
    for i in 0..k {
        acc = acc.saturating_mul(n - i) / (i + 1);
    }
    acc
}

/// Largest `steps` whose split space `C(steps−1, n−1)²` stays within the
/// search bound for `n` tenants (the error message's suggestion).
pub(crate) fn suggest_steps(n: usize) -> usize {
    if n <= 1 {
        return 64; // a lone tenant has one split at any granularity
    }
    let fits = |s: usize| {
        let b = binomial(s - 1, n - 1);
        b.saturating_mul(b) <= 50_000
    };
    let mut s = n;
    while s < 1024 && fits(s + 1) {
        s += 1;
    }
    s
}

/// `a` dominates `b` when it is ≥ on every tenant's fps and > on one —
/// the throughput half of plan dominance (kept public for fps-only
/// analyses; the frontier itself uses [`plan_dominates`]).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x >= y) && a.iter().zip(b).any(|(x, y)| x > y)
}

/// Plan-level dominance over the merged objective: `a` dominates `b` when
/// it is ≥ on every tenant's fps, ≤ on every tenant's worst-case latency,
/// and strictly better on at least one coordinate of either vector — the
/// canonical predicate behind [`frontier`] (public so tests assert
/// against the same definition the search uses). A plan that trades fps
/// for latency (or vice versa) is incomparable and survives.
pub fn plan_dominates(a: &ShardPlan, b: &ShardPlan) -> bool {
    vec_dominates(&a.fps, &a.latency_s, &b.fps, &b.latency_s)
}

/// The raw dominance arithmetic behind [`plan_dominates`], on bare
/// objective vectors — crate-shared with [`crate::plan::Planner`]'s
/// multi-board frontier so the two reductions cannot drift.
pub(crate) fn vec_dominates(a_fps: &[f64], a_lat: &[f64], b_fps: &[f64], b_lat: &[f64]) -> bool {
    a_fps.iter().zip(b_fps).all(|(x, y)| x >= y)
        && a_lat.iter().zip(b_lat).all(|(x, y)| x <= y)
        && (a_fps.iter().zip(b_fps).any(|(x, y)| x > y)
            || a_lat.iter().zip(b_lat).any(|(x, y)| x < y))
}

/// Weak dominance: `a` is at least as good as `b` on *every* coordinate,
/// ties allowed everywhere — so an exact objective tie weakly dominates
/// in both directions. The predicate behind [`FrontierMerge`]'s reject
/// and evict steps (rejecting on weak dominance is what deduplicates
/// exact ties: the earlier representative is already a member).
pub(crate) fn vec_weakly_dominates(
    a_fps: &[f64],
    a_lat: &[f64],
    b_fps: &[f64],
    b_lat: &[f64],
) -> bool {
    a_fps.iter().zip(b_fps).all(|(x, y)| x >= y) && a_lat.iter().zip(b_lat).all(|(x, y)| x <= y)
}

/// Incremental Pareto-frontier accumulator over objective vectors — a
/// maximized `ups` vector and a minimized `downs` vector per candidate
/// (per-tenant *(fps ↑, worst-case latency ↓)* for shard plans; the fleet
/// planner prepends a cost axis to `downs`) — replacing the old
/// collect-then-filter reduction. Offer every plan as it is born:
/// a candidate weakly dominated by an incumbent is rejected (this
/// subsumes exact-tie deduplication — the first representative wins),
/// otherwise it evicts every incumbent it weakly dominates and joins.
/// Offering plans in enumeration order keeps the member list sorted and
/// makes the result identical to the reference [`frontier`] reduction
/// (debug-asserted in [`Sharder::search`], property-tested in the
/// suite). The live incumbent set doubles as the branch-and-bound
/// pruning reference: a subtree bound weakly dominated by a member can
/// only produce rejected plans.
#[derive(Debug, Clone, Default)]
pub(crate) struct FrontierMerge {
    members: Vec<usize>,
    /// Objective vectors `(ups, downs)` parallel to `members`, cached so
    /// dominance checks need no back-reference into the caller's plan
    /// list (which lets heterogeneous callers — shard and fleet — share
    /// one accumulator implementation).
    keys: Vec<(Vec<f64>, Vec<f64>)>,
}

impl FrontierMerge {
    /// Offer candidate `idx` with maximized vector `ups` and minimized
    /// vector `downs`; returns whether it was admitted.
    pub(crate) fn offer_vec(&mut self, ups: &[f64], downs: &[f64], idx: usize) -> bool {
        if self
            .keys
            .iter()
            .any(|(u, d)| vec_weakly_dominates(u, d, ups, downs))
        {
            return false;
        }
        let mut i = 0;
        while i < self.members.len() {
            if vec_weakly_dominates(ups, downs, &self.keys[i].0, &self.keys[i].1) {
                self.members.remove(i);
                self.keys.remove(i);
            } else {
                i += 1;
            }
        }
        self.members.push(idx);
        self.keys.push((ups.to_vec(), downs.to_vec()));
        true
    }

    /// Offer `plans[idx]` under the shard objective (per-tenant fps ↑,
    /// worst-case latency ↓); returns whether it was admitted.
    pub(crate) fn offer(&mut self, plans: &[ShardPlan], idx: usize) -> bool {
        let p = &plans[idx];
        self.offer_vec(&p.fps, &p.latency_s, idx)
    }

    /// Current incumbent plan indices, ascending.
    pub(crate) fn members(&self) -> &[usize] {
        &self.members
    }

    /// Consume the accumulator into the final frontier index list.
    pub(crate) fn into_indices(self) -> Vec<usize> {
        self.members
    }
}

/// Indices of the non-dominated plans under [`plan_dominates`] — the
/// merged (fps ↑, worst-case latency ↓) Pareto frontier, with exact
/// objective ties deduplicated (only the first of a tie group survives;
/// duplicate plans told no one anything the first didn't). This is the
/// O(n²) *reference* reduction; [`Sharder::search`] builds the same set
/// incrementally with [`FrontierMerge`] and debug-asserts the two agree.
pub fn frontier(plans: &[ShardPlan]) -> Vec<usize> {
    let ties = |a: &ShardPlan, b: &ShardPlan| {
        a.fps == b.fps && a.latency_s == b.latency_s
    };
    (0..plans.len())
        .filter(|&i| {
            !(0..plans.len()).any(|j| j != i && plan_dominates(&plans[j], &plans[i]))
                && !(0..i).any(|j| ties(&plans[j], &plans[i]))
        })
        .collect()
}

fn argmax(plans: &[ShardPlan], key: impl Fn(&ShardPlan) -> f64) -> usize {
    let mut best = 0;
    for i in 1..plans.len() {
        if key(&plans[i]) > key(&plans[best]) {
            best = i;
        }
    }
    best
}

/// JSON encoding of one plan: per-tenant allocation (slice sizes, resource
/// use, per-stage `(C', M', K)`) plus the objective values.
pub fn plan_to_json(plan: &ShardPlan) -> Value {
    let tenants: Vec<Value> = plan
        .tenants
        .iter()
        .zip(&plan.fps)
        .enumerate()
        .map(|(i, (t, &fps))| {
            let stages: Vec<Value> = t
                .alloc
                .stages
                .iter()
                .map(|s| {
                    obj(vec![
                        ("layer", Value::Str(t.alloc.net.layers[s.layer_idx].label())),
                        ("cp", num(s.cfg.cp)),
                        ("mp", num(s.cfg.mp)),
                        ("k", num(s.cfg.k)),
                    ])
                })
                .collect();
            let mut pairs = vec![
                ("model", Value::Str(t.alloc.net.name.clone())),
                ("bits", num(t.alloc.mode.bits())),
                ("dsp_parts", num(t.dsp_parts)),
                ("bram_parts", num(t.bram_parts)),
                ("dsps", num(t.report.dsps)),
                ("bram18", num(t.report.bram18)),
                ("fps", Value::Num(fps)),
                ("gops", Value::Num(t.report.gops)),
                ("stages", Value::Arr(stages)),
            ];
            if let Some(sims) = &plan.sim {
                pairs.push(("sim_fps", Value::Num(sims[i].fps)));
                pairs.push((
                    "sim_cycles_per_frame",
                    Value::Num(sims[i].cycles_per_frame),
                ));
            }
            obj(pairs)
        })
        .collect();
    let mut pairs = vec![
        ("schedule", Value::Str(plan.regime.label().to_string())),
        ("min_fps", Value::Num(plan.min_fps)),
        ("weighted_fps", Value::Num(plan.weighted_fps)),
        (
            "latency_s",
            Value::Arr(plan.latency_s.iter().map(|&l| Value::Num(l)).collect()),
        ),
        ("tenants", Value::Arr(tenants)),
    ];
    match &plan.regime {
        Regime::Spatial => {}
        // Degenerate lone-tenant schedule: continuous solo operation — the
        // slice/period numbers would be 0/0 noise, so mark it instead.
        Regime::Temporal(info) if info.period_cycles == 0 => {
            pairs.push(("continuous_solo", Value::Bool(true)));
        }
        Regime::Temporal(info) => {
            pairs.push((
                "time_parts",
                Value::Arr(info.time_parts.iter().map(|&p| num(p)).collect()),
            ));
            pairs.push((
                "interleave",
                Value::Arr(info.interleave.iter().map(|&k| num(k)).collect()),
            ));
            pairs.push(("overlay", Value::Bool(info.overlay)));
            pairs.push(("quantum_cycles", Value::Num(info.quantum_cycles as f64)));
            pairs.push(("period_cycles", Value::Num(info.period_cycles as f64)));
            pairs.push((
                "frames_per_slice",
                Value::Arr(info.frames.iter().map(|&f| num(f)).collect()),
            ));
            pairs.push((
                "reconfig_cycles",
                Value::Arr(info.reconfig_cycles.iter().map(|&c| Value::Num(c as f64)).collect()),
            ));
            pairs.push((
                "slices",
                Value::Arr(
                    info.slices
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("tenant", num(s.tenant)),
                                ("parts", num(s.parts)),
                                ("frames", num(s.frames)),
                                ("reconfig_cycles", Value::Num(s.reconfig_cycles as f64)),
                                ("overlap_cycles", Value::Num(s.overlap_cycles as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
            pairs.push(("dead_frac", Value::Num(info.dead_frac)));
        }
    }
    obj(pairs)
}

/// JSON encoding of a whole search: the frontier plans plus the two
/// scalarized picks (`flexipipe shard --json`).
pub fn result_to_json(r: &ShardResult, steps: usize) -> Value {
    obj(vec![
        ("steps", num(steps)),
        ("feasible_plans", num(r.plans.len())),
        (
            "search",
            obj(vec![
                ("lattice_nodes", num(r.stats.lattice_nodes)),
                ("pruned_nodes", num(r.stats.pruned_nodes)),
                ("bound_skipped", num(r.stats.bound_skipped)),
                ("alloc_calls", num(r.stats.alloc_calls)),
            ]),
        ),
        (
            "frontier",
            Value::Arr(r.frontier.iter().map(|&i| plan_to_json(&r.plans[i])).collect()),
        ),
        ("best_min_fps", plan_to_json(&r.plans[r.best_min])),
        ("best_weighted_fps", plan_to_json(&r.plans[r.best_weighted])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;

    #[test]
    fn compositions_count_and_sum() {
        // C(steps-1, n-1): 2 tenants over 16 steps → 15 splits.
        let c = compositions(16, 2);
        assert_eq!(c.len(), 15);
        assert!(c.iter().all(|v| v.iter().sum::<usize>() == 16));
        assert!(c.iter().all(|v| v.iter().all(|&p| p >= 1)));
        assert_eq!(compositions(6, 3).len(), 10); // C(5,2)
        assert_eq!(compositions(4, 1), vec![vec![4]]);
    }

    #[test]
    fn sub_board_full_share_is_identity() {
        let b = zc706();
        let s = sub_board(&b, 16, 16, 16);
        assert_eq!(s, b);
    }

    #[test]
    fn sub_board_partitions_never_oversubscribe() {
        let b = zc706();
        for splits in compositions(16, 3) {
            let subs: Vec<Board> = splits.iter().map(|&p| sub_board(&b, p, p, 16)).collect();
            assert!(subs.iter().map(|s| s.dsps).sum::<usize>() <= b.dsps);
            assert!(subs.iter().map(|s| s.bram36).sum::<usize>() <= b.bram36);
            assert!(
                subs.iter().map(|s| s.ddr_bytes_per_sec).sum::<f64>()
                    <= b.ddr_bytes_per_sec * (1.0 + 1e-9)
            );
        }
    }

    #[test]
    fn two_small_tenants_shard_a_zedboard() {
        let sh = Sharder {
            steps: 8,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        assert!(!r.plans.is_empty());
        assert!(!r.frontier.is_empty());
        for p in &r.plans {
            assert_eq!(p.tenants.len(), 2);
            assert!(p.fps.iter().all(|&f| f > 0.0));
            // Partition safety: slices sum within the physical board.
            let dsps: usize = p.tenants.iter().map(|t| t.report.dsps).sum();
            let bram: usize = p.tenants.iter().map(|t| t.report.bram18).sum();
            assert!(dsps <= zedboard().dsps, "{dsps} DSPs oversubscribed");
            assert!(bram <= zedboard().bram18(), "{bram} BRAM18 oversubscribed");
        }
        // The frontier is non-dominated under the merged
        // (fps, latency) objective.
        for &i in &r.frontier {
            for &j in &r.frontier {
                if i != j {
                    assert!(!plan_dominates(&r.plans[j], &r.plans[i]));
                }
            }
        }
        // Every plan carries the latency axis.
        for p in &r.plans {
            assert_eq!(p.latency_s.len(), 2);
            assert!(p.latency_s.iter().all(|&l| l > 0.0 && l.is_finite()));
        }
    }

    #[test]
    fn single_tenant_shard_is_the_plain_allocator() {
        use crate::alloc::Allocator;
        let sh = Sharder::new(zc706(), vec![Tenant::new(zoo::zf(), QuantMode::W16A16)]);
        let r = sh.search().unwrap();
        assert_eq!(r.plans.len(), 1);
        let plain = FlexAllocator::default()
            .allocate(&zoo::zf(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let shard_alloc = &r.plans[0].tenants[0].alloc;
        for (a, b) in shard_alloc.stages.iter().zip(&plain.stages) {
            assert_eq!(a.cfg, b.cfg);
        }
        assert_eq!(
            r.plans[0].tenants[0].report.fps.to_bits(),
            plain.evaluate().fps.to_bits()
        );
    }

    #[test]
    fn temporal_mode_produces_consistent_schedules() {
        let sh = Sharder {
            steps: 8,
            schedule: ScheduleMode::Temporal,
            max_period_s: 0.2,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        assert!(!r.plans.is_empty());
        let freq = zedboard().freq_hz;
        for p in &r.plans {
            let Regime::Temporal(info) = &p.regime else {
                panic!("temporal mode emitted a spatial plan")
            };
            assert_eq!(info.time_parts.iter().sum::<usize>(), 8);
            // fps is exactly the analytic schedule: frames·f/period.
            for (i, &f) in info.frames.iter().enumerate() {
                assert!(f >= 1);
                let want = f as f64 * freq / info.period_cycles as f64;
                assert_eq!(p.fps[i].to_bits(), want.to_bits());
            }
            // Every tenant holds the whole board during its slice.
            assert!(p.tenants.iter().all(|t| t.dsp_parts == 8 && t.bram_parts == 8));
        }
    }

    #[test]
    fn auto_mode_merges_both_regimes_into_one_frontier() {
        let sh = Sharder {
            steps: 8,
            schedule: ScheduleMode::Auto,
            max_period_s: 0.2,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        let spatial = r.plans.iter().filter(|p| !p.regime.is_temporal()).count();
        let temporal = r.plans.iter().filter(|p| p.regime.is_temporal()).count();
        assert!(spatial > 0, "auto must include the spatial split space");
        assert!(temporal > 0, "auto must include temporal schedules");
        // The frontier is non-dominated across the *union* of regimes.
        for &i in &r.frontier {
            for (j, p) in r.plans.iter().enumerate() {
                assert!(
                    j == i || !plan_dominates(p, &r.plans[i]),
                    "frontier member {i} dominated by plan {j}"
                );
            }
        }
        // And auto's frontier objectives are at least as good as either
        // regime alone.
        let solo = |mode| {
            Sharder {
                schedule: mode,
                ..sh.clone()
            }
            .search()
            .unwrap()
        };
        let s = solo(ScheduleMode::Spatial);
        let t = solo(ScheduleMode::Temporal);
        let eps = 1e-9;
        assert!(
            r.plans[r.best_min].min_fps >= s.plans[s.best_min].min_fps - eps
                && r.plans[r.best_min].min_fps >= t.plans[t.best_min].min_fps - eps
        );
    }

    #[test]
    fn slo_parsing_and_application() {
        let slos = parse_slos("vgg16=33ms, zf=0.05s,lenet=2000us").unwrap();
        assert_eq!(slos.len(), 3);
        assert_eq!(slos[0].0, "vgg16");
        assert!((slos[0].1 - 0.033).abs() < 1e-12);
        assert_eq!(slos[1].0, "zf");
        assert!((slos[1].1 - 0.05).abs() < 1e-12);
        assert!((slos[2].1 - 0.002).abs() < 1e-12);
        // Unitless durations are rejected — a bare `33` silently meaning
        // 33 seconds was a 1000× footgun — and the error names the
        // accepted suffixes.
        let err = parse_slos("x=0.25").unwrap_err().to_string();
        assert!(err.contains("s, ms, us, m, or h"), "{err}");
        assert!(parse_slos("vgg16=33").is_err());
        assert!(parse_slos("vgg16").is_err());
        assert!(parse_slos("vgg16=-3ms").is_err());
        assert!(parse_slos("vgg16=soon").is_err());
        assert!(parse_slos("").is_err());

        let mut tenants = vec![Tenant::new(zoo::zf(), QuantMode::W8A8)];
        assert!(apply_slos(&mut tenants, &[("nope".to_string(), 0.1)]).is_err());
        apply_slos(&mut tenants, &[("zf".to_string(), 0.1)]).unwrap();
        assert_eq!(tenants[0].slo_s, Some(0.1));
        // The builder form agrees.
        assert_eq!(
            Tenant::new(zoo::zf(), QuantMode::W8A8).with_slo(0.1).slo_s,
            Some(0.1)
        );
    }

    #[test]
    fn min_fps_parsing_and_application() {
        let floors = parse_min_fps("vgg16=25, alexnet=120.5").unwrap();
        assert_eq!(floors.len(), 2);
        assert_eq!(floors[0].0, "vgg16");
        assert!((floors[0].1 - 25.0).abs() < 1e-12);
        assert!((floors[1].1 - 120.5).abs() < 1e-12);
        assert!(parse_min_fps("vgg16").is_err());
        assert!(parse_min_fps("vgg16=-3").is_err());
        assert!(parse_min_fps("vgg16=fast").is_err());
        assert!(parse_min_fps("").is_err());

        let mut tenants = vec![Tenant::new(zoo::zf(), QuantMode::W8A8)];
        assert!(apply_min_fps(&mut tenants, &[("nope".to_string(), 10.0)]).is_err());
        apply_min_fps(&mut tenants, &[("zf".to_string(), 10.0)]).unwrap();
        assert_eq!(tenants[0].min_fps, Some(10.0));
        assert_eq!(
            Tenant::new(zoo::zf(), QuantMode::W8A8).with_min_fps(10.0).min_fps,
            Some(10.0)
        );
    }

    #[test]
    fn min_fps_floor_prunes_spatial_plans() {
        let base = Sharder {
            steps: 8,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let free = base.search().unwrap();
        // A floor strictly between tenant 1's worst and best rates must
        // prune the plans below it and keep the ones above.
        let lo = free.plans.iter().map(|p| p.fps[1]).fold(f64::INFINITY, f64::min);
        let hi = free
            .plans
            .iter()
            .map(|p| p.fps[1])
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < hi, "fixture needs fps spread on tenant 1");
        let floor = 0.5 * (lo + hi);
        let mut floored = base.clone();
        floored.tenants[1].min_fps = Some(floor);
        let kept = floored.search().unwrap();
        let expect = free.plans.iter().filter(|p| p.fps[1] >= floor).count();
        assert_eq!(kept.plans.len(), expect);
        assert!(kept.plans.len() < free.plans.len(), "floor must prune");
        assert!(kept.plans.iter().all(|p| p.fps[1] >= floor));
        // The floored best-min pick serves tenant 1 at least at the floor.
        assert!(kept.plans[kept.best_min].fps[1] >= floor);
        // An unachievable floor makes the search fail with the real cause.
        let mut starved = base.clone();
        starved.tenants[1].min_fps = Some(hi * 10.0);
        let err = starved.search().unwrap_err();
        assert!(err.to_string().contains("min-fps"), "{err}");
    }

    #[test]
    fn overlay_mode_parses_and_searches() {
        assert_eq!(ScheduleMode::parse("overlay").unwrap(), ScheduleMode::Overlay);
        assert_eq!(ScheduleMode::Overlay.label(), "overlay");
        let sh = Sharder {
            steps: 4,
            schedule: ScheduleMode::Overlay,
            max_period_s: 0.2,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                    Tenant::new(zoo::lenet(), QuantMode::W8A8),
                ],
            )
        };
        let r = sh.search().unwrap();
        assert!(!r.plans.is_empty());
        for p in &r.plans {
            assert!(p.regime.is_temporal());
            assert_eq!(p.regime.label(), "overlay");
            let Regime::Temporal(info) = &p.regime else { unreachable!() };
            assert!(info.overlay);
            assert!(info.slices.iter().all(|s| s.reconfig_cycles == 0));
        }
    }

    /// Bitwise (fps, latency) signature of the indexed plans — the
    /// content-identity currency for pruned-vs-exhaustive comparisons
    /// (plan *indices* may shift when pruning shrinks the listing).
    fn plan_keys(r: &ShardResult, idx: &[usize]) -> Vec<(Vec<u64>, Vec<u64>)> {
        idx.iter()
            .map(|&i| {
                (
                    r.plans[i].fps.iter().map(|f| f.to_bits()).collect(),
                    r.plans[i].latency_s.iter().map(|l| l.to_bits()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn prune_is_exact_and_engages_on_the_paper_workload() {
        // The tentpole workload: vgg16 + alexnet on a ZC706 at 16 bit,
        // 1/16 quanta. All counts are pinned against an independent
        // Python mirror of the staircase sweep.
        let mk = |prune: bool| Sharder {
            prune,
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::vgg16(), QuantMode::W16A16),
                    Tenant::new(zoo::alexnet(), QuantMode::W16A16),
                ],
            )
        };
        let full = mk(false).search().unwrap();
        let pruned = mk(true).search().unwrap();

        assert_eq!(full.plans.len(), 29);
        assert_eq!(full.frontier.len(), 11);
        // 2 tenants × 15² staircase cells + 15² assemblies.
        assert_eq!(full.stats.lattice_nodes, 675);
        // Monotone staircase reuse keeps the allocator-call count far
        // below the 450 cells.
        assert_eq!(full.stats.alloc_calls, 35);
        // Rule-based skipping alone covers 415/675 = 61.5% of the
        // lattice — comfortably above the 20% acceptance bar.
        assert_eq!(full.stats.pruned_nodes, 415);
        assert!(full.stats.pruned_nodes * 5 >= full.stats.lattice_nodes);

        // Unconstrained, the optimistic assembly bounds are never
        // dominated by a real incumbent: pruning is a no-op and the
        // listing survives verbatim.
        assert_eq!(pruned.stats.bound_skipped, 0);
        assert_eq!(pruned.plans.len(), full.plans.len());
        let all: Vec<usize> = (0..full.plans.len()).collect();
        assert_eq!(plan_keys(&full, &all), plan_keys(&pruned, &all));
        assert_eq!(full.frontier, pruned.frontier);

        // Tie-dedup regression: the frontier carries no duplicate
        // objective vectors.
        let keys = plan_keys(&full, &full.frontier);
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b, "frontier carries an exact objective tie");
            }
        }
    }

    #[test]
    fn floor_bound_prunes_assemblies_without_changing_results() {
        // A 6 fps floor on vgg16 lets the admissible per-tenant fps
        // upper bound reject whole dsp-compositions before any BRAM
        // split is scored; the exhaustive path instead instantiates
        // them and drops them at the Rule-2 floor check. Same plans,
        // same frontier. Counts pinned against the Python mirror.
        let mk = |prune: bool| Sharder {
            prune,
            ..Sharder::new(
                zc706(),
                vec![
                    Tenant::new(zoo::vgg16(), QuantMode::W16A16).with_min_fps(6.0),
                    Tenant::new(zoo::alexnet(), QuantMode::W16A16),
                ],
            )
        };
        let full = mk(false).search().unwrap();
        let pruned = mk(true).search().unwrap();

        assert_eq!(full.plans.len(), 9);
        assert_eq!(full.stats.bound_skipped, 0);
        // One dsp composition fails the optimistic floor bound → all 15
        // of its BRAM splits are skipped unscored.
        assert_eq!(pruned.stats.bound_skipped, 15);
        assert_eq!(pruned.stats.pruned_nodes, full.stats.pruned_nodes + 15);
        assert_eq!(pruned.plans.len(), 9);
        let all: Vec<usize> = (0..full.plans.len()).collect();
        assert_eq!(plan_keys(&full, &all), plan_keys(&pruned, &all));
        assert_eq!(full.frontier, pruned.frontier);
        assert_eq!(full.frontier.len(), 3);
    }

    #[test]
    fn pruned_search_is_exact_across_regimes() {
        // Property: for every sharing regime, with and without fps
        // floors, the pruned search reproduces the exhaustive search's
        // frontier and objective-pick contents bit for bit.
        for schedule in [
            ScheduleMode::Spatial,
            ScheduleMode::Temporal,
            ScheduleMode::Overlay,
            ScheduleMode::Auto,
        ] {
            let mk = |prune: bool, floor: Option<f64>| Sharder {
                steps: 8,
                schedule,
                max_period_s: 0.2,
                prune,
                ..Sharder::new(
                    zedboard(),
                    vec![
                        Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                        Tenant {
                            min_fps: floor,
                            ..Tenant::new(zoo::lenet(), QuantMode::W8A8)
                        },
                    ],
                )
            };
            let check = |floor: Option<f64>| {
                let full = mk(false, floor).search().unwrap();
                let pruned = mk(true, floor).search().unwrap();
                assert_eq!(
                    plan_keys(&full, &full.frontier),
                    plan_keys(&pruned, &pruned.frontier),
                    "{schedule:?} floor {floor:?}: frontier diverged under pruning"
                );
                for (a, b) in [
                    (full.best_min, pruned.best_min),
                    (full.best_weighted, pruned.best_weighted),
                ] {
                    assert_eq!(
                        plan_keys(&full, &[a]),
                        plan_keys(&pruned, &[b]),
                        "{schedule:?} floor {floor:?}: objective pick diverged"
                    );
                }
                assert_eq!(full.stats.lattice_nodes, pruned.stats.lattice_nodes);
                assert!(pruned.stats.pruned_nodes >= full.stats.pruned_nodes);
                full
            };
            let free = check(None);
            // A floor strictly inside tenant 1's fps spread exercises the
            // bound against a binding constraint.
            let lo = free.plans.iter().map(|p| p.fps[1]).fold(f64::INFINITY, f64::min);
            let hi = free
                .plans
                .iter()
                .map(|p| p.fps[1])
                .fold(f64::NEG_INFINITY, f64::max);
            if lo < hi {
                check(Some(0.5 * (lo + hi)));
            }
        }
    }

    #[test]
    fn weighted_objective_responds_to_weights() {
        let mk = |w1: f64, w2: f64| Sharder {
            steps: 8,
            ..Sharder::new(
                zedboard(),
                vec![
                    Tenant {
                        weight: w1,
                        ..Tenant::new(zoo::tinycnn(), QuantMode::W8A8)
                    },
                    Tenant {
                        weight: w2,
                        ..Tenant::new(zoo::lenet(), QuantMode::W8A8)
                    },
                ],
            )
        };
        let a = mk(1.0, 1.0).search().unwrap();
        let b = mk(10.0, 1.0).search().unwrap();
        // Heavier weight on tenant 0 can only shift the weighted pick
        // toward plans serving tenant 0 at least as fast.
        assert!(
            b.plans[b.best_weighted].fps[0] >= a.plans[a.best_weighted].fps[0] - 1e-9
        );
    }
}
