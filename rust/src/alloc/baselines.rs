//! The three comparison architectures of Table I, rebuilt on the same
//! substrate so the comparison isolates *allocation flexibility* — the
//! paper's actual claim.
//!
//! - [`DnnBuilderAllocator`] — [3]: layer-wise pipeline like this work, but
//!   with DNNBuilder's constraints: every channel parallelism is a power of
//!   two and the input parallelism of layer *i* must equal the output
//!   parallelism of layer *i−1* (its activation buffer can't re-shape).
//!   Those constraints are exactly what the paper's Sec. 2.2 blames for
//!   [3]'s lower DSP utilization.
//! - [`FusionAllocator`] — [2]: heterogeneous fusion pipeline: consecutive
//!   conv layers fuse into groups that execute *sequentially* (only one
//!   group's engines exist at a time conceptually; here: one group active),
//!   3×3/stride-1 convs use Winograd (4× multiplication reduction), and the
//!   design closes timing at 100 MHz (Table I).
//! - [`RecurrentAllocator`] — [1]: one fixed `Tn×Tm` PE array processes
//!   layers one-by-one; intermediate activations spill to DDR. Runs at
//!   150 MHz (Table I).

use super::{Allocation, Allocator, ArchKind, StageAlloc};
use crate::board::Board;
use crate::engine::{self, div_ceil, EngineConfig, EngineFigures};
use crate::model::{Layer, Network};
use crate::quant::QuantMode;

/// Largest power of two `<= n` (min 1).
fn pow2_floor(n: usize) -> usize {
    if n == 0 {
        1
    } else {
        1 << (usize::BITS - 1 - n.leading_zeros())
    }
}

// ---------------------------------------------------------------------------
// DNNBuilder-style constrained pipeline [3]
// ---------------------------------------------------------------------------

/// Pipeline allocator under DNNBuilder's buffer constraints.
pub struct DnnBuilderAllocator;

impl DnnBuilderAllocator {
    /// Interface parallelisms `p[0..=n]` (p[j] = M' of compute stage j−1 =
    /// C' of compute stage j), all powers of two, greedily doubled at the
    /// interface that most relieves the bottleneck stage.
    fn solve_interfaces(net: &Network, theta: usize, compute: &[usize]) -> Vec<usize> {
        let n = compute.len();
        let dims: Vec<(usize, usize, usize)> = compute
            .iter()
            .map(|&i| match &net.layers[i] {
                Layer::Conv(c) => (c.c / c.groups, c.m, c.r * c.s),
                Layer::Fc(f) => (f.n_in, f.n_out, 1),
                Layer::Pool(_) => unreachable!("compute layers only"),
            })
            .collect();
        // caps: p[j] ≤ pow2_floor(min(M_{j-1}, C_j))
        let caps: Vec<usize> = (0..=n)
            .map(|j| {
                let up = if j == 0 { usize::MAX } else { dims[j - 1].1 };
                let down = if j == n { usize::MAX } else { dims[j].0 };
                pow2_floor(up.min(down))
            })
            .collect();
        let mut p = vec![1usize; n + 1];

        let mults = |p: &[usize]| -> usize {
            (0..n).map(|j| p[j] * p[j + 1] * dims[j].2).sum()
        };
        let cycles = |p: &[usize], j: usize| -> u64 {
            let (c, m, _) = dims[j];
            let li = &net.layers[compute[j]];
            let (h, w) = match li {
                Layer::Conv(cv) => (cv.h as u64, cv.w as u64),
                Layer::Fc(_) => (1, 1),
                Layer::Pool(_) => unreachable!(),
            };
            h * w * div_ceil(c, p[j]) as u64 * div_ceil(m, p[j + 1]) as u64
        };
        // Greedy doubling under a lexicographic (bottleneck, total) metric:
        // with many stages tied at the maximum, no single doubling reduces
        // the global worst, so the secondary sum objective keeps growth
        // balanced instead of front-loading the budget on early layers.
        //
        // Incremental evaluation: doubling interface p[j] only changes the
        // cycles of stages j−1 (its M') and j (its C') and re-doubles those
        // two stages' multiplier terms, so each candidate is scored from
        // the cached per-stage cycles with two substitutions instead of a
        // cloned vector and four full recomputation passes. Metrics are
        // exact u64 sums — decisions match the naive loop bit-for-bit.
        let mut cyc: Vec<u64> = (0..n).map(|j| cycles(&p, j)).collect();
        let mut mult_sum = mults(&p);
        // Re-doubled contribution of the stages adjacent to interface j.
        let mult_delta = |p: &[usize], j: usize| -> usize {
            (if j >= 1 { p[j - 1] * p[j] * dims[j - 1].2 } else { 0 })
                + (if j < n { p[j] * p[j + 1] * dims[j].2 } else { 0 })
        };
        loop {
            let worst0 = cyc.iter().copied().max().unwrap_or(1);
            let total0: u64 = cyc.iter().sum();
            let base = (worst0, total0);
            let mut best: Option<(usize, (u64, u64))> = None;
            for j in 0..=n {
                if p[j] * 2 > caps[j] {
                    continue;
                }
                if mult_sum + mult_delta(&p, j) > theta {
                    continue;
                }
                p[j] *= 2;
                let c_prev = if j >= 1 { cycles(&p, j - 1) } else { 0 };
                let c_self = if j < n { cycles(&p, j) } else { 0 };
                p[j] /= 2;
                let mut worst_new = 0u64;
                let mut total_new = 0u64;
                for s in 0..n {
                    let c = if j >= 1 && s == j - 1 {
                        c_prev
                    } else if j < n && s == j {
                        c_self
                    } else {
                        cyc[s]
                    };
                    worst_new = worst_new.max(c);
                    total_new += c;
                }
                let m = (worst_new.max(u64::from(n == 0)), total_new);
                if m < base && best.map_or(true, |(_, bm)| m < bm) {
                    best = Some((j, m));
                }
            }
            match best {
                Some((j, _)) => {
                    mult_sum += mult_delta(&p, j);
                    p[j] *= 2;
                    if j >= 1 {
                        cyc[j - 1] = cycles(&p, j - 1);
                    }
                    if j < n {
                        cyc[j] = cycles(&p, j);
                    }
                }
                None => break,
            }
        }
        p
    }
}

impl Allocator for DnnBuilderAllocator {
    fn arch(&self) -> ArchKind {
        ArchKind::DnnBuilder
    }

    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation> {
        net.validate()?;
        let theta = board.dsps * mode.mults_per_dsp();
        let compute = net.compute_layers();
        let p = Self::solve_interfaces(net, theta, &compute);

        let mut cfgs = vec![EngineConfig::minimal(); net.layers.len()];
        for (j, &i) in compute.iter().enumerate() {
            cfgs[i] = EngineConfig {
                cp: p[j],
                mp: p[j + 1],
                k: 1,
            };
        }
        let stages = cfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| StageAlloc {
                layer_idx: i,
                cfg: *cfg,
                figures: engine::figures(&net.layers[i], cfg, mode),
                mac_gain: 1.0,
            })
            .collect();
        let mut alloc = Allocation {
            arch: ArchKind::DnnBuilder,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: board.freq_hz,
            arch_derate: 1.0,
            groups: None,
            extra_cycles: 0,
            shared_array: false,
        };
        // DNNBuilder also pipelines rows and buffers weights; give it the
        // same Algorithm-2 bandwidth relief so the comparison isolates the
        // channel-parallelism constraints.
        super::flex::FlexAllocator::default().raise_k(net, board, mode, &mut alloc);
        Ok(alloc)
    }
}

// ---------------------------------------------------------------------------
// Fusion / Winograd pipeline [2]
// ---------------------------------------------------------------------------

/// Fusion-pipeline allocator (Winograd + sequential fused groups).
pub struct FusionAllocator;

/// Conv layers per fused group ([2] fuses a few layers at a time).
const FUSION_GROUP: usize = 3;
/// Winograd multiplication reduction for 3×3 stride-1 convs. F(2×2,3×3)
/// gives 2.25× (16 multiplies per 4 outputs vs 36 MACs); F(4×4,3×3) gives
/// 4× ("one quarter", the paper's quote for [2]'s best case) but needs
/// bigger transform buffers. [2] mixes both ("heterogeneous algorithms"),
/// so the effective gain sits between: 3.0 reproduces [2]'s reported
/// 230 GOPS @ 824 DSPs/100 MHz within the fidelity this comparison needs.
const WINOGRAD_GAIN: f64 = 3.0;
/// [2]'s clock (Table I).
const FUSION_FREQ: f64 = 100e6;

impl Allocator for FusionAllocator {
    fn arch(&self) -> ArchKind {
        ArchKind::Fusion
    }

    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation> {
        net.validate()?;
        let theta = board.dsps * mode.mults_per_dsp();
        let compute = net.compute_layers();

        // Fused groups of consecutive compute layers. The *hardware* is one
        // set of FUSION_GROUP engines sized for the heaviest group; every
        // other group time-multiplexes onto those fixed engines (that is
        // the fusion architecture's core constraint — and why its average
        // DSP efficiency trails a fully layer-wise pipeline).
        let groups: Vec<Vec<usize>> = compute.chunks(FUSION_GROUP).map(|c| c.to_vec()).collect();
        let eff_macs = |i: usize| net.layers[i].macs() as f64 / winograd_gain(&net.layers[i]);
        let heavy = groups
            .iter()
            .max_by(|a, b| {
                let sa: f64 = a.iter().map(|&i| eff_macs(i)).sum();
                let sb: f64 = b.iter().map(|&i| eff_macs(i)).sum();
                sa.partial_cmp(&sb).unwrap()
            })
            .expect("at least one group")
            .clone();

        // Size the engines on the heaviest group, power-of-2 parallelisms
        // (the Winograd transform banks require it).
        let total_heavy: f64 = heavy.iter().map(|&i| eff_macs(i)).sum();
        let mut engines: Vec<EngineConfig> = Vec::new();
        for &i in &heavy {
            let l = &net.layers[i];
            let share = ((theta as f64) * eff_macs(i) / total_heavy.max(1.0)) as usize;
            let (c_eff, m, rs) = match l {
                Layer::Conv(c) => (c.c / c.groups, c.m, c.r * c.s),
                Layer::Fc(f) => (f.n_in, f.n_out, 1),
                Layer::Pool(_) => unreachable!(),
            };
            let pairs = (share / rs).max(1);
            let cp = pow2_floor(c_eff.min(pairs));
            let mp = pow2_floor(m.min((pairs / cp).max(1)));
            engines.push(EngineConfig { cp, mp, k: 1 });
        }

        // Map every compute layer onto its position's engine; pools ride
        // along (no DSPs). Hardware is counted once: stages outside the
        // heavy group carry zero mults/dsps (they reuse the engines).
        let mut cfgs = vec![EngineConfig::minimal(); net.layers.len()];
        let mut gains = vec![1.0f64; net.layers.len()];
        let mut counted = vec![false; net.layers.len()];
        for g in &groups {
            for (j, &i) in g.iter().enumerate() {
                cfgs[i] = engines[j.min(engines.len() - 1)];
                gains[i] = winograd_gain(&net.layers[i]);
            }
        }
        for &i in &heavy {
            counted[i] = true;
        }

        let stages: Vec<StageAlloc> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let mut figures = engine::figures(l, &cfgs[i], mode);
                if l.uses_dsps() && !counted[i] {
                    // shared hardware: resources already counted in the
                    // heavy group's stages
                    figures.mults = 0;
                    figures.dsps = 0;
                }
                StageAlloc {
                    layer_idx: i,
                    cfg: cfgs[i],
                    figures,
                    mac_gain: gains[i],
                }
            })
            .collect();

        // Inter-group activation spills over DDR: each group boundary
        // writes + reads the intermediate map.
        let bpc = board.ddr_bytes_per_sec / FUSION_FREQ;
        let mut spill_bytes = 0u64;
        for g in groups.iter().take(groups.len().saturating_sub(1)) {
            let &last = g.last().unwrap();
            spill_bytes += 2 * out_bytes(&net.layers[last], mode);
        }
        let extra_cycles = (spill_bytes as f64 / bpc) as u64;

        // Stage-index groups for sequential evaluation: attach pools to
        // the group of the preceding compute layer.
        let mut stage_groups: Vec<Vec<usize>> = groups.clone();
        for (i, l) in net.layers.iter().enumerate() {
            if !l.uses_dsps() {
                let host = stage_groups
                    .iter_mut()
                    .find(|g| g.iter().any(|&j| j + 1 == i));
                match host {
                    Some(g) => g.push(i),
                    None => stage_groups[0].push(i),
                }
            }
        }

        Ok(Allocation {
            arch: ArchKind::Fusion,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: FUSION_FREQ,
            arch_derate: 1.0,
            groups: Some(stage_groups),
            extra_cycles,
            shared_array: false,
        })
    }
}

/// Winograd applies to 3×3 stride-1 convolutions.
fn winograd_gain(layer: &Layer) -> f64 {
    match layer {
        Layer::Conv(c) if c.r == 3 && c.s == 3 && c.stride == 1 && c.groups == 1 => WINOGRAD_GAIN,
        _ => 1.0,
    }
}

/// Output activation bytes of a stage.
fn out_bytes(layer: &Layer, mode: QuantMode) -> u64 {
    let elems = match layer {
        Layer::Conv(c) => c.m * c.h * c.w,
        Layer::Pool(p) => p.c * p.h * p.w,
        Layer::Fc(f) => f.n_out,
    };
    (elems * mode.act_bytes()) as u64
}

// ---------------------------------------------------------------------------
// Recurrent single-array design [1]
// ---------------------------------------------------------------------------

/// Recurrent allocator: one `Tn×Tm` array, layers sequential, activations
/// spilled to DDR between layers.
pub struct RecurrentAllocator;

/// [1]'s clock (Table I).
const RECURRENT_FREQ: f64 = 150e6;

impl Allocator for RecurrentAllocator {
    fn arch(&self) -> ArchKind {
        ArchKind::Recurrent
    }

    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation> {
        net.validate()?;
        let theta = board.dsps * mode.mults_per_dsp();
        let compute = net.compute_layers();

        // Search the fixed array shape (power-of-2 Tn/Tm — the mapping
        // granularity [1]'s compiler supports) minimizing total cycles.
        let mut best: Option<(usize, usize, u64)> = None;
        let mut tn = 1;
        while tn <= 512 {
            let mut tm = 1;
            while tm <= 512 {
                if tn * tm <= theta {
                    let total: u64 = compute
                        .iter()
                        .map(|&i| recurrent_cycles(&net.layers[i], tn, tm))
                        .sum();
                    if best.map_or(true, |(_, _, b)| total < b) {
                        best = Some((tn, tm, total));
                    }
                }
                tm *= 2;
            }
            tn *= 2;
        }
        let (tn, tm, _) = best.expect("array search");

        let stages: Vec<StageAlloc> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let cycles = if l.uses_dsps() {
                    recurrent_cycles(l, tn, tm)
                } else {
                    // pooling rides along with the producing layer's pass
                    0
                };
                let mults = if l.uses_dsps() { tn * tm } else { 0 };
                StageAlloc {
                    layer_idx: i,
                    cfg: EngineConfig { cp: tn, mp: tm, k: 1 },
                    figures: EngineFigures {
                        mults,
                        dsps: div_ceil(mults, mode.mults_per_dsp()),
                        t_row: cycles,
                        groups_per_frame: 1,
                        macs_per_group: l.macs(),
                        weight_bytes_per_group: l.weights() * mode.act_bytes() as u64,
                    },
                    mac_gain: 1.0,
                }
            })
            .collect();

        // Every intermediate activation writes to and reads back from DDR.
        let bpc = board.ddr_bytes_per_sec / RECURRENT_FREQ;
        let spill: u64 = net
            .layers
            .iter()
            .take(net.layers.len().saturating_sub(1))
            .map(|l| 2 * out_bytes(l, mode))
            .sum();
        let extra_cycles = (spill as f64 / bpc) as u64;

        let groups = Some((0..net.layers.len()).map(|i| vec![i]).collect());
        Ok(Allocation {
            arch: ArchKind::Recurrent,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: RECURRENT_FREQ,
            arch_derate: 1.0,
            groups,
            extra_cycles,
            shared_array: true,
        })
    }
}

/// Cycles for one layer on a `Tn×Tm` array with the kernel taps processed
/// sequentially ([1]'s loop order).
fn recurrent_cycles(layer: &Layer, tn: usize, tm: usize) -> u64 {
    match layer {
        Layer::Conv(c) => {
            let c_eff = c.c / c.groups;
            (c.h * c.w) as u64
                * (c.r * c.s) as u64
                * div_ceil(c_eff, tn) as u64
                * div_ceil(c.m, tm) as u64
        }
        Layer::Fc(f) => div_ceil(f.n_in, tn) as u64 * div_ceil(f.n_out, tm) as u64,
        Layer::Pool(_) => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flex::FlexAllocator;
    use crate::alloc::Allocator;
    use crate::board::zc706;
    use crate::model::zoo;

    #[test]
    fn pow2_floor_basics() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(512), 512);
        assert_eq!(pow2_floor(513), 512);
    }

    #[test]
    fn dnnbuilder_respects_constraints() {
        let net = zoo::vgg16();
        let alloc = DnnBuilderAllocator
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        let compute = net.compute_layers();
        // matched interfaces + powers of two
        for w in compute.windows(2) {
            let a = &alloc.stages[w[0]].cfg;
            let b = &alloc.stages[w[1]].cfg;
            assert_eq!(a.mp, b.cp, "interface must match");
        }
        for &i in &compute {
            let c = &alloc.stages[i].cfg;
            assert!(c.cp.is_power_of_two() && c.mp.is_power_of_two());
        }
        assert!(alloc.evaluate().dsps <= 900);
    }

    #[test]
    fn flex_beats_dnnbuilder_on_all_paper_nets() {
        // The paper's headline: flexibility buys 23–50% over [3].
        for net in zoo::paper_nets() {
            let f = FlexAllocator::default()
                .allocate(&net, &zc706(), QuantMode::W16A16)
                .unwrap()
                .evaluate();
            let d = DnnBuilderAllocator
                .allocate(&net, &zc706(), QuantMode::W16A16)
                .unwrap()
                .evaluate();
            assert!(
                f.gops > d.gops,
                "{}: flex {:.0} GOPS should beat dnnbuilder {:.0}",
                net.name,
                f.gops,
                d.gops
            );
        }
    }

    #[test]
    fn recurrent_lags_pipelines() {
        let net = zoo::vgg16();
        let f = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap()
            .evaluate();
        let r = RecurrentAllocator
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap()
            .evaluate();
        assert!(
            f.gops / r.gops > 1.8,
            "flex {:.0} GOPS vs recurrent {:.0}: expected ≥1.8x gap (paper: 2.58x)",
            f.gops,
            r.gops
        );
    }

    #[test]
    fn fusion_marks_winograd_stages() {
        let net = zoo::vgg16();
        let alloc = FusionAllocator
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        // all 13 VGG convs are 3×3/s1 → Winograd
        let wino = alloc.stages.iter().filter(|s| s.mac_gain > 1.0).count();
        assert_eq!(wino, 13);
        assert!((alloc.freq_hz - 100e6).abs() < 1.0);
    }

    #[test]
    fn recurrent_counts_shared_array_once() {
        let net = zoo::alexnet();
        let alloc = RecurrentAllocator
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        assert!(r.dsps <= 900, "shared array must not be double counted");
    }
}
