//! The paper's allocation algorithms.
//!
//! **Algorithm 1 — computation resources** (Sec. 4.1): pre-allocate
//! multipliers to layers proportionally to their MAC workload `π_i`,
//! rounded to `R_i·S_i` blocks, then greedily feed the slowest layer;
//! finally decompose each `θ_i` into `C'_i × M'_i`.
//!
//! **Algorithm 2 — BRAM and bandwidth** (Sec. 4.2): while the DDR
//! bandwidth demanded by weight reloading exceeds the board's `β`, raise
//! the row parallelism `K` of the heaviest-traffic layer (each increment
//! reuses weights across one more activation row) — as long as the extra
//! activation-buffer rows still fit the BRAM budget `α`.
//!
//! The flexible activation buffer (engine::linebuf) is what frees Algorithm
//! 1 from DNNBuilder's constraints: `C'_i` needn't equal `M'_{i−1}` and
//! nothing needs to be a power of two, so the decomposition can chase exact
//! divisors of `C`/`M` and the greedy loop can hand out single `R·S` blocks.

use super::{Allocation, Allocator, ArchKind, StageAlloc, TOP_BRAM18};
use crate::board::Board;
use crate::engine::{self, buffer_geometry, div_ceil, EngineConfig};
use crate::model::{Layer, Network};
use crate::quant::QuantMode;

/// The paper's allocator ("This Work" in Table I).
#[derive(Debug, Clone)]
pub struct FlexAllocator {
    /// Cap on Algorithm 2 iterations (defensive; the loop is monotone).
    pub max_k_steps: usize,
    /// Reserve a fraction of DSPs for the top-level interconnect? The paper
    /// uses all 900 on VGG16/ZC706; default 0.
    pub dsp_reserve: usize,
    /// Algorithm 2 targets `B ≤ bw_margin·β`: DDR never sustains its peak
    /// (refresh, bank turnaround, request interleaving), so allocating to
    /// 100% of β produces a design the cycle simulator shows stalling.
    pub bw_margin: f64,
}

impl Default for FlexAllocator {
    fn default() -> Self {
        FlexAllocator {
            max_k_steps: 4096,
            dsp_reserve: 0,
            bw_margin: 0.75,
        }
    }
}

/// Decompose a multiplier budget into `(C', M')` for one layer.
///
/// Minimizes the phase count `ceil(C/C')·ceil(M/M')` subject to
/// `C'·M'·R·S ≤ budget`; ties prefer fewer multipliers (return the spare to
/// the pool), then larger `C'` (wider accumulation = shallower psum tree).
pub fn decompose(c_eff: usize, m: usize, rs: usize, budget_mults: usize) -> (usize, usize) {
    let pairs = (budget_mults / rs).max(1);
    let mut best = (1usize, 1usize);
    let mut best_phases = u64::MAX;
    let mut best_mults = usize::MAX;
    for cp in 1..=c_eff.min(pairs) {
        let mp = (pairs / cp).min(m);
        if mp == 0 {
            continue;
        }
        // Shrink to the smallest mp with the same phase count (saves mults).
        let phases_m = div_ceil(m, mp);
        let mp = div_ceil(m, phases_m);
        let phases = (div_ceil(c_eff, cp) as u64) * (phases_m as u64);
        let mults = cp * mp * rs;
        if phases < best_phases || (phases == best_phases && mults < best_mults) {
            best_phases = phases;
            best_mults = mults;
            best = (cp, mp);
        }
    }
    best
}

/// π_i for a compute layer (Alg. 1 line 1).
fn workload(layer: &Layer) -> u64 {
    layer.macs()
}

/// `R·S` rounding granule (Alg. 1 line 3); FCs use 1.
fn granule(layer: &Layer) -> usize {
    match layer {
        Layer::Conv(c) => c.r * c.s,
        Layer::Fc(_) => 1,
        Layer::Pool(_) => 0,
    }
}

/// (C_eff, M) seen by the PE array.
fn dims(layer: &Layer) -> (usize, usize) {
    match layer {
        Layer::Conv(c) => (c.c / c.groups, c.m),
        Layer::Fc(f) => (f.n_in, f.n_out),
        Layer::Pool(_) => (0, 0),
    }
}

impl FlexAllocator {
    /// Algorithm 1: returns per-layer `(C', M')` using up to Θ multipliers.
    fn algorithm1(&self, net: &Network, theta_total: usize) -> Vec<EngineConfig> {
        let compute: Vec<usize> = net.compute_layers();
        let pis: Vec<u64> = compute.iter().map(|&i| workload(&net.layers[i])).collect();
        let pi_sum: u64 = pis.iter().sum();

        // Lines 2–3: proportional pre-allocation rounded to R·S granules.
        let mut theta: Vec<usize> = compute
            .iter()
            .zip(&pis)
            .map(|(&i, &pi)| {
                let l = &net.layers[i];
                let g = granule(l);
                let ideal = (pi as f64 * theta_total as f64 / pi_sum as f64) as usize;
                ((ideal / g).max(1)) * g
            })
            .collect();

        // Pre-allocation may overshoot after rounding-up: trim the most
        // over-served layers (smallest π/θ) back one granule at a time.
        loop {
            let used: usize = theta.iter().sum();
            if used <= theta_total {
                break;
            }
            let j = (0..theta.len())
                .filter(|&j| theta[j] > granule(&net.layers[compute[j]]))
                .min_by(|&a, &b| {
                    let ra = pis[a] as f64 / theta[a] as f64;
                    let rb = pis[b] as f64 / theta[b] as f64;
                    ra.partial_cmp(&rb).unwrap()
                });
            match j {
                Some(j) => theta[j] -= granule(&net.layers[compute[j]]),
                None => break,
            }
        }

        // Lines 4–8: greedy — keep feeding the slowest layer. The paper
        // adds one R·S granule at a time; we strengthen this to "grow the
        // bottleneck's θ to the next value that strictly shortens it",
        // because the decomposition only improves at divisor steps (adding
        // 9 multipliers to a 64-channel layer at C'=1,M'=11 changes
        // nothing until the phase count drops). Same fixpoint as the
        // paper's loop, fewer wasted DSPs.
        let cycles_of = |j: usize, theta_j: usize| -> u64 {
            let l = &net.layers[compute[j]];
            let (c_eff, m) = dims(l);
            let (cp, mp) = decompose(c_eff, m, granule(l), theta_j);
            let phases = div_ceil(c_eff, cp) as u64 * div_ceil(m, mp) as u64;
            let spatial = match l {
                Layer::Conv(c) => (c.h * c.w) as u64,
                Layer::Fc(_) => 1,
                Layer::Pool(_) => unreachable!(),
            };
            spatial * phases
        };
        loop {
            let used: usize = theta.iter().sum();
            let avail = theta_total.saturating_sub(used);
            if avail == 0 {
                break;
            }
            // Bottleneck layer under the current assignment.
            let (b, cur) = (0..theta.len())
                .map(|j| (j, cycles_of(j, theta[j])))
                .max_by_key(|&(_, c)| c)
                .unwrap();
            let g = granule(&net.layers[compute[b]]);
            let (c_eff, m) = dims(&net.layers[compute[b]]);
            let cap = c_eff * m * g;
            // Smallest affordable growth that strictly reduces the
            // bottleneck's cycles.
            let mut grown = None;
            let mut t = theta[b] + g;
            while t <= cap.min(theta[b] + avail) {
                if cycles_of(b, t) < cur {
                    grown = Some(t);
                    break;
                }
                t += g;
            }
            match grown {
                Some(t) => theta[b] = t,
                // The bottleneck can't improve within budget: t_frame is
                // final; spare DSPs would only dilute efficiency.
                None => break,
            }
        }

        // Rebalance pass: the grow loop can strand budget on non-bottleneck
        // layers (their θ was rounded up past what their cycle target
        // needs). Shrink every layer to the smallest θ that keeps it under
        // the bottleneck, then re-grow the bottleneck with the freed
        // multipliers. Two rounds reach a fixpoint in practice.
        for _ in 0..2 {
            let t_frame = (0..theta.len())
                .map(|j| cycles_of(j, theta[j]))
                .max()
                .unwrap_or(1);
            for j in 0..theta.len() {
                let g = granule(&net.layers[compute[j]]);
                while theta[j] > g && cycles_of(j, theta[j] - g) <= t_frame {
                    theta[j] -= g;
                }
            }
            // Re-grow the bottleneck with whatever was freed.
            loop {
                let used: usize = theta.iter().sum();
                let avail = theta_total.saturating_sub(used);
                if avail == 0 {
                    break;
                }
                let (b, cur) = (0..theta.len())
                    .map(|j| (j, cycles_of(j, theta[j])))
                    .max_by_key(|&(_, c)| c)
                    .unwrap();
                let g = granule(&net.layers[compute[b]]);
                let (c_eff, m) = dims(&net.layers[compute[b]]);
                let cap = c_eff * m * g;
                let mut grown = None;
                let mut t = theta[b] + g;
                while t <= cap.min(theta[b] + avail) {
                    if cycles_of(b, t) < cur {
                        grown = Some(t);
                        break;
                    }
                    t += g;
                }
                match grown {
                    Some(t) => theta[b] = t,
                    None => break,
                }
            }
        }

        // Line 9: decompose θ_i into C'_i × M'_i.
        let mut cfgs = vec![EngineConfig::minimal(); net.layers.len()];
        for (j, &i) in compute.iter().enumerate() {
            let l = &net.layers[i];
            let (c_eff, m) = dims(l);
            let (cp, mp) = decompose(c_eff, m, granule(l), theta[j]);
            cfgs[i] = EngineConfig { cp, mp, k: 1 };
        }
        cfgs
    }

    /// Algorithm 2: raise `K` of the heaviest weight-traffic layer until
    /// the bandwidth fits (or BRAM runs out). Public so the DNNBuilder
    /// baseline gets the same bandwidth relief (isolating the channel
    /// constraints as the only difference).
    pub fn raise_k(&self, net: &Network, board: &Board, mode: QuantMode, alloc: &mut Allocation) {
        let beta = board.ddr_bytes_per_sec * self.bw_margin;
        let alpha = board.bram18();
        for _ in 0..self.max_k_steps {
            let report = alloc.evaluate();
            // Compare the *demand* (at compute rate) against the budget —
            // the achieved-rate traffic is throttled to fit by definition.
            if report.ddr_demand_bytes_per_sec <= beta {
                break;
            }
            // Line 7: among conv layers (FC traffic is batch-amortized and
            // K-independent; pools carry no weights), try the highest-ω
            // layer first — but only K *jumps that reduce the group count*
            // (intermediate K adds ragged-tail cycles without saving a
            // fetch). A jump may stretch the bottleneck slightly; accept
            // it when the *overall* fps (compute rate capped by the DDR
            // ceiling) improves — the trade Sec. 4.2 describes.
            let cur_fps = report.fps;
            let mut cands: Vec<(usize, usize, u64)> = alloc
                .stages
                .iter()
                .enumerate()
                .filter_map(|(idx, s)| {
                    let Layer::Conv(ref c) = net.layers[s.layer_idx] else {
                        return None;
                    };
                    let groups = c.h.div_ceil(s.cfg.k);
                    if groups <= 1 {
                        return None;
                    }
                    let new_k = c.h.div_ceil(groups - 1);
                    Some((idx, new_k, s.figures.weight_bytes_per_frame()))
                })
                .collect();
            cands.sort_by_key(|&(_, _, omega)| std::cmp::Reverse(omega));
            let mut accepted = false;
            for (idx, new_k, _) in cands {
                let mut trial = alloc.clone();
                trial.stages[idx].cfg.k = new_k;
                refresh_figures(net, mode, &mut trial);
                if bram_total(net, mode, &trial) > alpha {
                    continue;
                }
                if trial.evaluate().fps > cur_fps * (1.0 + 1e-9) {
                    *alloc = trial;
                    accepted = true;
                    break;
                }
            }
            if !accepted {
                break;
            }
        }
    }
}

/// Recompute every stage's figures after a config change.
pub fn refresh_figures(net: &Network, mode: QuantMode, alloc: &mut Allocation) {
    for s in alloc.stages.iter_mut() {
        s.figures = engine::figures(&net.layers[s.layer_idx], &s.cfg, mode);
    }
}

/// Total BRAM18 of an allocation (per-stage buffers + top).
pub fn bram_total(net: &Network, mode: QuantMode, alloc: &Allocation) -> usize {
    let mut total = TOP_BRAM18;
    for (i, s) in alloc.stages.iter().enumerate() {
        let (pk, pm) = alloc.producer(i);
        let geo = buffer_geometry(&net.layers[s.layer_idx], &s.cfg, pk, pm);
        total += engine::bram18_cost(&net.layers[s.layer_idx], &s.cfg, &geo, mode);
    }
    total
}

impl Allocator for FlexAllocator {
    fn arch(&self) -> ArchKind {
        ArchKind::FlexPipeline
    }

    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation> {
        net.validate()?;
        anyhow::ensure!(board.dsps > self.dsp_reserve, "no DSPs available");
        // Multiplier budget, packing-aware: at 8-bit each DSP packs two
        // multiplies, but a DSP cannot be shared across engines — a stage
        // with an odd multiplier count strands half a slice. Reserving
        // (mults_per_dsp − 1) per compute stage guarantees
        // Σ ceil(mults_i / pack) ≤ DSPs for any split Algorithm 1 picks.
        let pack = mode.mults_per_dsp();
        let slack = (pack - 1) * net.compute_layers().len();
        let theta_total = ((board.dsps - self.dsp_reserve) * pack).saturating_sub(slack);
        let cfgs = self.algorithm1(net, theta_total);

        let stages = cfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| StageAlloc {
                layer_idx: i,
                cfg: *cfg,
                figures: engine::figures(&net.layers[i], cfg, mode),
                mac_gain: 1.0,
            })
            .collect();

        let mut alloc = Allocation {
            arch: ArchKind::FlexPipeline,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: board.freq_hz,
            arch_derate: 1.0,
            groups: None,
            extra_cycles: 0,
            shared_array: false,
        };
        self.raise_k(net, board, mode, &mut alloc);
        Ok(alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zc706;
    use crate::model::zoo;

    #[test]
    fn decompose_prefers_exact_divisors() {
        // 128 channels, budget 64 pairs: (8,8) gives 16·16 = 256 phases;
        // any non-divisor wastes slots.
        let (cp, mp) = decompose(128, 128, 9, 64 * 9);
        assert_eq!(128 % cp, 0);
        assert_eq!(128 % mp, 0);
        assert_eq!(cp * mp, 64);
    }

    #[test]
    fn decompose_respects_layer_dims() {
        let (cp, mp) = decompose(3, 64, 9, 10_000 * 9);
        assert!(cp <= 3 && mp <= 64);
    }

    #[test]
    fn algorithm1_stays_within_budget() {
        let net = zoo::vgg16();
        let board = zc706();
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        assert!(r.dsps <= board.dsps, "{} > {}", r.dsps, board.dsps);
        // Paper Table I: 900/900 DSPs for VGG16 — we should be close.
        assert!(
            r.dsps as f64 >= 0.9 * board.dsps as f64,
            "only {} of {} DSPs used",
            r.dsps,
            board.dsps
        );
    }

    #[test]
    fn vgg16_efficiency_matches_paper_band() {
        // Table I: DSP efficiency 98.0% for VGG16, >90% for all four.
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        assert!(
            r.dsp_efficiency > 0.90,
            "DSP efficiency {:.3} below the paper's band",
            r.dsp_efficiency
        );
    }

    #[test]
    fn more_dsps_never_slower() {
        let net = zoo::alexnet();
        let mut small = zc706();
        small.dsps = 300;
        let a_small = FlexAllocator::default()
            .allocate(&net, &small, QuantMode::W16A16)
            .unwrap();
        let a_big = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        assert!(a_big.evaluate().fps >= a_small.evaluate().fps);
    }

    #[test]
    fn algorithm2_reduces_bandwidth_within_bram() {
        // On a bandwidth-starved board, Algorithm 2 must trade BRAM for
        // weight reuse by raising K somewhere.
        let net = zoo::vgg16();
        let mut board = zc706();
        board.ddr_bytes_per_sec = 4.0e9;
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let bram = bram_total(&net, QuantMode::W16A16, &alloc);
        assert!(bram <= board.bram18(), "BRAM {bram} > {}", board.bram18());
        assert!(alloc.stages.iter().any(|s| s.cfg.k > 1));
        // And the relief must actually reduce traffic vs the K=1 baseline.
        let k1 = FlexAllocator { max_k_steps: 0, ..Default::default() }
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        assert!(
            alloc.evaluate().ddr_bytes_per_sec < k1.evaluate().ddr_bytes_per_sec
        );
    }

    #[test]
    fn eight_bit_doubles_multiplier_pool() {
        let net = zoo::zf();
        let board = zc706();
        let a16 = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let a8 = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W8A8)
            .unwrap();
        let (r16, r8) = (a16.evaluate(), a8.evaluate());
        assert!(
            r8.gops > 1.6 * r16.gops,
            "8-bit {} GOPS should be near 2x 16-bit {}",
            r8.gops,
            r16.gops
        );
    }
}

#[cfg(test)]
mod bw_tests {
    use super::*;
    use crate::alloc::Allocator;
    use crate::board::zc706;
    use crate::model::zoo;

    #[test]
    fn bandwidth_starved_board_throttles_fps() {
        // When BRAM can't buy enough weight reuse, fps must fall to the
        // DDR-sustainable rate instead of pretending to hit the compute
        // rate (paper Sec. 4.2's whole point).
        let net = zoo::vgg16();
        let rich = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap()
            .evaluate();
        let mut starved_board = zc706();
        starved_board.ddr_bytes_per_sec = 1.5e9;
        let starved = FlexAllocator::default()
            .allocate(&net, &starved_board, QuantMode::W16A16)
            .unwrap()
            .evaluate();
        assert!(
            starved.fps < rich.fps * 0.7,
            "starved {} vs rich {}",
            starved.fps,
            rich.fps
        );
    }
}
