//! The paper's allocation algorithms.
//!
//! **Algorithm 1 — computation resources** (Sec. 4.1): pre-allocate
//! multipliers to layers proportionally to their MAC workload `π_i`,
//! rounded to `R_i·S_i` blocks, then greedily feed the slowest layer;
//! finally decompose each `θ_i` into `C'_i × M'_i`.
//!
//! **Algorithm 2 — BRAM and bandwidth** (Sec. 4.2): while the DDR
//! bandwidth demanded by weight reloading exceeds the board's `β`, raise
//! the row parallelism `K` of the heaviest-traffic layer (each increment
//! reuses weights across one more activation row) — as long as the extra
//! activation-buffer rows still fit the BRAM budget `α`.
//!
//! The flexible activation buffer (engine::linebuf) is what frees Algorithm
//! 1 from DNNBuilder's constraints: `C'_i` needn't equal `M'_{i−1}` and
//! nothing needs to be a power of two, so the decomposition can chase exact
//! divisors of `C`/`M` and the greedy loop can hand out single `R·S` blocks.
//!
//! # Hot-path structure (and its invariants)
//!
//! Both algorithms are the framework's inner loop — a design-space sweep
//! calls them thousands of times — so they run on precomputed tables and
//! incremental deltas instead of full recomputation:
//!
//! - [`PhaseStair`] collapses the O(C·M) decomposition search into a sorted
//!   staircase of `(pairs, phases)` breakpoints: the minimum phase count is
//!   a step function of the multiplier-pair budget, with at most
//!   `O(√C·√M)` steps (distinct ceiling quotients). `cycles_of` becomes a
//!   binary search, and "smallest growth that strictly shortens the
//!   bottleneck" becomes a single lookup of the next step.
//! - Algorithm 1's grow/rebalance loops track the bottleneck stage with a
//!   lazily-invalidated max-heap keyed `(cycles, stage)` — ties resolve to
//!   the highest index, matching `Iterator::max_by_key`'s last-maximum rule
//!   so the heap path visits stages in exactly the naive order.
//! - Algorithm 2 ([`FlexAllocator::raise_k`]) evaluates each candidate
//!   K-jump *in place*: only the touched stage's figures are recomputed
//!   ([`refresh_stage_figures`]), BRAM is maintained as per-stage cached
//!   contributions (a K change invalidates exactly stages `i` and `i+1` —
//!   see [`crate::alloc::Allocation::stage_bram18`]), and fps comes from
//!   the geometry-free [`crate::alloc::Allocation::evaluate_perf`]. No
//!   `Allocation` (or `Network`) clone is ever made.
//!
//! **Equivalence invariant**: the optimized paths must produce
//! *bit-identical* allocations and reports to the seed's naive
//! implementation, which is preserved verbatim in [`naive`] as the
//! executable specification. `tests/proptests.rs` and
//! `tests/golden_equivalence.rs` enforce this on randomized networks and
//! on the paper's VGG16/ZC706 design point.

use super::{Allocation, Allocator, ArchKind, StageAlloc, TOP_BRAM18};
use crate::board::Board;
use crate::engine::{self, div_ceil, EngineConfig};
use crate::model::{Layer, Network};
use crate::quant::QuantMode;
use std::collections::BinaryHeap;

/// The paper's allocator ("This Work" in Table I).
#[derive(Debug, Clone)]
pub struct FlexAllocator {
    /// Cap on Algorithm 2 iterations (defensive; the loop is monotone).
    pub max_k_steps: usize,
    /// Reserve a fraction of DSPs for the top-level interconnect? The paper
    /// uses all 900 on VGG16/ZC706; default 0.
    pub dsp_reserve: usize,
    /// Algorithm 2 targets `B ≤ bw_margin·β`: DDR never sustains its peak
    /// (refresh, bank turnaround, request interleaving), so allocating to
    /// 100% of β produces a design the cycle simulator shows stalling.
    pub bw_margin: f64,
}

impl Default for FlexAllocator {
    fn default() -> Self {
        FlexAllocator {
            max_k_steps: 4096,
            dsp_reserve: 0,
            bw_margin: 0.75,
        }
    }
}

/// The θ vector a finished Algorithm 1 run settles on, carried between
/// neighboring DSP budgets of a design-space sweep as a warm start.
///
/// Warm-start contract (regression-tested in `search`): seeding the next
/// (larger) budget's run with the previous budget's settled θ skips the
/// proportional pre-allocation + trim and goes straight to the grow /
/// rebalance loops — and produces the **bit-identical** allocation the
/// cold start would, because the rebalance rounds re-canonicalize every
/// stage against the final bottleneck (`min_theta_under(t_frame)` depends
/// only on `t_frame`, not on how θ got there). A seed from a *larger*
/// budget than the current one is ignored (cold start) — shrinking is the
/// trim loop's job and its tie-breaks are anchored to the pre-allocation.
#[derive(Debug, Clone)]
pub struct ThetaSeed {
    /// Per-compute-layer multiplier budgets (granule multiples), in
    /// `Network::compute_layers` order.
    pub theta: Vec<usize>,
    /// The Θ total the vector settled under.
    pub theta_total: usize,
}

/// Decompose a multiplier budget into `(C', M')` for one layer.
///
/// Minimizes the phase count `ceil(C/C')·ceil(M/M')` subject to
/// `C'·M'·R·S ≤ budget`; ties prefer fewer multipliers (return the spare to
/// the pool), then the first (smallest) `C'` encountered. This is the
/// reference implementation — the allocator's loops query [`PhaseStair`]
/// instead and only call this once per layer for the final tie-broken
/// `(C', M')`.
pub fn decompose(c_eff: usize, m: usize, rs: usize, budget_mults: usize) -> (usize, usize) {
    let pairs = (budget_mults / rs).max(1);
    let mut best = (1usize, 1usize);
    let mut best_phases = u64::MAX;
    let mut best_mults = usize::MAX;
    for cp in 1..=c_eff.min(pairs) {
        let mp = (pairs / cp).min(m);
        if mp == 0 {
            continue;
        }
        // Shrink to the smallest mp with the same phase count (saves mults).
        let phases_m = div_ceil(m, mp);
        let mp = div_ceil(m, phases_m);
        let phases = (div_ceil(c_eff, cp) as u64) * (phases_m as u64);
        let mults = cp * mp * rs;
        if phases < best_phases || (phases == best_phases && mults < best_mults) {
            best_phases = phases;
            best_mults = mults;
            best = (cp, mp);
        }
    }
    best
}

/// π_i for a compute layer (Alg. 1 line 1).
fn workload(layer: &Layer) -> u64 {
    layer.macs()
}

/// `R·S` rounding granule (Alg. 1 line 3); FCs use 1.
fn granule(layer: &Layer) -> usize {
    match layer {
        Layer::Conv(c) => c.r * c.s,
        Layer::Fc(_) => 1,
        Layer::Pool(_) => 0,
    }
}

/// (C_eff, M) seen by the PE array.
fn dims(layer: &Layer) -> (usize, usize) {
    match layer {
        Layer::Conv(c) => (c.c / c.groups, c.m),
        Layer::Fc(f) => (f.n_in, f.n_out),
        Layer::Pool(_) => (0, 0),
    }
}

// ---------------------------------------------------------------------------
// Decomposition tables: O(C·M) search → O(log) staircase lookups
// ---------------------------------------------------------------------------

/// All distinct ceiling quotients of `n`: `(x, ceil(n/x))` with the minimal
/// `x` achieving each quotient, quotient strictly decreasing. At most
/// `2·√n` entries (standard divisor-block enumeration).
fn quotient_breaks(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut x = 1usize;
    while x <= n {
        let q = n.div_ceil(x);
        out.push((x, q));
        if q == 1 {
            break;
        }
        // Smallest x' whose quotient drops below q.
        x = n.div_ceil(q - 1);
    }
    out
}

/// The minimum achievable phase count `ceil(C/C')·ceil(M/M')` as a step
/// function of the multiplier-pair budget `pairs = budget/(R·S)`.
///
/// Entries are `(pairs, phases)` with `pairs` strictly increasing and
/// `phases` strictly decreasing: `pairs` is the *smallest* budget reaching
/// that phase count. Built once per layer; queried by binary search.
///
/// Equivalence with [`decompose`] (property-tested): the phase count of
/// `decompose(c_eff, m, rs, budget)`'s result equals
/// `phases_at((budget/rs).max(1))`. Only the phase count is tabulated —
/// the tie-broken `(C', M')` pair still comes from `decompose`, called
/// once per layer after the budgets settle.
#[derive(Debug, Clone)]
pub struct PhaseStair {
    stair: Vec<(u64, u64)>,
}

impl PhaseStair {
    /// Build the staircase for a layer with `c_eff` input channels and `m`
    /// output channels.
    pub fn build(c_eff: usize, m: usize) -> PhaseStair {
        let cb = quotient_breaks(c_eff.max(1));
        let mb = quotient_breaks(m.max(1));
        let mut pts: Vec<(u64, u64)> = Vec::with_capacity(cb.len() * mb.len());
        for &(cp, qc) in &cb {
            for &(mp, qm) in &mb {
                pts.push(((cp * mp) as u64, qc as u64 * qm as u64));
            }
        }
        pts.sort_unstable();
        let mut stair = Vec::new();
        let mut best = u64::MAX;
        for (cost, phases) in pts {
            if phases < best {
                best = phases;
                stair.push((cost, phases));
            }
        }
        PhaseStair { stair }
    }

    /// Minimum phase count achievable with `pairs` multiplier pairs.
    pub fn phases_at(&self, pairs: u64) -> u64 {
        let idx = self.stair.partition_point(|&(c, _)| c <= pairs);
        // stair[0].0 == 1 and pairs >= 1, so idx >= 1 always.
        self.stair[idx - 1].1
    }

    /// Smallest pair budget whose phase count is *strictly below* `phases`
    /// (the grow loop's "next value that strictly shortens the
    /// bottleneck"). `None` when `phases` is already the minimum.
    pub fn first_below(&self, phases: u64) -> Option<u64> {
        let idx = self.stair.partition_point(|&(_, p)| p >= phases);
        self.stair.get(idx).map(|&(c, _)| c)
    }

    /// Smallest pair budget whose phase count is `≤ phases` (the rebalance
    /// pass's "smallest θ that keeps this stage under the bottleneck").
    /// `phases` must be reachable (≥ 1); the stair always ends at 1.
    pub fn first_at_most(&self, phases: u64) -> u64 {
        let idx = self.stair.partition_point(|&(_, p)| p > phases);
        self.stair[idx].0
    }
}

/// Per-layer precomputation for Algorithm 1: staircase + the constants that
/// turn phase counts into cycle counts.
#[derive(Debug, Clone)]
pub struct LayerTable {
    /// `R·S` allocation granule (1 for FC).
    granule: usize,
    /// Cycles per phase: `H·W` for conv, 1 for FC.
    spatial: u64,
    /// Largest useful θ: `C_eff·M·granule` (phases = 1).
    theta_cap: usize,
    /// Phase staircase.
    stair: PhaseStair,
}

impl LayerTable {
    /// Build for one compute layer.
    pub fn for_layer(layer: &Layer) -> LayerTable {
        let (c_eff, m) = dims(layer);
        let g = granule(layer);
        let spatial = match layer {
            Layer::Conv(c) => (c.h * c.w) as u64,
            Layer::Fc(_) => 1,
            Layer::Pool(_) => unreachable!("compute layers only"),
        };
        LayerTable {
            granule: g,
            spatial,
            theta_cap: c_eff * m * g,
            stair: PhaseStair::build(c_eff, m),
        }
    }

    /// Pair budget a θ multiplier budget buys (mirrors [`decompose`]'s
    /// `(budget/rs).max(1)`).
    fn pairs_of(&self, theta: usize) -> u64 {
        ((theta / self.granule).max(1)) as u64
    }

    /// Cycles/frame at multiplier budget θ — equals the naive
    /// `spatial · phases(decompose(θ))` exactly.
    pub fn cycles_at(&self, theta: usize) -> u64 {
        self.spatial * self.stair.phases_at(self.pairs_of(theta))
    }

    /// Smallest θ (granule multiple) strictly improving on `cur_cycles`,
    /// or `None` if no improvement exists within the layer's cap.
    fn next_improving(&self, cur_cycles: u64) -> Option<usize> {
        let pairs = self.stair.first_below(cur_cycles / self.spatial)?;
        Some(pairs as usize * self.granule)
    }

    /// Smallest θ (granule multiple) whose cycles stay `≤ t_frame`.
    /// Requires `t_frame ≥ spatial` (true whenever some budget meets it).
    fn min_theta_under(&self, t_frame: u64) -> usize {
        self.stair.first_at_most(t_frame / self.spatial) as usize * self.granule
    }
}

/// Decomposition tables for every compute layer of a network, in
/// `Network::compute_layers` order. Build once, share across every
/// `(board, mode, DSP budget)` the design-space search throws at the model
/// — the staircase depends only on layer dimensions.
#[derive(Debug, Clone)]
pub struct NetTables {
    layers: Vec<LayerTable>,
}

impl NetTables {
    /// Precompute for `net`'s compute layers.
    pub fn build(net: &Network) -> NetTables {
        NetTables {
            layers: net
                .compute_layers()
                .iter()
                .map(|&i| LayerTable::for_layer(&net.layers[i]))
                .collect(),
        }
    }

    /// Number of compute-layer tables.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Admissible lower bound on any allocation's pipeline beat at a total
    /// multiplier budget Θ: the slowest layer when each layer is
    /// (optimistically) handed the *entire* budget alone. Any real split
    /// gives every layer `θ_j ≤ Θ`, and [`LayerTable::cycles_at`] is
    /// non-increasing in θ, so every layer's real cycles are `≥
    /// cycles_at(Θ)` — and raising `K` (Algorithm 2) only adds ragged-tail
    /// cycles on top. This is the staircase bound the branch-and-bound
    /// search prunes on: `fps ≤ freq / bottleneck_cycles_lb(Θ)`.
    pub fn bottleneck_cycles_lb(&self, theta_total: usize) -> u64 {
        self.layers
            .iter()
            .map(|lt| lt.cycles_at(theta_total))
            .max()
            .unwrap_or(1)
            .max(1)
    }

    /// Admissible lower bound on the *sum* of compute-stage cycles at a
    /// total budget Θ (same per-layer argument as
    /// [`NetTables::bottleneck_cycles_lb`], summed) — the compute half of
    /// the latency lower bound; pool stages are costed separately (their
    /// cycles are `H·W`, independent of the allocation).
    pub fn stage_cycle_sum_lb(&self, theta_total: usize) -> u64 {
        self.layers.iter().map(|lt| lt.cycles_at(theta_total)).sum()
    }
}

/// Outcome flags of one allocator run, reported by
/// [`FlexAllocator::allocate_outcome`].
#[derive(Debug, Clone, Copy)]
pub struct AllocOutcome {
    /// Did Algorithm 2 finish without ever rejecting a candidate K-jump on
    /// the BRAM budget α? When `true`, the whole run's decision sequence
    /// was independent of α: every accepted jump fit with room to spare and
    /// every rejection was an fps rejection (which compares compute/DDR
    /// rates only). A clean allocation is therefore **bit-identical** on
    /// any board with the same Θ/β and a *larger* α — the reuse rule the
    /// shard search's α-saturation cache exploits.
    pub bram_clean: bool,
}

/// Grow the bottleneck stage until the budget is exhausted or it can no
/// longer improve (Alg. 1 lines 4–8). The bottleneck is tracked with a
/// lazily-invalidated max-heap keyed `(cycles, stage)`; stale entries are
/// dropped when popped. Tie-break (highest stage index) matches the naive
/// scan's `max_by_key` last-maximum rule, so the growth sequence is
/// identical to the seed implementation's.
fn grow_bottleneck(tables: &[LayerTable], theta: &mut [usize], cycles: &mut [u64], budget: usize) {
    let mut used: usize = theta.iter().sum();
    let mut heap: BinaryHeap<(u64, usize)> = cycles
        .iter()
        .copied()
        .enumerate()
        .map(|(j, c)| (c, j))
        .collect();
    loop {
        let avail = budget.saturating_sub(used);
        if avail == 0 {
            return;
        }
        // Current bottleneck. Invariant: exactly one live entry per stage
        // (the update below pops the old entry before pushing the new
        // one), so the top is never stale.
        let Some(&(cur, b)) = heap.peek() else {
            return;
        };
        debug_assert_eq!(cycles[b], cur, "heap entry went stale");
        let lt = &tables[b];
        // Smallest affordable growth that strictly reduces the
        // bottleneck's cycles. If none fits, t_frame is final: spare DSPs
        // would only dilute efficiency.
        let Some(t) = lt.next_improving(cur) else {
            return;
        };
        if t > lt.theta_cap.min(theta[b] + avail) {
            return;
        }
        heap.pop(); // b's entry, about to go stale
        used += t - theta[b];
        theta[b] = t;
        cycles[b] = lt.cycles_at(t);
        heap.push((cycles[b], b));
    }
}

impl FlexAllocator {
    /// Algorithm 1: returns per-layer `(C', M')` using up to Θ multipliers.
    /// Bit-identical to [`naive::algorithm1`] (property-tested); the
    /// decomposition search and bottleneck scans run on `tables`.
    fn algorithm1(
        &self,
        net: &Network,
        theta_total: usize,
        tables: &NetTables,
    ) -> Vec<EngineConfig> {
        self.algorithm1_seeded(net, theta_total, tables, None).0
    }

    /// [`FlexAllocator::algorithm1`] with an optional θ warm start (see
    /// [`ThetaSeed`] for the bit-identity contract). Also returns the
    /// settled θ vector for the caller to carry to the next budget.
    fn algorithm1_seeded(
        &self,
        net: &Network,
        theta_total: usize,
        tables: &NetTables,
        seed: Option<&ThetaSeed>,
    ) -> (Vec<EngineConfig>, ThetaSeed) {
        let compute: Vec<usize> = net.compute_layers();
        let pis: Vec<u64> = compute.iter().map(|&i| workload(&net.layers[i])).collect();
        let pi_sum: u64 = pis.iter().sum();

        let mut theta: Vec<usize> = match seed {
            // Warm start: the previous (smaller) budget's settled θ is a
            // valid sub-budget state — skip pre-allocation + trim and let
            // the grow/rebalance loops spend the new headroom.
            Some(s) if s.theta_total <= theta_total && s.theta.len() == compute.len() => {
                debug_assert!(s.theta.iter().sum::<usize>() <= theta_total);
                s.theta.clone()
            }
            _ => {
                // Lines 2–3: proportional pre-allocation rounded to R·S
                // granules.
                let mut theta: Vec<usize> = compute
                    .iter()
                    .zip(&pis)
                    .map(|(&i, &pi)| {
                        let l = &net.layers[i];
                        let g = granule(l);
                        let ideal = (pi as f64 * theta_total as f64 / pi_sum as f64) as usize;
                        ((ideal / g).max(1)) * g
                    })
                    .collect();

                // Pre-allocation may overshoot after rounding-up: trim the
                // most over-served layers (smallest π/θ) back one granule
                // at a time.
                loop {
                    let used: usize = theta.iter().sum();
                    if used <= theta_total {
                        break;
                    }
                    let j = (0..theta.len())
                        .filter(|&j| theta[j] > granule(&net.layers[compute[j]]))
                        .min_by(|&a, &b| {
                            let ra = pis[a] as f64 / theta[a] as f64;
                            let rb = pis[b] as f64 / theta[b] as f64;
                            ra.partial_cmp(&rb).unwrap()
                        });
                    match j {
                        Some(j) => theta[j] -= granule(&net.layers[compute[j]]),
                        None => break,
                    }
                }
                theta
            }
        };

        // Lines 4–8: greedy — keep feeding the slowest layer. The paper
        // adds one R·S granule at a time; we strengthen this to "grow the
        // bottleneck's θ to the next value that strictly shortens it",
        // because the decomposition only improves at divisor steps. With
        // the staircase that next value is a single lookup instead of a
        // linear scan.
        let lt = &tables.layers;
        debug_assert_eq!(lt.len(), compute.len(), "tables built for another network");
        let mut cycles: Vec<u64> = (0..compute.len()).map(|j| lt[j].cycles_at(theta[j])).collect();
        grow_bottleneck(lt, &mut theta, &mut cycles, theta_total);

        // Rebalance pass: the grow loop can strand budget on non-bottleneck
        // layers (their θ was rounded up past what their cycle target
        // needs). Shrink every layer to the smallest θ that keeps it under
        // the bottleneck, then re-grow the bottleneck with the freed
        // multipliers. Two rounds reach a fixpoint in practice.
        for _ in 0..2 {
            let t_frame = cycles.iter().copied().max().unwrap_or(1);
            for j in 0..theta.len() {
                let shrunk = lt[j].min_theta_under(t_frame);
                if shrunk < theta[j] {
                    theta[j] = shrunk;
                    cycles[j] = lt[j].cycles_at(shrunk);
                }
            }
            grow_bottleneck(lt, &mut theta, &mut cycles, theta_total);
        }

        // Line 9: decompose θ_i into C'_i × M'_i (reference decompose for
        // the exact tie-broken pair — once per layer, off the hot path).
        let mut cfgs = vec![EngineConfig::minimal(); net.layers.len()];
        for (j, &i) in compute.iter().enumerate() {
            let l = &net.layers[i];
            let (c_eff, m) = dims(l);
            let (cp, mp) = decompose(c_eff, m, granule(l), theta[j]);
            cfgs[i] = EngineConfig { cp, mp, k: 1 };
        }
        let seed_out = ThetaSeed {
            theta,
            theta_total,
        };
        (cfgs, seed_out)
    }

    /// Algorithm 2: raise `K` of the heaviest weight-traffic layer until
    /// the bandwidth fits (or BRAM runs out). Public so the DNNBuilder
    /// baseline gets the same bandwidth relief (isolating the channel
    /// constraints as the only difference).
    ///
    /// Clone-free: candidates are applied to `alloc` in place and reverted
    /// on rejection; only the touched stage's figures and the two affected
    /// stages' BRAM contributions are recomputed per candidate, and fps
    /// comes from the geometry-free `evaluate_perf`. Decision-for-decision
    /// identical to [`naive::raise_k`] (golden-tested).
    pub fn raise_k(&self, net: &Network, board: &Board, mode: QuantMode, alloc: &mut Allocation) {
        self.raise_k_tracked(net, board, mode, alloc);
    }

    /// [`FlexAllocator::raise_k`] that additionally reports whether the run
    /// was BRAM-clean (see [`AllocOutcome::bram_clean`]): returns `true`
    /// iff no candidate K-jump was ever rejected because the new BRAM sum
    /// exceeded α.
    fn raise_k_tracked(
        &self,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        alloc: &mut Allocation,
    ) -> bool {
        let mut bram_clean = true;
        let beta = board.ddr_bytes_per_sec * self.bw_margin;
        let alpha = board.bram18();
        let n = alloc.stages.len();
        // Per-stage BRAM cache: candidate K-jumps patch stages idx/idx+1.
        let mut stage_bram: Vec<usize> = (0..n).map(|i| alloc.stage_bram18(i)).collect();
        let mut bram_sum: usize = TOP_BRAM18 + stage_bram.iter().sum::<usize>();
        for _ in 0..self.max_k_steps {
            let perf = alloc.evaluate_perf();
            // Compare the *demand* (at compute rate) against the budget —
            // the achieved-rate traffic is throttled to fit by definition.
            if perf.ddr_demand_bytes_per_sec <= beta {
                break;
            }
            // Line 7: among conv layers (FC traffic is batch-amortized and
            // K-independent; pools carry no weights), try the highest-ω
            // layer first — but only K *jumps that reduce the group count*
            // (intermediate K adds ragged-tail cycles without saving a
            // fetch). A jump may stretch the bottleneck slightly; accept
            // it when the *overall* fps (compute rate capped by the DDR
            // ceiling) improves — the trade Sec. 4.2 describes.
            let cur_fps = perf.fps;
            let mut cands: Vec<(usize, usize, u64)> = alloc
                .stages
                .iter()
                .enumerate()
                .filter_map(|(idx, s)| {
                    let Layer::Conv(ref c) = net.layers[s.layer_idx] else {
                        return None;
                    };
                    let groups = c.h.div_ceil(s.cfg.k);
                    if groups <= 1 {
                        return None;
                    }
                    let new_k = c.h.div_ceil(groups - 1);
                    Some((idx, new_k, s.figures.weight_bytes_per_frame()))
                })
                .collect();
            cands.sort_by_key(|&(_, _, omega)| std::cmp::Reverse(omega));
            let mut accepted = false;
            for (idx, new_k, _) in cands {
                let old_k = alloc.stages[idx].cfg.k;
                let old_fig = alloc.stages[idx].figures;
                alloc.stages[idx].cfg.k = new_k;
                refresh_stage_figures(net, mode, alloc, idx);
                // BRAM delta: own geometry + the downstream stage that sees
                // this stage as producer.
                let nb_self = alloc.stage_bram18(idx);
                let (ob_next, nb_next) = if idx + 1 < n {
                    (stage_bram[idx + 1], alloc.stage_bram18(idx + 1))
                } else {
                    (0, 0)
                };
                let new_sum = bram_sum - stage_bram[idx] - ob_next + nb_self + nb_next;
                if new_sum > alpha {
                    // Over BRAM: the only α-dependent decision in the whole
                    // allocator — record it so callers know this run's
                    // output is NOT reusable on a smaller-α board.
                    bram_clean = false;
                    alloc.stages[idx].cfg.k = old_k;
                    alloc.stages[idx].figures = old_fig;
                    continue;
                }
                if alloc.evaluate_perf().fps > cur_fps * (1.0 + 1e-9) {
                    stage_bram[idx] = nb_self;
                    if idx + 1 < n {
                        stage_bram[idx + 1] = nb_next;
                    }
                    bram_sum = new_sum;
                    accepted = true;
                    break;
                }
                // fps did not improve (an α-independent rejection): revert.
                alloc.stages[idx].cfg.k = old_k;
                alloc.stages[idx].figures = old_fig;
            }
            if !accepted {
                break;
            }
        }
        bram_clean
    }

    /// Allocate with caller-provided [`NetTables`] — the design-space
    /// search builds the tables once per model and shares them across every
    /// (board, mode, budget) job.
    pub fn allocate_with(
        &self,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        tables: &NetTables,
    ) -> crate::Result<Allocation> {
        Ok(self.allocate_seeded(net, board, mode, tables, None)?.0)
    }

    /// [`FlexAllocator::allocate_with`] plus the θ warm-start channel: the
    /// budget sweep threads each point's [`ThetaSeed`] into its
    /// larger-budget neighbor (bit-identical to cold starts — see
    /// [`ThetaSeed`]) and gets the settled seed back for the next point.
    pub fn allocate_seeded(
        &self,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        tables: &NetTables,
        seed: Option<&ThetaSeed>,
    ) -> crate::Result<(Allocation, ThetaSeed)> {
        let (alloc, seed_out, _) = self.allocate_outcome(net, board, mode, tables, seed)?;
        Ok((alloc, seed_out))
    }

    /// The Θ multiplier budget [`FlexAllocator::allocate_seeded`] derives
    /// for a board/mode pair with `n_compute` compute layers — exposed so
    /// the branch-and-bound search can evaluate staircase bounds for a
    /// candidate sub-board *without* running the allocator.
    pub fn theta_budget(&self, n_compute: usize, board: &Board, mode: QuantMode) -> usize {
        let pack = mode.mults_per_dsp();
        let slack = (pack - 1) * n_compute;
        ((board.dsps.saturating_sub(self.dsp_reserve)) * pack).saturating_sub(slack)
    }

    /// Settle Algorithm 1's θ vector only — the cheap prefix of
    /// [`FlexAllocator::allocate_seeded`], with no stage figures, no
    /// Algorithm 2 and no evaluation. The budget-sweep plateau skip runs
    /// this first: along a DSP-budget chain only the budget varies, and
    /// every downstream quantity (figures, K-raising, fps, power, DES) is
    /// a pure function of the settled θ vector — so when the vector equals
    /// the previous budget's, the previous design point can be reused
    /// verbatim (bit-identical; regression-tested).
    pub fn settle_thetas(
        &self,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        tables: &NetTables,
        seed: Option<&ThetaSeed>,
    ) -> crate::Result<ThetaSeed> {
        net.validate()?;
        anyhow::ensure!(board.dsps > self.dsp_reserve, "no DSPs available");
        anyhow::ensure!(
            tables.layers.len() == net.compute_layers().len(),
            "NetTables were built for a different network ({} compute layers vs {})",
            tables.layers.len(),
            net.compute_layers().len()
        );
        let theta_total = self.theta_budget(net.compute_layers().len(), board, mode);
        Ok(self.algorithm1_seeded(net, theta_total, tables, seed).1)
    }

    /// [`FlexAllocator::allocate_seeded`] plus the [`AllocOutcome`] flags —
    /// the α-saturation cache in [`crate::shard`] uses `bram_clean` to
    /// reuse one allocator run across every larger BRAM slice.
    pub fn allocate_outcome(
        &self,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        tables: &NetTables,
        seed: Option<&ThetaSeed>,
    ) -> crate::Result<(Allocation, ThetaSeed, AllocOutcome)> {
        net.validate()?;
        anyhow::ensure!(board.dsps > self.dsp_reserve, "no DSPs available");
        anyhow::ensure!(
            tables.layers.len() == net.compute_layers().len(),
            "NetTables were built for a different network ({} compute layers vs {})",
            tables.layers.len(),
            net.compute_layers().len()
        );
        // Multiplier budget, packing-aware: at 8-bit each DSP packs two
        // multiplies, but a DSP cannot be shared across engines — a stage
        // with an odd multiplier count strands half a slice. Reserving
        // (mults_per_dsp − 1) per compute stage guarantees
        // Σ ceil(mults_i / pack) ≤ DSPs for any split Algorithm 1 picks.
        let theta_total = self.theta_budget(net.compute_layers().len(), board, mode);
        let (cfgs, seed_out) = self.algorithm1_seeded(net, theta_total, tables, seed);

        let stages = cfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| StageAlloc {
                layer_idx: i,
                cfg: *cfg,
                figures: engine::figures(&net.layers[i], cfg, mode),
                mac_gain: 1.0,
            })
            .collect();

        let mut alloc = Allocation {
            arch: ArchKind::FlexPipeline,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: board.freq_hz,
            arch_derate: 1.0,
            groups: None,
            extra_cycles: 0,
            shared_array: false,
        };
        let bram_clean = self.raise_k_tracked(net, board, mode, &mut alloc);
        Ok((alloc, seed_out, AllocOutcome { bram_clean }))
    }
}

/// Recompute every stage's figures after a config change. Prefer
/// [`refresh_stage_figures`] when only one stage's config changed — figures
/// depend solely on (layer, own config, mode), so nothing else moves.
pub fn refresh_figures(net: &Network, mode: QuantMode, alloc: &mut Allocation) {
    for s in alloc.stages.iter_mut() {
        s.figures = engine::figures(&net.layers[s.layer_idx], &s.cfg, mode);
    }
}

/// Recompute one stage's figures after its config changed.
pub fn refresh_stage_figures(net: &Network, mode: QuantMode, alloc: &mut Allocation, idx: usize) {
    let s = &mut alloc.stages[idx];
    s.figures = engine::figures(&net.layers[s.layer_idx], &s.cfg, mode);
}

/// Total BRAM18 of an allocation (per-stage buffers + top).
pub fn bram_total(net: &Network, mode: QuantMode, alloc: &Allocation) -> usize {
    let mut total = TOP_BRAM18;
    for (i, s) in alloc.stages.iter().enumerate() {
        let (pk, pm) = alloc.producer(i);
        total += engine::stage_bram18(&net.layers[s.layer_idx], &s.cfg, pk, pm, mode);
    }
    total
}

impl Allocator for FlexAllocator {
    fn arch(&self) -> ArchKind {
        ArchKind::FlexPipeline
    }

    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation> {
        let tables = NetTables::build(net);
        self.allocate_with(net, board, mode, &tables)
    }
}

// ---------------------------------------------------------------------------
// Naive reference: the seed's implementation, kept as the executable spec
// ---------------------------------------------------------------------------

/// The seed's unoptimized Algorithm 1/2 — preserved verbatim as the
/// executable specification of the hot paths above. Every greedy decision
/// is made by full recomputation ([`decompose`] per probe, whole-allocation
/// clone + full `evaluate()` per Algorithm 2 candidate), which is why these
/// run orders of magnitude slower; `benches/hotpath.rs` measures the gap
/// and `tests/` assert the outputs are bit-identical.
pub mod naive {
    use super::*;

    /// Naive Algorithm 1 (full `decompose` search per cycle probe, linear
    /// bottleneck rescans).
    pub fn algorithm1(net: &Network, theta_total: usize) -> Vec<EngineConfig> {
        let compute: Vec<usize> = net.compute_layers();
        let pis: Vec<u64> = compute.iter().map(|&i| workload(&net.layers[i])).collect();
        let pi_sum: u64 = pis.iter().sum();

        let mut theta: Vec<usize> = compute
            .iter()
            .zip(&pis)
            .map(|(&i, &pi)| {
                let l = &net.layers[i];
                let g = granule(l);
                let ideal = (pi as f64 * theta_total as f64 / pi_sum as f64) as usize;
                ((ideal / g).max(1)) * g
            })
            .collect();

        loop {
            let used: usize = theta.iter().sum();
            if used <= theta_total {
                break;
            }
            let j = (0..theta.len())
                .filter(|&j| theta[j] > granule(&net.layers[compute[j]]))
                .min_by(|&a, &b| {
                    let ra = pis[a] as f64 / theta[a] as f64;
                    let rb = pis[b] as f64 / theta[b] as f64;
                    ra.partial_cmp(&rb).unwrap()
                });
            match j {
                Some(j) => theta[j] -= granule(&net.layers[compute[j]]),
                None => break,
            }
        }

        let cycles_of = |j: usize, theta_j: usize| -> u64 {
            let l = &net.layers[compute[j]];
            let (c_eff, m) = dims(l);
            let (cp, mp) = decompose(c_eff, m, granule(l), theta_j);
            let phases = div_ceil(c_eff, cp) as u64 * div_ceil(m, mp) as u64;
            let spatial = match l {
                Layer::Conv(c) => (c.h * c.w) as u64,
                Layer::Fc(_) => 1,
                Layer::Pool(_) => unreachable!(),
            };
            spatial * phases
        };
        loop {
            let used: usize = theta.iter().sum();
            let avail = theta_total.saturating_sub(used);
            if avail == 0 {
                break;
            }
            let (b, cur) = (0..theta.len())
                .map(|j| (j, cycles_of(j, theta[j])))
                .max_by_key(|&(_, c)| c)
                .unwrap();
            let g = granule(&net.layers[compute[b]]);
            let (c_eff, m) = dims(&net.layers[compute[b]]);
            let cap = c_eff * m * g;
            let mut grown = None;
            let mut t = theta[b] + g;
            while t <= cap.min(theta[b] + avail) {
                if cycles_of(b, t) < cur {
                    grown = Some(t);
                    break;
                }
                t += g;
            }
            match grown {
                Some(t) => theta[b] = t,
                None => break,
            }
        }

        for _ in 0..2 {
            let t_frame = (0..theta.len())
                .map(|j| cycles_of(j, theta[j]))
                .max()
                .unwrap_or(1);
            for j in 0..theta.len() {
                let g = granule(&net.layers[compute[j]]);
                while theta[j] > g && cycles_of(j, theta[j] - g) <= t_frame {
                    theta[j] -= g;
                }
            }
            loop {
                let used: usize = theta.iter().sum();
                let avail = theta_total.saturating_sub(used);
                if avail == 0 {
                    break;
                }
                let (b, cur) = (0..theta.len())
                    .map(|j| (j, cycles_of(j, theta[j])))
                    .max_by_key(|&(_, c)| c)
                    .unwrap();
                let g = granule(&net.layers[compute[b]]);
                let (c_eff, m) = dims(&net.layers[compute[b]]);
                let cap = c_eff * m * g;
                let mut grown = None;
                let mut t = theta[b] + g;
                while t <= cap.min(theta[b] + avail) {
                    if cycles_of(b, t) < cur {
                        grown = Some(t);
                        break;
                    }
                    t += g;
                }
                match grown {
                    Some(t) => theta[b] = t,
                    None => break,
                }
            }
        }

        let mut cfgs = vec![EngineConfig::minimal(); net.layers.len()];
        for (j, &i) in compute.iter().enumerate() {
            let l = &net.layers[i];
            let (c_eff, m) = dims(l);
            let (cp, mp) = decompose(c_eff, m, granule(l), theta[j]);
            cfgs[i] = EngineConfig { cp, mp, k: 1 };
        }
        cfgs
    }

    /// Naive Algorithm 2 (clones the whole allocation per candidate,
    /// recomputes every stage's figures and the full report).
    pub fn raise_k(
        a: &FlexAllocator,
        net: &Network,
        board: &Board,
        mode: QuantMode,
        alloc: &mut Allocation,
    ) {
        let beta = board.ddr_bytes_per_sec * a.bw_margin;
        let alpha = board.bram18();
        for _ in 0..a.max_k_steps {
            let report = alloc.evaluate();
            if report.ddr_demand_bytes_per_sec <= beta {
                break;
            }
            let cur_fps = report.fps;
            let mut cands: Vec<(usize, usize, u64)> = alloc
                .stages
                .iter()
                .enumerate()
                .filter_map(|(idx, s)| {
                    let Layer::Conv(ref c) = net.layers[s.layer_idx] else {
                        return None;
                    };
                    let groups = c.h.div_ceil(s.cfg.k);
                    if groups <= 1 {
                        return None;
                    }
                    let new_k = c.h.div_ceil(groups - 1);
                    Some((idx, new_k, s.figures.weight_bytes_per_frame()))
                })
                .collect();
            cands.sort_by_key(|&(_, _, omega)| std::cmp::Reverse(omega));
            let mut accepted = false;
            for (idx, new_k, _) in cands {
                let mut trial = alloc.clone();
                trial.stages[idx].cfg.k = new_k;
                refresh_figures(net, mode, &mut trial);
                if bram_total(net, mode, &trial) > alpha {
                    continue;
                }
                if trial.evaluate().fps > cur_fps * (1.0 + 1e-9) {
                    *alloc = trial;
                    accepted = true;
                    break;
                }
            }
            if !accepted {
                break;
            }
        }
    }

    /// Naive end-to-end allocation (the seed's `FlexAllocator::allocate`).
    pub fn allocate(
        a: &FlexAllocator,
        net: &Network,
        board: &Board,
        mode: QuantMode,
    ) -> crate::Result<Allocation> {
        net.validate()?;
        anyhow::ensure!(board.dsps > a.dsp_reserve, "no DSPs available");
        let pack = mode.mults_per_dsp();
        let slack = (pack - 1) * net.compute_layers().len();
        let theta_total = ((board.dsps - a.dsp_reserve) * pack).saturating_sub(slack);
        let cfgs = algorithm1(net, theta_total);

        let stages = cfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| StageAlloc {
                layer_idx: i,
                cfg: *cfg,
                figures: engine::figures(&net.layers[i], cfg, mode),
                mac_gain: 1.0,
            })
            .collect();

        let mut alloc = Allocation {
            arch: ArchKind::FlexPipeline,
            net: net.clone(),
            board: board.clone(),
            mode,
            stages,
            freq_hz: board.freq_hz,
            arch_derate: 1.0,
            groups: None,
            extra_cycles: 0,
            shared_array: false,
        };
        raise_k(a, net, board, mode, &mut alloc);
        Ok(alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zc706;
    use crate::model::zoo;

    #[test]
    fn decompose_prefers_exact_divisors() {
        // 128 channels, budget 64 pairs: (8,8) gives 16·16 = 256 phases;
        // any non-divisor wastes slots.
        let (cp, mp) = decompose(128, 128, 9, 64 * 9);
        assert_eq!(128 % cp, 0);
        assert_eq!(128 % mp, 0);
        assert_eq!(cp * mp, 64);
    }

    #[test]
    fn decompose_respects_layer_dims() {
        let (cp, mp) = decompose(3, 64, 9, 10_000 * 9);
        assert!(cp <= 3 && mp <= 64);
    }

    #[test]
    fn stair_matches_decompose_on_dense_sweep() {
        // Exhaustive check on a small layer: every pair budget's minimum
        // phase count must equal the staircase lookup.
        for (c_eff, m) in [(12usize, 40usize), (3, 64), (17, 17), (1, 9)] {
            let stair = PhaseStair::build(c_eff, m);
            for pairs in 1..=(c_eff * m + 3) {
                let (cp, mp) = decompose(c_eff, m, 1, pairs);
                let want = div_ceil(c_eff, cp) as u64 * div_ceil(m, mp) as u64;
                assert_eq!(
                    stair.phases_at(pairs as u64),
                    want,
                    "c={c_eff} m={m} pairs={pairs}"
                );
            }
        }
    }

    #[test]
    fn stair_first_below_is_next_strict_improvement() {
        let stair = PhaseStair::build(128, 128);
        let cur = stair.phases_at(64); // 256 phases at 64 pairs
        let next = stair.first_below(cur).unwrap();
        // The naive scan: first pairs budget whose phases beat `cur`.
        let mut want = None;
        for pairs in 65..=(128 * 128) {
            let (cp, mp) = decompose(128, 128, 1, pairs);
            if (div_ceil(128, cp) * div_ceil(128, mp)) < cur as usize {
                want = Some(pairs as u64);
                break;
            }
        }
        assert_eq!(Some(next), want);
    }

    #[test]
    fn optimized_allocate_matches_naive_on_small_nets() {
        for net in [zoo::tinycnn(), zoo::lenet(), zoo::zf()] {
            for mode in [QuantMode::W16A16, QuantMode::W8A8] {
                let a = FlexAllocator::default();
                let fast = a.allocate(&net, &zc706(), mode).unwrap();
                let slow = naive::allocate(&a, &net, &zc706(), mode).unwrap();
                for (f, s) in fast.stages.iter().zip(&slow.stages) {
                    assert_eq!(f.cfg, s.cfg, "{} {mode}", net.name);
                }
                let (rf, rs) = (fast.evaluate(), slow.evaluate());
                assert_eq!(rf.t_frame_cycles, rs.t_frame_cycles);
                assert_eq!(rf.fps.to_bits(), rs.fps.to_bits(), "{}", net.name);
                assert_eq!(rf.bram18, rs.bram18);
            }
        }
    }

    #[test]
    fn seeded_allocate_matches_cold_on_growing_budgets() {
        // The ThetaSeed contract: warm-starting from the previous
        // (smaller) budget's settled θ must reproduce the cold start
        // bit-for-bit at every point of an ascending budget chain.
        for net in [zoo::zf(), zoo::lenet()] {
            let tables = NetTables::build(&net);
            let a = FlexAllocator::default();
            let mut seed: Option<ThetaSeed> = None;
            let mut board = zc706();
            for dsps in [200usize, 350, 500, 700, 900, 1200] {
                board.dsps = dsps;
                let (warm, s) = a
                    .allocate_seeded(&net, &board, QuantMode::W16A16, &tables, seed.as_ref())
                    .unwrap();
                let cold = a
                    .allocate_with(&net, &board, QuantMode::W16A16, &tables)
                    .unwrap();
                for (x, y) in warm.stages.iter().zip(&cold.stages) {
                    assert_eq!(x.cfg, y.cfg, "{} dsps={dsps}", net.name);
                }
                assert_eq!(
                    warm.evaluate().fps.to_bits(),
                    cold.evaluate().fps.to_bits(),
                    "{} dsps={dsps}",
                    net.name
                );
                // The carried seed reflects the budget it settled under.
                assert_eq!(s.theta_total, dsps); // 16-bit: Θ = DSPs
                seed = Some(s);
            }
            // A seed from a larger budget is ignored (cold-start path), so
            // shrinking the budget still matches cold exactly.
            board.dsps = 300;
            let (shrunk, _) = a
                .allocate_seeded(&net, &board, QuantMode::W16A16, &tables, seed.as_ref())
                .unwrap();
            let cold = a
                .allocate_with(&net, &board, QuantMode::W16A16, &tables)
                .unwrap();
            for (x, y) in shrunk.stages.iter().zip(&cold.stages) {
                assert_eq!(x.cfg, y.cfg, "{} shrink", net.name);
            }
        }
    }

    #[test]
    fn algorithm1_stays_within_budget() {
        let net = zoo::vgg16();
        let board = zc706();
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        assert!(r.dsps <= board.dsps, "{} > {}", r.dsps, board.dsps);
        // Paper Table I: 900/900 DSPs for VGG16 — we should be close.
        assert!(
            r.dsps as f64 >= 0.9 * board.dsps as f64,
            "only {} of {} DSPs used",
            r.dsps,
            board.dsps
        );
    }

    #[test]
    fn vgg16_efficiency_matches_paper_band() {
        // Table I: DSP efficiency 98.0% for VGG16, >90% for all four.
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        assert!(
            r.dsp_efficiency > 0.90,
            "DSP efficiency {:.3} below the paper's band",
            r.dsp_efficiency
        );
    }

    #[test]
    fn more_dsps_never_slower() {
        let net = zoo::alexnet();
        let mut small = zc706();
        small.dsps = 300;
        let a_small = FlexAllocator::default()
            .allocate(&net, &small, QuantMode::W16A16)
            .unwrap();
        let a_big = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap();
        assert!(a_big.evaluate().fps >= a_small.evaluate().fps);
    }

    #[test]
    fn algorithm2_reduces_bandwidth_within_bram() {
        // On a bandwidth-starved board, Algorithm 2 must trade BRAM for
        // weight reuse by raising K somewhere.
        let net = zoo::vgg16();
        let mut board = zc706();
        board.ddr_bytes_per_sec = 4.0e9;
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let bram = bram_total(&net, QuantMode::W16A16, &alloc);
        assert!(bram <= board.bram18(), "BRAM {bram} > {}", board.bram18());
        assert!(alloc.stages.iter().any(|s| s.cfg.k > 1));
        // And the relief must actually reduce traffic vs the K=1 baseline.
        let k1 = FlexAllocator { max_k_steps: 0, ..Default::default() }
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        assert!(
            alloc.evaluate().ddr_bytes_per_sec < k1.evaluate().ddr_bytes_per_sec
        );
    }

    #[test]
    fn eight_bit_doubles_multiplier_pool() {
        let net = zoo::zf();
        let board = zc706();
        let a16 = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let a8 = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W8A8)
            .unwrap();
        let (r16, r8) = (a16.evaluate(), a8.evaluate());
        assert!(
            r8.gops > 1.6 * r16.gops,
            "8-bit {} GOPS should be near 2x 16-bit {}",
            r8.gops,
            r16.gops
        );
    }
}

#[cfg(test)]
mod bw_tests {
    use super::*;
    use crate::alloc::Allocator;
    use crate::board::zc706;
    use crate::model::zoo;

    #[test]
    fn bandwidth_starved_board_throttles_fps() {
        // When BRAM can't buy enough weight reuse, fps must fall to the
        // DDR-sustainable rate instead of pretending to hit the compute
        // rate (paper Sec. 4.2's whole point).
        let net = zoo::vgg16();
        let rich = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W16A16)
            .unwrap()
            .evaluate();
        let mut starved_board = zc706();
        starved_board.ddr_bytes_per_sec = 1.5e9;
        let starved = FlexAllocator::default()
            .allocate(&net, &starved_board, QuantMode::W16A16)
            .unwrap()
            .evaluate();
        assert!(
            starved.fps < rich.fps * 0.7,
            "starved {} vs rich {}",
            starved.fps,
            rich.fps
        );
    }
}
