//! Resource allocation: the paper's Sec. 4 framework.
//!
//! [`flex`] implements the paper's Algorithm 1 (computation resources) and
//! Algorithm 2 (BRAM vs DDR bandwidth). [`baselines`] implements the three
//! comparison architectures of Table I: the DNNBuilder-style constrained
//! pipeline [3], the fusion/Winograd pipeline [2], and the recurrent
//! single-array design [1].
//!
//! An [`Allocation`] is the common artifact all of them produce; its
//! closed-form [`Allocation::evaluate`] applies Eq. 2–4 (the simulator in
//! [`crate::sim`] then confirms those numbers stall-accurately).

pub mod baselines;
pub mod flex;

use crate::board::Board;
use crate::engine::{self, buffer_geometry, cost, EngineConfig, EngineFigures};
use crate::model::{Layer, Network};
use crate::quant::QuantMode;

/// Which architecture produced an allocation (controls simulation style
/// and the Table I row it maps to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchKind {
    /// This work: flexible layer-wise pipeline.
    FlexPipeline,
    /// DNNBuilder-style pipeline [3]: power-of-2, matched interfaces.
    DnnBuilder,
    /// Fusion pipeline with Winograd convs [2].
    Fusion,
    /// Recurrent single PE array [1].
    Recurrent,
}

impl ArchKind {
    /// CLI/report label.
    pub fn label(&self) -> &'static str {
        match self {
            ArchKind::FlexPipeline => "flex",
            ArchKind::DnnBuilder => "dnnbuilder",
            ArchKind::Fusion => "fusion",
            ArchKind::Recurrent => "recurrent",
        }
    }

    /// Parse a CLI label.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "flex" | "this-work" => Ok(ArchKind::FlexPipeline),
            "dnnbuilder" | "dnnb" => Ok(ArchKind::DnnBuilder),
            "fusion" | "winograd" => Ok(ArchKind::Fusion),
            "recurrent" => Ok(ArchKind::Recurrent),
            other => anyhow::bail!("unknown arch '{other}' (flex dnnbuilder fusion recurrent)"),
        }
    }
}

/// One pipeline stage's chosen parameters + derived figures.
#[derive(Debug, Clone)]
pub struct StageAlloc {
    /// Index into `net.layers`.
    pub layer_idx: usize,
    /// Chosen `(C', M', K)`.
    pub cfg: EngineConfig,
    /// Derived static figures.
    pub figures: EngineFigures,
    /// Effective MAC gain for this stage (1 normally; 4 for Winograd
    /// stages in the fusion baseline — Sec. 5.2 "reduce number of
    /// multiplications into one quarter").
    pub mac_gain: f64,
}

/// A complete allocation for one network on one board.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Architecture that produced this allocation.
    pub arch: ArchKind,
    /// The network being accelerated.
    pub net: Network,
    /// The board allocated against.
    pub board: Board,
    /// Quantization mode.
    pub mode: QuantMode,
    /// One entry per layer of `net`.
    pub stages: Vec<StageAlloc>,
    /// Clock the architecture runs at (fusion baseline runs at 100 MHz).
    pub freq_hz: f64,
    /// Architecture-level efficiency derate applied on top of the pipeline
    /// model (1.0 for pipelines; <1 models the recurrent/fusion overheads
    /// that are not captured by stage figures — documented per baseline).
    pub arch_derate: f64,
    /// `None` = all stages pipeline concurrently (this work, DNNBuilder).
    /// `Some(groups)` = the groups execute *sequentially*, stages inside a
    /// group pipeline (fusion baseline: fused layer groups; recurrent
    /// baseline: every layer its own group).
    pub groups: Option<Vec<Vec<usize>>>,
    /// Cycles per frame not attributable to stage compute: inter-group DDR
    /// activation transfers, array reconfiguration (baselines only).
    pub extra_cycles: u64,
    /// The recurrent baseline shares one PE array across all layers —
    /// resources are counted once, not summed per stage.
    pub shared_array: bool,
}

/// Closed-form performance/resource summary (Eq. 2–4 + cost models).
#[derive(Debug, Clone)]
pub struct AllocReport {
    /// Pipeline beat: slowest stage's cycles per frame.
    pub t_frame_cycles: u64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
    /// Frames per second at `freq_hz`.
    pub fps: f64,
    /// Conventional GOPS (2 ops/MAC, paper's metric).
    pub gops: f64,
    /// Multipliers instantiated.
    pub mults: usize,
    /// DSP slices used.
    pub dsps: usize,
    /// Achieved / peak of used DSPs (paper's "DSP Efficiency").
    pub dsp_efficiency: f64,
    /// BRAM18 blocks used.
    pub bram18: usize,
    /// LUTs used.
    pub luts: usize,
    /// FFs used.
    pub ffs: usize,
    /// DDR bytes/second moved at the achieved (possibly throttled) rate.
    pub ddr_bytes_per_sec: f64,
    /// DDR bytes/second the *compute* rate would demand (Algorithm 2's B:
    /// un-throttled — when this exceeds the board's β the design is
    /// bandwidth-bound and fps is capped).
    pub ddr_demand_bytes_per_sec: f64,
    /// Per-stage cycles/frame (for balance plots).
    pub stage_cycles: Vec<u64>,
}

/// Performance-only summary: every [`AllocReport`] field that does *not*
/// require the per-stage buffer-geometry / logic cost walk. Produced by
/// [`Allocation::evaluate_perf`] — the allocator's inner loops (Algorithm 2
/// candidate evaluation, design-space search scoring) call this thousands
/// of times, so it must stay O(stages) with no geometry work.
///
/// Invariant (locked by property + golden tests): every field here is
/// computed by the *same arithmetic, in the same order*, as the matching
/// field of [`Allocation::evaluate`] — the two are bit-identical, not
/// merely close.
#[derive(Debug, Clone)]
pub struct PerfReport {
    /// Pipeline beat: slowest stage's cycles per frame.
    pub t_frame_cycles: u64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
    /// Frames per second at `freq_hz` (DDR-capped).
    pub fps: f64,
    /// Conventional GOPS (2 ops/MAC).
    pub gops: f64,
    /// Multipliers instantiated.
    pub mults: usize,
    /// DSP slices used.
    pub dsps: usize,
    /// Achieved / peak of used DSPs.
    pub dsp_efficiency: f64,
    /// DDR bytes/second at the achieved (possibly throttled) rate.
    pub ddr_bytes_per_sec: f64,
    /// DDR bytes/second the compute rate would demand (Algorithm 2's B).
    pub ddr_demand_bytes_per_sec: f64,
    /// Per-stage cycles/frame.
    pub stage_cycles: Vec<u64>,
}

/// BRAM18 blocks for the pipeline top (actIn/actOut packers, weight
/// streamer FIFOs) — fixed overhead beside per-stage buffers.
pub const TOP_BRAM18: usize = 24;

impl Allocation {
    /// Per-stage cycles/frame, with the fusion baseline's Winograd gain
    /// folded in (a Winograd stage finishes its rows `mac_gain`× faster).
    pub fn stage_cycles(&self) -> Vec<u64> {
        self.stages
            .iter()
            .map(|s| ((s.figures.cycles_per_frame() as f64) / s.mac_gain).ceil() as u64)
            .collect()
    }

    /// Cheap closed-form evaluation: Eq. 3/4 performance figures only, no
    /// buffer-geometry or logic-cost walk. This is the API the hot loops
    /// use (`FlexAllocator::raise_k` evaluates every candidate K-jump with
    /// it; the search engine scores thousands of design points). Fields are
    /// bit-identical to the matching [`Allocation::evaluate`] fields — see
    /// [`PerfReport`]'s invariant note.
    pub fn evaluate_perf(&self) -> PerfReport {
        let stage_cycles = self.stage_cycles();
        let (bottleneck, _) = stage_cycles
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .expect("allocation has stages");
        // Pipeline: beat = slowest stage. Sequential groups: the groups run
        // one after another, stages inside each group pipeline.
        let t_frame = match &self.groups {
            None => stage_cycles.iter().copied().max().unwrap_or(1),
            Some(groups) => groups
                .iter()
                .map(|g| g.iter().map(|&i| stage_cycles[i]).max().unwrap_or(0))
                .sum(),
        }
        .saturating_add(self.extra_cycles)
        .max(1);
        let fps_compute = self.freq_hz / t_frame as f64 * self.arch_derate;
        // DDR ceiling: when Algorithm 2 runs out of BRAM before reaching
        // the bandwidth budget, the pipeline throttles to what the port
        // sustains (weights + frame I/O per frame).
        let bytes_per_frame: f64 = self
            .stages
            .iter()
            .map(|s| s.figures.weight_bytes_per_frame() as f64)
            .sum::<f64>()
            + (self.net.input.0 * self.net.input.1 * self.net.input.2) as f64
                * self.mode.act_bytes() as f64;
        let fps_bw = self.board.ddr_bytes_per_sec / bytes_per_frame.max(1.0);
        let fps = fps_compute.min(fps_bw);
        let macs = self.net.macs();
        let gops = 2.0 * macs as f64 * fps / 1e9;

        let (mults, dsps): (usize, usize) = if self.shared_array {
            (
                self.stages.iter().map(|s| s.figures.mults).max().unwrap_or(0),
                self.stages.iter().map(|s| s.figures.dsps).max().unwrap_or(0),
            )
        } else {
            (
                self.stages.iter().map(|s| s.figures.mults).sum(),
                self.stages.iter().map(|s| s.figures.dsps).sum(),
            )
        };
        // Peak of the *used* DSPs at this mode's packing; Winograd stages
        // count their effective (conventional-equivalent) MACs.
        let peak_macs_per_cycle: f64 = if self.shared_array {
            mults as f64
        } else {
            self.stages
                .iter()
                .map(|s| s.figures.mults as f64 * s.mac_gain)
                .sum()
        };
        let dsp_efficiency = if peak_macs_per_cycle > 0.0 {
            (macs as f64 * fps) / (peak_macs_per_cycle * self.freq_hz)
        } else {
            0.0
        };

        // DDR traffic: weights per frame + input frames in + outputs back.
        let weight_bytes: u64 = self
            .stages
            .iter()
            .map(|s| s.figures.weight_bytes_per_frame())
            .sum();
        let (c0, h0, w0) = self.net.input;
        let in_bytes = (c0 * h0 * w0 * self.mode.act_bytes()) as u64;
        let out_bytes = 4 * 1024; // final activations: negligible, bounded
        let ddr = (weight_bytes + in_bytes + out_bytes) as f64 * fps;
        let ddr_demand = (weight_bytes + in_bytes + out_bytes) as f64 * fps_compute;

        PerfReport {
            t_frame_cycles: t_frame,
            bottleneck,
            fps,
            gops,
            mults,
            dsps,
            dsp_efficiency,
            ddr_bytes_per_sec: ddr,
            ddr_demand_bytes_per_sec: ddr_demand,
            stage_cycles,
        }
    }

    /// Full closed-form evaluation: the [`evaluate_perf`] figures plus the
    /// BRAM/LUT/FF resource walk (buffer geometry + logic cost per stage).
    ///
    /// [`evaluate_perf`]: Allocation::evaluate_perf
    pub fn evaluate(&self) -> AllocReport {
        let perf = self.evaluate_perf();

        let mut bram = TOP_BRAM18;
        let mut logic = vec![];
        if self.shared_array {
            // One physical engine reused by every layer: cost it once at
            // its worst-case geometry, plus the tile double-buffers the
            // recurrent dataflow needs for off-chip activation staging.
            let (worst, s) = self
                .stages
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.figures.mults)
                .expect("stages");
            let layer = &self.net.layers[s.layer_idx];
            let geo = buffer_geometry(layer, &s.cfg, 1, 1);
            bram += engine::bram18_cost(layer, &s.cfg, &geo, self.mode);
            bram += 200; // input/output tile double-buffers ([1]'s design)
            logic.push(cost::stage_logic(
                layer,
                &s.cfg,
                s.figures.mults,
                &geo,
                self.mode,
            ));
            let _ = worst;
        } else {
            for (i, s) in self.stages.iter().enumerate() {
                let layer = &self.net.layers[s.layer_idx];
                let (pk, pm) = self.producer(i);
                let geo = buffer_geometry(layer, &s.cfg, pk, pm);
                bram += engine::bram18_cost(layer, &s.cfg, &geo, self.mode);
                logic.push(cost::stage_logic(
                    layer,
                    &s.cfg,
                    s.figures.mults,
                    &geo,
                    self.mode,
                ));
            }
        }
        let total_logic = cost::total_logic(logic);

        AllocReport {
            t_frame_cycles: perf.t_frame_cycles,
            bottleneck: perf.bottleneck,
            fps: perf.fps,
            gops: perf.gops,
            mults: perf.mults,
            dsps: perf.dsps,
            dsp_efficiency: perf.dsp_efficiency,
            bram18: bram,
            luts: total_logic.luts,
            ffs: total_logic.ffs,
            ddr_bytes_per_sec: perf.ddr_bytes_per_sec,
            ddr_demand_bytes_per_sec: perf.ddr_demand_bytes_per_sec,
            stage_cycles: perf.stage_cycles,
        }
    }

    /// BRAM18 blocks one pipeline stage contributes (its activation buffer
    /// at the geometry induced by its producer, plus weight/psum memories).
    /// Isolated so incremental callers can recompute just the stages a
    /// config change touches: changing stage `i`'s `K` invalidates stage
    /// `i` (own geometry) and stage `i+1` (producer `K` seen downstream) —
    /// nothing else.
    pub fn stage_bram18(&self, i: usize) -> usize {
        let s = &self.stages[i];
        let (pk, pm) = self.producer(i);
        engine::stage_bram18(&self.net.layers[s.layer_idx], &s.cfg, pk, pm, self.mode)
    }

    /// Producer `(K, M')` seen by stage `i` (the DDR unpacker writes one
    /// row at a time at the line rate for stage 0).
    pub fn producer(&self, i: usize) -> (usize, usize) {
        if i == 0 {
            (1, 1)
        } else {
            let p = &self.stages[i - 1];
            let pm = match &self.net.layers[p.layer_idx] {
                Layer::Conv(_) | Layer::Fc(_) => p.cfg.mp,
                // Pools pass through the upstream write parallelism.
                Layer::Pool(_) => p.cfg.mp.max(1),
            };
            (p.cfg.k, pm)
        }
    }

    /// Does the allocation fit the board? Returns the violated resource.
    pub fn check_fit(&self) -> Result<(), String> {
        let r = self.evaluate();
        if r.dsps > self.board.dsps {
            return Err(format!("DSPs: {} > {}", r.dsps, self.board.dsps));
        }
        if r.bram18 > self.board.bram18() {
            return Err(format!("BRAM18: {} > {}", r.bram18, self.board.bram18()));
        }
        if r.luts > self.board.luts {
            return Err(format!("LUTs: {} > {}", r.luts, self.board.luts));
        }
        if r.ffs > self.board.ffs {
            return Err(format!("FFs: {} > {}", r.ffs, self.board.ffs));
        }
        Ok(())
    }
}

/// Common interface over the four architectures.
pub trait Allocator {
    /// Which Table I row this produces.
    fn arch(&self) -> ArchKind;
    /// Produce an allocation for `net` on `board` in `mode`.
    fn allocate(&self, net: &Network, board: &Board, mode: QuantMode) -> crate::Result<Allocation>;
}

/// Allocator instance for an [`ArchKind`].
pub fn allocator_for(arch: ArchKind) -> Box<dyn Allocator> {
    match arch {
        ArchKind::FlexPipeline => Box::new(flex::FlexAllocator::default()),
        ArchKind::DnnBuilder => Box::new(baselines::DnnBuilderAllocator),
        ArchKind::Fusion => Box::new(baselines::FusionAllocator),
        ArchKind::Recurrent => Box::new(baselines::RecurrentAllocator),
    }
}
