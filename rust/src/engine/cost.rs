//! LUT/FF cost model for the engine fabric.
//!
//! The paper implements engines in parameterized RTL; we substitute a linear
//! resource model calibrated against Table I's utilization columns (VGG16 on
//! ZC706: 54% LUT / 34% FF at 900 DSPs and 21 stages; AlexNet 51%/36% at
//! 864, etc.). The absolute constants are estimates — what the framework
//! *uses* them for is feasibility (does the allocation fit the board?) and
//! the utilization rows of the regenerated Table I, where ±15% is the
//! claimed fidelity (EXPERIMENTS.md).

use crate::engine::{BufferGeometry, EngineConfig};
use crate::model::Layer;
use crate::quant::QuantMode;

/// LUTs per fabric multiplier-lane: adder-tree slice, alignment shifter
/// share, and operand muxing around each DSP lane.
const LUT_PER_MULT: f64 = 95.0;
/// LUTs per channelBuffer: address generator + read mux lane.
const LUT_PER_CHB: f64 = 55.0;
/// Fixed LUTs per pipeline stage: controller FSM, zeroMac/flush/rowSel
/// generation, psum alignment.
const LUT_PER_STAGE: f64 = 1500.0;
/// Fixed LUTs for the top (DDR interface, actIn/actOut pack/unpack, AXI).
const LUT_TOP: f64 = 12_000.0;

/// FF ratios: MAC pipeline registers dominate (psum regs are 32-bit wide).
const FF_PER_MULT: f64 = 64.0;
const FF_PER_CHB: f64 = 40.0;
const FF_PER_STAGE: f64 = 1200.0;
const FF_TOP: f64 = 10_000.0;

/// LUT/FF totals for a full pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogicCost {
    /// Look-up tables used.
    pub luts: usize,
    /// Flip-flops used.
    pub ffs: usize,
}

/// Cost of one stage.
pub fn stage_logic(
    _layer: &Layer,
    _cfg: &EngineConfig,
    mults: usize,
    geo: &BufferGeometry,
    mode: QuantMode,
) -> LogicCost {
    // 8-bit mode packs two mults per DSP but still needs both result lanes'
    // fabric (separate adder trees), so fabric cost follows `mults`, not
    // DSPs. 16-bit lanes are wider: scale by bits/8 on the datapath share.
    let width_scale = mode.bits() as f64 / 16.0;
    let luts = LUT_PER_MULT * mults as f64 * (0.5 + 0.5 * width_scale)
        + LUT_PER_CHB * geo.channel_buffers as f64
        + LUT_PER_STAGE;
    let ffs = FF_PER_MULT * mults as f64 * (0.5 + 0.5 * width_scale)
        + FF_PER_CHB * geo.channel_buffers as f64
        + FF_PER_STAGE;
    LogicCost {
        luts: luts as usize,
        ffs: ffs as usize,
    }
}

/// Pipeline-top overhead.
pub fn top_logic() -> LogicCost {
    LogicCost {
        luts: LUT_TOP as usize,
        ffs: FF_TOP as usize,
    }
}

/// Sum stage costs plus the top.
pub fn total_logic(stages: impl IntoIterator<Item = LogicCost>) -> LogicCost {
    let mut total = top_logic();
    for s in stages {
        total.luts += s.luts;
        total.ffs += s.ffs;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{buffer_geometry, conv_figures};
    use crate::model::{conv, Layer};

    #[test]
    fn logic_scales_with_parallelism() {
        let l = conv(64, 64, 56, 56, 3, 1, 1);
        let Layer::Conv(c) = l else { unreachable!() };
        let small = EngineConfig { cp: 2, mp: 2, k: 1 };
        let big = EngineConfig { cp: 8, mp: 8, k: 1 };
        let geo_s = buffer_geometry(&l, &small, 1, 2);
        let geo_b = buffer_geometry(&l, &big, 1, 8);
        let cs = stage_logic(
            &l,
            &small,
            conv_figures(&c, &small, QuantMode::W16A16).mults,
            &geo_s,
            QuantMode::W16A16,
        );
        let cb = stage_logic(
            &l,
            &big,
            conv_figures(&c, &big, QuantMode::W16A16).mults,
            &geo_b,
            QuantMode::W16A16,
        );
        assert!(cb.luts > cs.luts && cb.ffs > cs.ffs);
    }

    #[test]
    fn eight_bit_fabric_cheaper_per_mult_but_not_half() {
        let l = conv(64, 64, 56, 56, 3, 1, 1);
        let Layer::Conv(c) = l else { unreachable!() };
        let cfg = EngineConfig { cp: 8, mp: 8, k: 1 };
        let geo = buffer_geometry(&l, &cfg, 1, 8);
        let mults = conv_figures(&c, &cfg, QuantMode::W16A16).mults;
        let c16 = stage_logic(&l, &cfg, mults, &geo, QuantMode::W16A16);
        let c8 = stage_logic(&l, &cfg, mults, &geo, QuantMode::W8A8);
        assert!(c8.luts < c16.luts);
        assert!(c8.luts * 2 > c16.luts);
    }
}
