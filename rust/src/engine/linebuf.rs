//! Functional model of the flexible activation line buffer (paper Sec. 3.3).
//!
//! The RTL buffer is a ring of `rowBuffers`, each split into
//! `max(C'_i, M'_{i−1})` channelBuffers, written by the producer at `M'`
//! channels/cycle and read by the consumer at `C'·R` pixels/cycle. The
//! "complicated reading sequence ... carefully processed by the appropriate
//! address generator" is modelled here functionally: rows carry sequence
//! numbers, slots are a ring, and every read checks it hits the row it
//! expects. The property tests in `rust/tests/` drive random geometries
//! through a full frame to show `R + G(K−1) + K_prev` slots always suffice.


/// Ring-of-rows line buffer with validity tracking.
#[derive(Debug, Clone)]
pub struct LineBuffer {
    /// Number of row slots (the BRAM geometry).
    slots: usize,
    /// Sequence number of the row held in each slot (`None` = empty).
    held: Vec<Option<u64>>,
    /// Next row sequence number the producer will write.
    next_write: u64,
    /// Rows the consumer has fully consumed (may be reclaimed).
    consumed_below: u64,
}

/// Error from an invalid buffer operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LineBufError {
    /// Writer found no free slot: consumer too slow for this geometry.
    Overrun { row: u64 },
    /// Reader asked for a row that is not resident.
    Miss { row: u64 },
}

impl std::fmt::Display for LineBufError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LineBufError::Overrun { row } => write!(f, "line buffer overrun writing row {row}"),
            LineBufError::Miss { row } => write!(f, "line buffer miss reading row {row}"),
        }
    }
}

impl std::error::Error for LineBufError {}

impl LineBuffer {
    /// A buffer with `slots` row buffers.
    pub fn new(slots: usize) -> Self {
        LineBuffer {
            slots,
            held: vec![None; slots],
            next_write: 0,
            consumed_below: 0,
        }
    }

    /// Slot count for a consumer window of `r` rows, stride `g`, consumer
    /// row-parallelism `k`, producer row-parallelism `k_prev`.
    ///
    /// **Deviation from the paper** (found by this functional model): Alg. 2
    /// line 5 sizes the write margin as `K_{i−1}`, but the engine pins its
    /// whole `R + G(K−1)` window for the entire group (every (C,M) phase
    /// re-reads all window rows), while the rate-matched producer delivers
    /// `G·K` rows per consumer beat. When `G·K > K_{i−1}` the paper's
    /// margin overruns; the safe margin is `max(K_{i−1}, G·K)`. For the
    /// paper's own stride-1, equal-K case this reduces to their
    /// `R + 2K − 1`, so Table I is unaffected. Property-tested in
    /// rust/tests/proptests.rs.
    pub fn required_slots(r: usize, g: usize, k: usize, k_prev: usize) -> usize {
        r + g * (k - 1) + k_prev.max(g * k)
    }

    /// Number of row slots (the BRAM geometry).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Number of resident rows.
    pub fn resident(&self) -> usize {
        self.held.iter().filter(|h| h.is_some()).count()
    }

    /// Highest row sequence written so far plus one.
    pub fn rows_written(&self) -> u64 {
        self.next_write
    }

    /// Producer writes the next row; returns the slot used.
    pub fn write_row(&mut self) -> Result<usize, LineBufError> {
        // Reclaim any slot whose row is fully consumed.
        let slot = self
            .held
            .iter()
            .position(|h| match h {
                None => true,
                Some(seq) => *seq < self.consumed_below,
            })
            .ok_or(LineBufError::Overrun {
                row: self.next_write,
            })?;
        self.held[slot] = Some(self.next_write);
        self.next_write += 1;
        Ok(slot)
    }

    /// Can the consumer read the window `[base, base+r)`?
    pub fn window_ready(&self, base: u64, r: usize) -> bool {
        (base..base + r as u64).all(|row| self.held.contains(&Some(row)))
    }

    /// Consumer reads rows `[base, base+r)` (one output-group window) and
    /// then declares rows below `retire` reclaimable (`retire` = first row
    /// still needed by the *next* window).
    pub fn read_window(&mut self, base: u64, r: usize, retire: u64) -> Result<Vec<usize>, LineBufError> {
        let mut slots = Vec::with_capacity(r);
        for row in base..base + r as u64 {
            let slot = self
                .held
                .iter()
                .position(|h| *h == Some(row))
                .ok_or(LineBufError::Miss { row })?;
            slots.push(slot);
        }
        self.consumed_below = self.consumed_below.max(retire);
        Ok(slots)
    }
}

/// Drive a full frame through a producer/consumer pair and report whether
/// `slots` row buffers suffice — with the *concurrent* semantics the RTL
/// has (Sec. 3.3: "to support simultaneous writing and reading"): while the
/// consumer holds its `r + g·(k−1)`-row window open for a whole group
/// computation, the producer concurrently writes the next `k_prev` rows.
/// Neither may touch the other's rows. Pure function used by tests and by
/// the allocator's feasibility check.
pub fn frame_fits(
    slots: usize,
    h_in: usize,
    r: usize,
    g: usize,
    k: usize,
    k_prev: usize,
) -> Result<(), LineBufError> {
    let mut buf = LineBuffer::new(slots);
    let window = r + g * (k - 1);
    let h_out = if h_in >= r { (h_in - r) / g + 1 } else { 0 };
    let groups = h_out.div_ceil(k);
    let mut written = 0usize;
    let mut owed = 0usize; // rows the rate-matched producer delivers this beat

    for group in 0..groups as u64 {
        let base = group * (g as u64) * (k as u64);
        let win = window.min(h_in - base as usize);
        // Fill phase: rows of the open window must be resident before the
        // group starts (rows below `base` were retired by the previous
        // group and are reclaimable).
        while (written as u64) < base + win as u64 {
            buf.write_row()?;
            written += 1;
        }
        // Concurrent phase: the window is pinned for the whole group
        // (every (C,M) phase re-reads it) while the rate-matched producer
        // delivers g·k new rows, bursting k_prev at a time.
        owed += g * k;
        let deliver = owed.min(h_in.saturating_sub(written));
        for _ in 0..deliver {
            buf.write_row()?;
            written += 1;
        }
        owed -= deliver;
        let _ = k_prev; // burst size ≤ margin by construction of required_slots
        // End of group: verify the window stayed resident, then retire
        // rows the next group no longer needs.
        let retire = (group + 1) * (g as u64) * (k as u64);
        buf.read_window(base, win, retire.min(h_in as u64))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_slot_count_suffices_stride1() {
        // Sec. 3.3: stride 1, K_prev = K → R + 2K − 1
        for (r, k) in [(3, 1), (3, 2), (5, 3), (1, 4)] {
            let slots = LineBuffer::required_slots(r, 1, k, k);
            assert_eq!(slots, r + 2 * k - 1);
            frame_fits(slots, 64, r, 1, k, k).unwrap_or_else(|e| {
                panic!("r={r} k={k}: {e}");
            });
        }
    }

    #[test]
    fn paper_slot_count_suffices_stride2() {
        for (r, k, kp) in [(3, 2, 1), (3, 1, 2), (5, 2, 2), (2, 2, 4)] {
            let slots = LineBuffer::required_slots(r, 2, k, kp);
            frame_fits(slots, 96, r, 2, k, kp).unwrap_or_else(|e| {
                panic!("r={r} k={k} kp={kp}: {e}");
            });
        }
    }

    #[test]
    fn undersized_buffer_overruns() {
        // R=3, K=2, K_prev=2, G=1 needs 3+1+2=6... minimum is R+G(K−1)+K_prev;
        // one slot fewer must fail somewhere in the frame.
        let slots = LineBuffer::required_slots(3, 1, 2, 2) - 1;
        assert!(frame_fits(slots, 64, 3, 1, 2, 2).is_err());
    }

    #[test]
    fn read_before_write_misses() {
        let mut buf = LineBuffer::new(4);
        assert!(!buf.window_ready(0, 3));
        assert_eq!(
            buf.read_window(0, 3, 0),
            Err(LineBufError::Miss { row: 0 })
        );
    }

    #[test]
    fn slots_are_reused_round_robin() {
        let mut buf = LineBuffer::new(3);
        let s0 = buf.write_row().unwrap();
        let _ = buf.write_row().unwrap();
        let _ = buf.write_row().unwrap();
        // consume row 0 so its slot can be reclaimed
        buf.read_window(0, 1, 1).unwrap();
        let s3 = buf.write_row().unwrap();
        assert_eq!(s0, s3, "reclaimed slot should be reused");
    }
}
