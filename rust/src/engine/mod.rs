//! Convolution layer engine micro-model (paper Sec. 3.3, Fig. 3).
//!
//! One engine = PE array (`M'×C'×R×S` multipliers) + weight buffer +
//! activation line buffer + psum scratchpad + controller. This module
//! models everything the allocator and simulator need:
//!
//! - cycle counts (`T_row`, Eq. 2 — generalized to non-divisor `C'`,`M'`
//!   with ceilings: that waste is exactly the intra-group inefficiency the
//!   flexible allocator minimizes),
//! - multiplier/DSP counts under the 8/16-bit packing rule,
//! - buffer geometry and BRAM cost (the flexible activation buffer is the
//!   paper's enabling trick: `R + G(K−1) + K_prev` rowBuffers of
//!   `max(C'_i, M'_{i−1})` channelBuffers),
//! - LUT/FF cost ([`cost`]),
//! - a functional line-buffer/address-generator model ([`linebuf`]).

pub mod cost;
pub mod linebuf;

use crate::model::{ConvShape, FcShape, Layer};
use crate::quant::QuantMode;

/// Frames per FC weight load. FC layers have zero intra-frame weight reuse
/// (each weight touches one MAC), so at batch 1 they would dominate DDR
/// traffic (VGG16: 247 MB/frame). The demo system streams several frames at
/// once (paper Sec. 5.1: the host "sends more input frames continuously"),
/// letting the FC engine hold a batch of flattened maps and reuse each
/// loaded weight tile across the batch — the standard fix, and the only way
/// the paper's AlexNet 230 FPS fits in ZC706 bandwidth.
pub const FC_BATCH: usize = 16;

/// Per-layer engine parameters chosen by the allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Input-channel parallelism `C'`.
    pub cp: usize,
    /// Output-channel parallelism `M'`.
    pub mp: usize,
    /// Row parallelism `K` (rows computed per weight load).
    pub k: usize,
}

impl EngineConfig {
    /// Minimal engine: 1×1 parallelism, single row.
    pub fn minimal() -> Self {
        EngineConfig { cp: 1, mp: 1, k: 1 }
    }
}

/// Static per-stage figures derived from (layer, config, mode).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineFigures {
    /// Multipliers instantiated: `C'·M'·R·S`.
    pub mults: usize,
    /// DSP slices consumed (packing rule applied).
    pub dsps: usize,
    /// Cycles to compute one `K`-row output group (Eq. 2, ceil form).
    pub t_row: u64,
    /// Output row groups per frame: `ceil(H/K)` (1 for FC).
    pub groups_per_frame: u64,
    /// Useful MACs per group (numerator of intra-group efficiency).
    pub macs_per_group: u64,
    /// Weight bytes loaded from DDR per group (weights reloaded per group;
    /// raising `K` is Alg. 2's reuse lever).
    pub weight_bytes_per_group: u64,
}

impl EngineFigures {
    /// Cycles per frame for this stage in isolation.
    pub fn cycles_per_frame(&self) -> u64 {
        self.t_row * self.groups_per_frame
    }

    /// Intra-group multiplier efficiency: fraction of MAC slots doing
    /// useful work within a busy group (1.0 when `C' | C` and `M' | M`).
    pub fn intra_efficiency(&self) -> f64 {
        let slots = self.mults as u64 * self.t_row;
        if slots == 0 {
            return 0.0;
        }
        self.macs_per_group as f64 / slots as f64
    }

    /// Weight bytes per frame.
    pub fn weight_bytes_per_frame(&self) -> u64 {
        self.weight_bytes_per_group * self.groups_per_frame
    }
}

/// Compute the static figures for a conv stage.
pub fn conv_figures(c: &ConvShape, cfg: &EngineConfig, mode: QuantMode) -> EngineFigures {
    let c_eff = c.c / c.groups;
    let cp = cfg.cp.min(c_eff);
    let mp = cfg.mp.min(c.m);
    let mults = cp * mp * c.r * c.s;
    let phases = div_ceil(c_eff, cp) as u64 * div_ceil(c.m, mp) as u64;
    // Eq. 2: T_row = K · W · (C/C') · (M/M'), with ceilings for the general
    // (non-divisor) case the flexible buffer supports.
    let t_row = cfg.k as u64 * c.w as u64 * phases;
    let groups = div_ceil(c.h, cfg.k) as u64;
    let macs_group = (cfg.k as u64 * c.w as u64)
        .min(c.h as u64 * c.w as u64)
        * c.r as u64
        * c.s as u64
        * c_eff as u64
        * c.m as u64;
    EngineFigures {
        mults,
        dsps: div_ceil(mults, mode.mults_per_dsp()),
        t_row,
        groups_per_frame: groups,
        macs_per_group: macs_group,
        weight_bytes_per_group: c.weights() * mode.act_bytes() as u64,
    }
}

/// Compute the static figures for an FC stage (a `1×1` conv on a `1×1`
/// map: `C=n_in`, `M=n_out`, one group per frame).
pub fn fc_figures(f: &FcShape, cfg: &EngineConfig, mode: QuantMode) -> EngineFigures {
    let cp = cfg.cp.min(f.n_in);
    let mp = cfg.mp.min(f.n_out);
    let mults = cp * mp;
    let t_row = div_ceil(f.n_in, cp) as u64 * div_ceil(f.n_out, mp) as u64;
    EngineFigures {
        mults,
        dsps: div_ceil(mults, mode.mults_per_dsp()),
        t_row,
        groups_per_frame: 1,
        macs_per_group: f.macs(),
        // Amortized per frame over the FC batch (see FC_BATCH).
        weight_bytes_per_group: f.macs() * mode.act_bytes() as u64 / FC_BATCH as u64,
    }
}

/// Static figures for any stage. Pooling consumes no DSPs and tracks the
/// producer rate (its `t_row` models the comparator pipeline: `K·W` cycles
/// per group of `K` output rows).
pub fn figures(layer: &Layer, cfg: &EngineConfig, mode: QuantMode) -> EngineFigures {
    match layer {
        Layer::Conv(c) => conv_figures(c, cfg, mode),
        Layer::Fc(f) => fc_figures(f, cfg, mode),
        Layer::Pool(p) => EngineFigures {
            mults: 0,
            dsps: 0,
            t_row: cfg.k as u64 * p.w as u64,
            groups_per_frame: div_ceil(p.h, cfg.k) as u64,
            macs_per_group: 0,
            weight_bytes_per_group: 0,
        },
    }
}

/// Activation-buffer geometry between stage `i−1` (producer, parallelism
/// `M'_{i−1}`) and stage `i` (consumer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferGeometry {
    /// Row buffers: `R + G·(K_i − 1) + K_{i−1}` (paper Alg. 2 line 5; for
    /// `G=1, K_{i−1}=K_i=K` this is the Sec. 3.3 `R + 2K − 1`).
    pub row_buffers: usize,
    /// Channel buffers per row: `max(C'_i, M'_{i−1})`.
    pub channel_buffers: usize,
    /// Pixels per row buffer (input width × channels).
    pub pixels_per_row: usize,
}

impl BufferGeometry {
    /// Total pixels buffered.
    pub fn pixels(&self) -> usize {
        self.row_buffers * self.pixels_per_row
    }
}

/// Geometry of the buffer feeding a stage. `prod_k`/`prod_mp` describe the
/// producing stage (the DDR unpacker for the first stage).
pub fn buffer_geometry(
    layer: &Layer,
    cfg: &EngineConfig,
    prod_k: usize,
    prod_mp: usize,
) -> BufferGeometry {
    match layer {
        // Write margin is max(K_prev, G·K), not the paper's K_prev — see
        // linebuf::required_slots for the deviation note.
        Layer::Conv(c) => BufferGeometry {
            row_buffers: c.r + c.stride * (cfg.k - 1) + prod_k.max(c.stride * cfg.k),
            channel_buffers: cfg.cp.min(c.c).max(prod_mp),
            pixels_per_row: c.in_w() * c.c,
        },
        // Pooling reads each input row exactly once (single comparator
        // pass, no per-(C,M)-phase re-reads), so rows retire as the window
        // slides; the margin only needs to absorb the producer's burst.
        Layer::Pool(p) => BufferGeometry {
            row_buffers: p.r + p.stride * (cfg.k - 1) + prod_k.max(1),
            channel_buffers: prod_mp.max(1),
            pixels_per_row: ((p.w - 1) * p.stride + p.r) * p.c,
        },
        Layer::Fc(f) => BufferGeometry {
            // FC input is fully buffered (it needs the whole flattened map).
            row_buffers: 1,
            channel_buffers: cfg.cp.min(f.n_in).max(prod_mp),
            pixels_per_row: f.n_in,
        },
    }
}

/// BRAM18 blocks for one stage: activation buffer + double-buffered weight
/// buffer + psum scratchpad.
pub fn bram18_cost(
    layer: &Layer,
    cfg: &EngineConfig,
    geo: &BufferGeometry,
    mode: QuantMode,
) -> usize {
    const BRAM18_BITS: usize = 18 * 1024;
    let act_bits = mode.bits();
    // Each channelBuffer is an independently addressed memory, but BRAM18
    // blocks are dual-ported: two small channelBuffers share one block
    // (one port each), so the count is max(capacity bound, port bound).
    let pixels_per_chb = div_ceil(geo.pixels_per_row, geo.channel_buffers) * geo.row_buffers;
    let capacity_bound =
        div_ceil(geo.channel_buffers * pixels_per_chb * act_bits, BRAM18_BITS);
    let port_bound = div_ceil(geo.channel_buffers, 2);
    let act = capacity_bound.max(port_bound).max(1);
    let (weight, psum) = match layer {
        Layer::Conv(c) => {
            let c_eff = c.c / c.groups;
            let wbits = 2 * cfg.cp.min(c_eff) * cfg.mp.min(c.m) * c.r * c.s * act_bits;
            let pbits = cfg.mp.min(c.m) * cfg.k * c.w * 32;
            (
                div_ceil(wbits, BRAM18_BITS).max(2),
                div_ceil(pbits, BRAM18_BITS).max(1),
            )
        }
        Layer::Fc(f) => {
            let wbits = 2 * cfg.cp.min(f.n_in) * cfg.mp.min(f.n_out) * act_bits;
            (div_ceil(wbits, BRAM18_BITS).max(2), 1)
        }
        Layer::Pool(_) => (0, 0),
    };
    act + weight + psum
}

/// BRAM18 blocks for one stage in one call (geometry + cost). The hot
/// incremental paths (`alloc::flex::FlexAllocator::raise_k`'s per-candidate
/// delta, `alloc::Allocation::stage_bram18`) use this so a stage's BRAM
/// contribution can be recomputed in isolation when only that stage (or its
/// producer) changed.
pub fn stage_bram18(
    layer: &Layer,
    cfg: &EngineConfig,
    prod_k: usize,
    prod_mp: usize,
    mode: QuantMode,
) -> usize {
    let geo = buffer_geometry(layer, cfg, prod_k, prod_mp);
    bram18_cost(layer, cfg, &geo, mode)
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::conv;

    fn vgg_conv2_2() -> ConvShape {
        let Layer::Conv(c) = conv(128, 128, 112, 112, 3, 1, 1) else {
            unreachable!()
        };
        c
    }

    #[test]
    fn t_row_matches_eq2_on_exact_divisors() {
        // Eq. 2: T_row = K·W·(C/C')·(M/M')
        let c = vgg_conv2_2();
        let cfg = EngineConfig { cp: 8, mp: 16, k: 2 };
        let f = conv_figures(&c, &cfg, QuantMode::W16A16);
        assert_eq!(f.t_row, 2 * 112 * (128 / 8) * (128 / 16));
    }

    #[test]
    fn intra_efficiency_is_one_on_exact_divisors() {
        let c = vgg_conv2_2();
        let cfg = EngineConfig { cp: 8, mp: 16, k: 2 };
        let f = conv_figures(&c, &cfg, QuantMode::W16A16);
        assert!((f.intra_efficiency() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn intra_efficiency_degrades_on_non_divisors() {
        let c = vgg_conv2_2();
        // C'=7 does not divide 128: ceil(128/7)=19 phases, 7·19=133 slots
        let cfg = EngineConfig { cp: 7, mp: 16, k: 2 };
        let f = conv_figures(&c, &cfg, QuantMode::W16A16);
        let expect = 128.0 / (7.0 * 19.0);
        assert!((f.intra_efficiency() - expect).abs() < 1e-12);
    }

    #[test]
    fn dsp_packing_halves_at_8bit() {
        let c = vgg_conv2_2();
        let cfg = EngineConfig { cp: 4, mp: 4, k: 1 };
        let f16 = conv_figures(&c, &cfg, QuantMode::W16A16);
        let f8 = conv_figures(&c, &cfg, QuantMode::W8A8);
        assert_eq!(f16.mults, f8.mults);
        assert_eq!(f16.dsps, 2 * f8.dsps);
    }

    #[test]
    fn raising_k_cuts_weight_traffic() {
        // Alg. 2's lever: ω_i = H·R·S·C·M/K
        let c = vgg_conv2_2();
        let f1 = conv_figures(&c, &EngineConfig { cp: 8, mp: 8, k: 1 }, QuantMode::W16A16);
        let f4 = conv_figures(&c, &EngineConfig { cp: 8, mp: 8, k: 4 }, QuantMode::W16A16);
        assert_eq!(
            f1.weight_bytes_per_frame(),
            4 * f4.weight_bytes_per_frame()
        );
    }

    #[test]
    fn buffer_rows_match_sec33_for_stride1_equal_k() {
        // stride 1, K_prev = K = 3, R = 3 → R + 2K − 1 = 8
        let l = conv(64, 64, 112, 112, 3, 1, 1);
        let cfg = EngineConfig { cp: 8, mp: 8, k: 3 };
        let geo = buffer_geometry(&l, &cfg, 3, 8);
        assert_eq!(geo.row_buffers, 3 + 1 * 2 + 3);
        assert_eq!(geo.row_buffers, 8); // R + 2K − 1
    }

    #[test]
    fn channel_buffers_take_max_of_interface_parallelisms() {
        // The flexible buffer's whole point: C'_i ≠ M'_{i−1} is fine.
        let l = conv(64, 64, 56, 56, 3, 1, 1);
        let cfg = EngineConfig { cp: 3, mp: 8, k: 1 };
        let geo = buffer_geometry(&l, &cfg, 1, 20);
        assert_eq!(geo.channel_buffers, 20);
        let geo2 = buffer_geometry(&l, &cfg, 1, 2);
        assert_eq!(geo2.channel_buffers, 3);
    }

    #[test]
    fn fc_figures_single_group() {
        let f = FcShape { n_in: 400, n_out: 120 };
        let cfg = EngineConfig { cp: 8, mp: 4, k: 1 };
        let fig = fc_figures(&f, &cfg, QuantMode::W16A16);
        assert_eq!(fig.groups_per_frame, 1);
        assert_eq!(fig.t_row, (400 / 8) * (120 / 4));
        assert!((fig.intra_efficiency() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bram_cost_grows_with_k() {
        let l = conv(128, 128, 56, 56, 3, 1, 1);
        let g1 = buffer_geometry(&l, &EngineConfig { cp: 8, mp: 8, k: 1 }, 1, 8);
        let g4 = buffer_geometry(&l, &EngineConfig { cp: 8, mp: 8, k: 4 }, 1, 8);
        let b1 = bram18_cost(&l, &EngineConfig { cp: 8, mp: 8, k: 1 }, &g1, QuantMode::W16A16);
        let b4 = bram18_cost(&l, &EngineConfig { cp: 8, mp: 8, k: 4 }, &g4, QuantMode::W16A16);
        assert!(b4 > b1, "more rows buffered must cost more BRAM");
    }
}
