//! In-tree substrates replacing crates unavailable in the offline vendor
//! set (DESIGN.md §5): JSON, CLI parsing, property testing, benchmarking.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
