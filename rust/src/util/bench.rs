//! Minimal criterion-style bench harness (substrate — no criterion in the
//! offline vendor set). Used by the `harness = false` targets under
//! `rust/benches/`.
//!
//! Measures wall time with warmup, adaptive iteration count, and reports
//! mean / p50 / p95 per iteration plus a user-supplied throughput unit.

use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Command-line options shared by the `harness = false` bench targets.
///
/// Every bench binary accepts the same two options after `cargo bench
/// --bench NAME --`:
///
/// - `--budget SECS` — per-benchmark time budget (CI smoke runs pass a
///   tiny value so the binaries finish in seconds),
/// - `--json PATH` — where to write the machine-readable summary; the
///   default is `BENCH_<name>.json` at the repository root.
///
/// Unknown arguments are ignored so harness pass-throughs stay harmless.
pub struct BenchOpts {
    /// Per-benchmark time budget in seconds.
    pub budget_secs: f64,
    /// Resolved output path for the machine-readable summary.
    pub json: PathBuf,
}

impl BenchOpts {
    /// Parse `std::env::args`, falling back to the given defaults.
    pub fn parse(default_budget_secs: f64, default_json: PathBuf) -> BenchOpts {
        Self::from_args(std::env::args().skip(1), default_budget_secs, default_json)
    }

    fn from_args<I: Iterator<Item = String>>(
        args: I,
        default_budget_secs: f64,
        default_json: PathBuf,
    ) -> BenchOpts {
        let mut opts = BenchOpts {
            budget_secs: default_budget_secs,
            json: default_json,
        };
        let argv: Vec<String> = args.collect();
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--budget" => {
                    if let Some(v) = argv.get(i + 1).and_then(|s| s.parse::<f64>().ok()) {
                        if v > 0.0 {
                            opts.budget_secs = v;
                        }
                    }
                    i += 2;
                }
                "--json" => {
                    if let Some(p) = argv.get(i + 1) {
                        opts.json = PathBuf::from(p);
                    }
                    i += 2;
                }
                _ => i += 1,
            }
        }
        opts
    }

    /// Build the runner with the parsed budget.
    pub fn bench(&self) -> Bench {
        Bench::with_budget_secs(self.budget_secs)
    }

    /// Write the machine-readable summary to the resolved path, reporting
    /// the outcome on stdout/stderr.
    pub fn write(&self, json: &str) {
        match std::fs::write(&self.json, json) {
            Ok(()) => println!("wrote {}", self.json.display()),
            Err(e) => eprintln!("could not write {}: {e}", self.json.display()),
        }
    }
}

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Median wall time per iteration.
    pub p50: Duration,
    /// 95th-percentile wall time per iteration.
    pub p95: Duration,
}

impl Summary {
    fn fmt_dur(d: Duration) -> String {
        let ns = d.as_nanos();
        if ns < 10_000 {
            format!("{ns} ns")
        } else if ns < 10_000_000 {
            format!("{:.2} µs", ns as f64 / 1e3)
        } else if ns < 10_000_000_000 {
            format!("{:.2} ms", ns as f64 / 1e6)
        } else {
            format!("{:.2} s", ns as f64 / 1e9)
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            Self::fmt_dur(self.mean),
            Self::fmt_dur(self.p50),
            Self::fmt_dur(self.p95),
            self.iters
        )
    }
}

/// Bench runner with a fixed time budget per benchmark.
pub struct Bench {
    /// Target measurement time per benchmark.
    pub budget: Duration,
    /// Collected summaries (for a final table).
    pub results: Vec<Summary>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Construct with a per-benchmark time budget (seconds).
    pub fn with_budget_secs(s: f64) -> Self {
        Bench {
            budget: Duration::from_secs_f64(s),
            ..Default::default()
        }
    }

    /// Run one benchmark; `f` must do one full unit of work per call and
    /// return something (guards against dead-code elimination).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Summary {
        // Warmup: one call to estimate per-iter cost.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let est = t0.elapsed().max(Duration::from_nanos(50));

        let target_iters = (self.budget.as_secs_f64() / est.as_secs_f64()).clamp(1.0, 1e6) as usize;
        let mut times = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let s = Summary {
            name: name.to_string(),
            iters: times.len(),
            mean: total / times.len() as u32,
            p50: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
        };
        println!("{s}");
        self.results.push(s);
        self.results.last().unwrap()
    }

    /// Print a closing rule (cosmetic parity with criterion's output).
    pub fn finish(&self) {
        println!("{} benchmarks, done", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::with_budget_secs(0.05);
        let s = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 1);
        assert!(s.mean.as_nanos() > 0);
    }

    #[test]
    fn bench_opts_parse_overrides_and_ignores_unknowns() {
        let argv = ["--verbose", "--budget", "0.25", "--json", "out.json", "extra"];
        let o = BenchOpts::from_args(
            argv.iter().map(|s| s.to_string()),
            2.0,
            PathBuf::from("BENCH_default.json"),
        );
        assert_eq!(o.budget_secs, 0.25);
        assert_eq!(o.json, PathBuf::from("out.json"));

        // Defaults survive absent / malformed values.
        let o = BenchOpts::from_args(
            ["--budget", "nope"].iter().map(|s| s.to_string()),
            1.5,
            PathBuf::from("BENCH_default.json"),
        );
        assert_eq!(o.budget_secs, 1.5);
        assert_eq!(o.json, PathBuf::from("BENCH_default.json"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(Summary::fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(Summary::fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(Summary::fmt_dur(Duration::from_millis(50)).contains("ms"));
    }
}
