//! Minimal JSON parser/serializer (substrate — no serde in the offline
//! vendor set).
//!
//! Full RFC 8259 value model with the subset of ergonomics this crate
//! needs: object field access, typed getters, pretty printing. Strings
//! support the standard escapes incl. `\uXXXX` (surrogate pairs included);
//! numbers parse through `f64` (every value this crate serializes is well
//! inside the 2^53 integer range).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Number (all JSON numbers are f64 here).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field lookup that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed usize field.
    pub fn usize_field(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a non-negative integer"))
    }

    /// Typed string field.
    pub fn str_field(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    /// Typed f64 field (accepts any JSON number).
    pub fn f64_field(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    /// Typed bool field.
    pub fn bool_field(&self, key: &str) -> crate::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a boolean"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, Some(2), 0);
        s
    }
}

/// Build an object from pairs (test/serialization ergonomics).
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Numeric value from usize.
pub fn num(n: usize) -> Value {
    Value::Num(n as f64)
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_value(out, item, indent, depth + 1);
            }
            if !a.is_empty() {
                newline(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, item, indent, depth + 1);
            }
            if !m.is_empty() {
                newline(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> crate::Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> crate::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(
            got == b,
            "expected '{}' at byte {}, got '{}'",
            b as char,
            self.pos - 1,
            got as char
        );
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> crate::Result<Value> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> crate::Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> crate::Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
        Ok(Value::Obj(m))
    }

    fn array(&mut self) -> crate::Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
        Ok(Value::Arr(a))
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            anyhow::ensure!(
                                (0xDC00..0xE000).contains(&lo),
                                "invalid low surrogate"
                            );
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    c => anyhow::bail!("invalid escape '\\{}'", c as char),
                },
                c if c < 0x20 => anyhow::bail!("raw control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    let start = self.pos - 1;
                    for _ in 0..len {
                        self.bump()?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8"))?,
                    );
                }
            }
        }
        Ok(s)
    }

    fn hex4(&mut self) -> crate::Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()? as char;
            v = v * 16
                + c.to_digit(16)
                    .ok_or_else(|| anyhow::anyhow!("invalid hex digit '{c}'"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> crate::Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number '{text}'"))?;
        Ok(Value::Num(n))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_types() {
        let text = r#"{"a":[1,2.5,-3],"b":"hi\n\"there\"","c":true,"d":null,"e":{}}"#;
        let v = parse(text).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("b").unwrap(), "hi\n\"there\"");
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ✓");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn pretty_prints_stably() {
        let v = obj(vec![("z", num(1)), ("a", num(2))]);
        // BTreeMap: keys sorted → deterministic output
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
        assert!(v.to_pretty().contains("\n  \"a\": 2"));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn typed_getters() {
        let v = parse(r#"{"n": 7, "s": "x", "f": 2.5, "b": true}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 7);
        assert!(v.usize_field("s").is_err());
        assert!(v.usize_field("missing").is_err());
        assert_eq!(v.f64_field("f").unwrap(), 2.5);
        assert_eq!(v.f64_field("n").unwrap(), 7.0);
        assert!(v.f64_field("s").is_err());
        assert!(v.bool_field("b").unwrap());
        assert!(v.bool_field("n").is_err());
    }

    #[test]
    fn floats_round_trip_bit_exactly() {
        // The deployment-plan format leans on this: Rust's shortest
        // round-trip float Display means serialize → parse is identity
        // at the bit level for every finite f64.
        for x in [0.1, 1.0 / 3.0, 12.8e9, 1e-12, 123456.789012345, 145e6] {
            let text = Value::Num(x).to_string();
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x} → {text}");
        }
    }
}
