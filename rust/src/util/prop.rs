//! Tiny deterministic property-testing harness (substrate — no proptest in
//! the offline vendor set).
//!
//! A [`Rng`] (xorshift64*, seeded per test) feeds generator closures; the
//! [`check`] runner executes N cases and reports the failing case's inputs
//! via the panic message of the property closure itself (generators should
//! format inputs into assertions). Deterministic by construction: the same
//! test sees the same cases on every run — no flakes, easy reproduction.

/// xorshift64* PRNG — tiny, seedable, good enough for case generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded RNG (seed 0 is remapped — xorshift fixpoint).
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed })
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn urange(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as i64, hi as i64) as usize
    }

    /// Pick one element. Panics with an explicit message on an empty
    /// slice (the bare `len() - 1` indexing used to underflow, which
    /// surfaced as a cryptic `attempt to subtract with overflow`).
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(
            !items.is_empty(),
            "Rng::pick on an empty slice — the generator must supply at least one candidate"
        );
        &items[self.urange(0, items.len() - 1)]
    }

    /// Coin flip.
    pub fn flip(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `cases` deterministic property cases. The property closure receives
/// a per-case RNG; it should `panic!`/`assert!` with enough context to
/// reproduce (the case index is echoed by this runner on failure).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0xF1E2_D3C4_B5A6_9788 ^ (case as u64).wrapping_mul(0x9E3779B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut r = Rng::new(7);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failing_case() {
        check("always-fails", 3, |_| panic!("boom"));
    }

    #[test]
    #[should_panic(expected = "Rng::pick on an empty slice")]
    fn pick_empty_slice_panics_with_explicit_message() {
        let mut r = Rng::new(1);
        let empty: &[u8] = &[];
        r.pick(empty);
    }

    #[test]
    fn pick_covers_all_elements() {
        let mut r = Rng::new(9);
        let items = [10usize, 20, 30];
        let mut seen = [false; 3];
        for _ in 0..200 {
            let v = *r.pick(&items);
            seen[v / 10 - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
