//! Tiny CLI argument parser (substrate — no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! collects unknown-option errors and auto-generates usage text.

use std::collections::BTreeMap;

/// Parsed arguments: options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Option/flag declaration for validation + usage text.
#[derive(Debug, Clone)]
pub struct Spec {
    /// `--name`.
    pub name: &'static str,
    /// Takes a value?
    pub takes_value: bool,
    /// Usage line help.
    pub help: &'static str,
    /// Default shown in help (informational).
    pub default: Option<&'static str>,
}

/// Declare an option that takes a value.
pub const fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> Spec {
    Spec {
        name,
        takes_value: true,
        help,
        default,
    }
}

/// Declare a boolean flag.
pub const fn flag(name: &'static str, help: &'static str) -> Spec {
    Spec {
        name,
        takes_value: false,
        help,
        default: None,
    }
}

impl Args {
    /// Parse `argv` (no program name) against the declared specs.
    pub fn parse(argv: &[String], specs: &[Spec]) -> crate::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", usage(specs)))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    out.opts.insert(name.to_string(), v);
                } else {
                    anyhow::ensure!(inline.is_none(), "--{name} takes no value");
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Option value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Option value or default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parsed numeric option.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value for --{name}: '{v}'")),
        }
    }

    /// Was the flag passed?
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Split a comma-separated CLI list, trimming entries and dropping empty
/// segments (`"a, b,,c"` → `["a", "b", "c"]`). Shared by every
/// list-valued flag of the `flexipipe` CLI.
pub fn split_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|p| p.trim().to_string())
        .filter(|p| !p.is_empty())
        .collect()
}

/// Parse a duration with a **required** unit suffix (`s`, `ms`, `us`,
/// `m` for minutes, or `h` for hours) into seconds — `"33ms"` → `0.033`,
/// `"5m"` → `300`. Bare numbers are rejected: a unitless `33` silently
/// read as seconds when the author meant milliseconds is a 1000× error,
/// so the unit must be spelled. The long suffixes `ms`/`us` are matched
/// before the single-letter ones so `33ms` never parses as minutes.
/// Shared by every duration-valued surface of the `flexipipe` CLI
/// (`--slo`, `serve --trace` durations, `trace gen` flags, control-plane
/// request deadlines).
pub fn parse_duration_s(s: &str) -> crate::Result<f64> {
    let s = s.trim();
    let (num, scale) = if let Some(v) = s.strip_suffix("ms") {
        (v, 1e-3)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e-6)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0)
    } else if let Some(v) = s.strip_suffix('m') {
        (v, 60.0)
    } else if let Some(v) = s.strip_suffix('h') {
        (v, 3600.0)
    } else {
        anyhow::bail!(
            "duration '{s}' has no unit — write an explicit suffix: s, ms, us, m, or h (e.g. 33ms)"
        );
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration '{s}' (expected e.g. 0.05s, 33ms, 250us)"))?;
    anyhow::ensure!(
        v > 0.0 && v.is_finite(),
        "duration '{s}' must be positive and finite"
    );
    Ok(v * scale)
}

/// Render usage text for a spec set.
pub fn usage(specs: &[Spec]) -> String {
    let mut s = String::from("options:\n");
    for spec in specs {
        let val = if spec.takes_value { " <value>" } else { "" };
        let def = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\t{}{def}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            opt("model", "network name", Some("vgg16")),
            opt("bits", "quantization", Some("16")),
            flag("verbose", "more output"),
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = Args::parse(&sv(&["--model", "zf", "--bits=8", "--verbose", "extra"]), &specs())
            .unwrap();
        assert_eq!(a.get("model"), Some("zf"));
        assert_eq!(a.get_parse::<usize>("bits", 16).unwrap(), 8);
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn rejects_unknown_options() {
        assert!(Args::parse(&sv(&["--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--model"]), &specs()).is_err());
    }

    #[test]
    fn split_list_trims_and_drops_empties() {
        assert_eq!(split_list("a, b,,c"), vec!["a", "b", "c"]);
        assert!(split_list(" , ").is_empty());
        assert_eq!(split_list("one"), vec!["one"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_or("model", "vgg16"), "vgg16");
        assert_eq!(a.get_parse::<usize>("bits", 16).unwrap(), 16);
    }

    #[test]
    fn duration_suffixes_scale_to_seconds() {
        assert!((parse_duration_s("33ms").unwrap() - 0.033).abs() < 1e-12);
        assert!((parse_duration_s("250us").unwrap() - 250e-6).abs() < 1e-15);
        assert!((parse_duration_s("0.05s").unwrap() - 0.05).abs() < 1e-12);
        assert!((parse_duration_s(" 2s ").unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn unitless_duration_is_rejected_naming_suffixes() {
        let err = parse_duration_s("33").unwrap_err().to_string();
        assert!(err.contains("no unit"), "{err}");
        assert!(
            err.contains("s, ms, us, m, or h"),
            "error must name the accepted suffixes: {err}"
        );
    }

    #[test]
    fn nonpositive_and_garbage_durations_are_rejected() {
        assert!(parse_duration_s("0s").is_err());
        assert!(parse_duration_s("-5ms").is_err());
        assert!(parse_duration_s("infs").is_err());
        assert!(parse_duration_s("abcms").is_err());
        assert!(parse_duration_s("ms").is_err());
    }

    #[test]
    fn minute_and_hour_suffixes_scale_to_seconds() {
        assert!((parse_duration_s("5m").unwrap() - 300.0).abs() < 1e-9);
        assert!((parse_duration_s("0.5h").unwrap() - 1800.0).abs() < 1e-9);
        assert!((parse_duration_s("2h").unwrap() - 7200.0).abs() < 1e-9);
        // `ms` keeps winning over a trailing `s` or `m` read.
        assert!((parse_duration_s("90ms").unwrap() - 0.09).abs() < 1e-12);
    }

    #[test]
    fn bad_minute_hour_durations_carry_the_offending_string() {
        for bad in ["-5m", "infh", "nanm", "0h", "h", "m"] {
            let err = parse_duration_s(bad).unwrap_err().to_string();
            let core = bad.trim();
            assert!(err.contains(core), "error for '{bad}' must quote it: {err}");
        }
    }
}
