//! Plan-centric public API: `Workload` → [`Planner`] → [`DeploymentPlan`].
//!
//! The paper's framework is one flow — describe the CNN workload and the
//! board, allocate balanced resources (Sec. 4), validate by simulation,
//! deploy — and this module is that flow as an API. Three pieces:
//!
//! - [`Workload`]: *what* must be served — tenant models with weights and
//!   typed [`Constraint`]s (latency SLO ceilings, fps floors) plus the
//!   [`Objective`] used to pick among feasible plans.
//! - [`Planner`]: *how* to map it — one builder routing to solo
//!   allocation (a one-tenant workload is the plain Sec. 4 allocator),
//!   spatial / temporal / overlay board sharing
//!   ([`crate::shard::Sharder`]), or a multi-board sweep (each board's
//!   plan space is enumerated and the results merge into one frontier;
//!   for full grid sweeps over models × precisions × budgets, see
//!   [`crate::search::DesignSpace`], which this facade fronts for the
//!   board axis).
//! - [`DeploymentPlan`]: *the artifact* — a versioned, JSON-serializable
//!   record of one feasible deployment (per-tenant θ/α quanta, schedule
//!   layout, reconfiguration model, provisioned DDR shares) that is the
//!   only currency between subsystems: [`crate::sim::Simulate`] executes
//!   it, [`crate::coordinator::Coordinator::start_planned`] serves it,
//!   and a plan written to disk re-simulates **bit-identically** to the
//!   in-process search (regression-pinned), so plans can be diffed,
//!   shipped, and regression-tested as files.
//!
//! ```
//! use flexipipe::board::zedboard;
//! use flexipipe::model::zoo;
//! use flexipipe::plan::{Planner, Workload};
//! use flexipipe::quant::QuantMode;
//! use flexipipe::sim::{Simulate, Simulator};
//!
//! let workload = Workload::new(QuantMode::W8A8)
//!     .tenant(zoo::tinycnn())
//!     .tenant(zoo::lenet());
//! let set = Planner::on(zedboard()).steps(8).plan(&workload).unwrap();
//! let plan = &set.plans[set.best];
//! let report = Simulator::default().simulate(plan).unwrap();
//! assert!(report.tenants.iter().all(|r| r.fps > 0.0));
//! ```

use crate::alloc::flex::FlexAllocator;
use crate::alloc::{Allocation, Allocator};
use crate::board::Board;
use crate::engine::EngineConfig;
use crate::model::{config, Network};
use crate::quant::QuantMode;
use crate::shard::{
    self, ReconfigModel, Regime, ScheduleMode, ShardPlan, Sharder, SliceSpec, TemporalInfo, Tenant,
};
use crate::util::json::{self, num, obj, Value};
use std::path::Path;

/// The deployment-plan format version this build reads and writes.
/// [`DeploymentPlan::from_json`] rejects values outside
/// [`PLAN_VERSION_MIN`]`..=`[`PLAN_VERSION`], so a plan file can never be
/// silently misinterpreted across format changes.
pub const PLAN_VERSION: usize = 1;

/// Oldest deployment-plan format version this build still reads. Rejection
/// errors report the version found, this supported range, and (through
/// [`DeploymentPlan::load`]) the plan path — the groundwork for a
/// version-2 migration story.
pub const PLAN_VERSION_MIN: usize = 1;

// ---------------------------------------------------------------------------
// Workload
// ---------------------------------------------------------------------------

/// One typed requirement a plan must satisfy for a tenant. Constraints are
/// admission filters: every regime's planner drops plans violating any of
/// a tenant's constraints before the frontier reduction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Constraint {
    /// Latency ceiling in seconds: the tenant's worst-case frame sojourn
    /// (arrival → completion) must not exceed this (the CLI's
    /// `--slo model=33ms`). Several `Slo` constraints combine to the
    /// tightest.
    Slo(f64),
    /// Throughput floor in frames/second: the tenant's effective rate
    /// must be at least this (the CLI's `--min-fps model=25`), so
    /// meeting one tenant's SLO can never starve a throughput tenant.
    /// Several `MinFps` constraints combine to the highest.
    MinFps(f64),
}

/// One tenant of a [`Workload`]: a model, its weight in the weighted-fps
/// objective, and its [`Constraint`]s.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// The model this tenant serves.
    pub net: Network,
    /// Relative importance in the weighted-fps objective (default 1.0).
    pub weight: f64,
    /// Admission constraints (SLO ceilings, fps floors).
    pub constraints: Vec<Constraint>,
}

impl TenantSpec {
    /// Tenant with unit weight and no constraints.
    pub fn new(net: Network) -> TenantSpec {
        TenantSpec {
            net,
            weight: 1.0,
            constraints: Vec::new(),
        }
    }

    /// Set the tenant's weighted-fps weight.
    pub fn weight(mut self, weight: f64) -> TenantSpec {
        self.weight = weight;
        self
    }

    /// Add a worst-case frame-sojourn ceiling ([`Constraint::Slo`], seconds).
    pub fn slo(mut self, seconds: f64) -> TenantSpec {
        self.constraints.push(Constraint::Slo(seconds));
        self
    }

    /// Add an effective-fps floor ([`Constraint::MinFps`]).
    pub fn min_fps(mut self, fps: f64) -> TenantSpec {
        self.constraints.push(Constraint::MinFps(fps));
        self
    }
}

/// Which scalar pick [`Planner::plan`] labels `best`. The full Pareto
/// frontier over per-tenant (fps ↑, worst-case latency ↓) vectors is
/// always returned alongside; the objective only selects one plan from it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Maximize `min_i fps_i` — the egalitarian pick (the default).
    MaxMinFps,
    /// Maximize `Σ_i weight_i · fps_i` — the SLA-weighted pick.
    MaxWeightedFps,
}

impl Objective {
    /// CLI/report label (`"min_fps"` / `"weighted_fps"`).
    pub fn label(&self) -> &'static str {
        match self {
            Objective::MaxMinFps => "min_fps",
            Objective::MaxWeightedFps => "weighted_fps",
        }
    }

    /// Parse a CLI label (`min-fps` or `weighted`, with `_` accepted
    /// for `-`).
    pub fn parse(s: &str) -> crate::Result<Objective> {
        match s {
            "min-fps" | "min_fps" | "min" => Ok(Objective::MaxMinFps),
            "weighted" | "weighted-fps" | "weighted_fps" => Ok(Objective::MaxWeightedFps),
            other => anyhow::bail!("unknown objective '{other}' (min-fps | weighted)"),
        }
    }
}

/// What must be served: tenants (with weights and constraints), the
/// quantization width they run at, and the scalar [`Objective`].
#[derive(Debug, Clone)]
pub struct Workload {
    /// Co-resident tenants, in plan order.
    pub tenants: Vec<TenantSpec>,
    /// Quantization mode every tenant runs at.
    pub mode: QuantMode,
    /// Which feasible plan [`Planner::plan`] labels `best`.
    pub objective: Objective,
}

impl Workload {
    /// Empty workload at the given precision (egalitarian objective).
    pub fn new(mode: QuantMode) -> Workload {
        Workload {
            tenants: Vec::new(),
            mode,
            objective: Objective::MaxMinFps,
        }
    }

    /// Add an unconstrained unit-weight tenant.
    pub fn tenant(mut self, net: Network) -> Workload {
        self.tenants.push(TenantSpec::new(net));
        self
    }

    /// Add a fully-specified tenant.
    pub fn tenant_spec(mut self, spec: TenantSpec) -> Workload {
        self.tenants.push(spec);
        self
    }

    /// Set the scalar objective.
    pub fn objective(mut self, objective: Objective) -> Workload {
        self.objective = objective;
        self
    }

    /// Apply a constraint to every tenant of the named model (the CLI's
    /// `--slo` / `--min-fps` lists resolve through here); errors when the
    /// name matches no tenant — a misspelled model is a bug, not a no-op.
    pub fn constrain(&mut self, model: &str, constraint: Constraint) -> crate::Result<()> {
        let mut hit = false;
        for t in self.tenants.iter_mut().filter(|t| t.net.name == model) {
            t.constraints.push(constraint);
            hit = true;
        }
        anyhow::ensure!(hit, "constraint names unknown tenant model '{model}'");
        Ok(())
    }

    /// Reject empty or malformed workloads with the real cause.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.tenants.is_empty(), "workload has no tenants");
        for t in &self.tenants {
            t.net.validate()?;
            anyhow::ensure!(
                t.weight > 0.0 && t.weight.is_finite(),
                "tenant '{}': weight must be positive and finite",
                t.net.name
            );
            for c in &t.constraints {
                let v = match c {
                    Constraint::Slo(s) => *s,
                    Constraint::MinFps(f) => *f,
                };
                anyhow::ensure!(
                    v > 0.0 && v.is_finite(),
                    "tenant '{}': constraint bounds must be positive and finite",
                    t.net.name
                );
            }
        }
        Ok(())
    }

    /// Lower to the sharder's tenant form: multiple `Slo` constraints
    /// combine to the tightest ceiling, multiple `MinFps` to the highest
    /// floor.
    pub(crate) fn to_tenants(&self) -> Vec<Tenant> {
        self.tenants
            .iter()
            .map(|s| {
                let mut t = Tenant::new(s.net.clone(), self.mode);
                t.weight = s.weight;
                for c in &s.constraints {
                    match *c {
                        Constraint::Slo(v) => {
                            t.slo_s = Some(t.slo_s.map_or(v, |cur| cur.min(v)));
                        }
                        Constraint::MinFps(v) => {
                            t.min_fps = Some(t.min_fps.map_or(v, |cur| cur.max(v)));
                        }
                    }
                }
                t
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Planner
// ---------------------------------------------------------------------------

/// The one planning facade: routes a [`Workload`] to solo allocation
/// (one tenant), spatial / temporal / overlay sharding, or a multi-board
/// sweep, and returns every feasible [`DeploymentPlan`] reduced to a
/// Pareto frontier plus the objective picks. Field defaults match
/// [`Sharder::new`]; the chainable setters cover the common knobs and the
/// fields stay public for struct-update syntax.
#[derive(Debug, Clone)]
pub struct Planner {
    /// Candidate boards. One board plans directly; several enumerate each
    /// board's plan space and merge the results into one frontier (the
    /// board axis of a design-space sweep).
    pub boards: Vec<Board>,
    /// Split granularity: θ/α (and temporal time) move in `1/steps`
    /// quanta. Default 16.
    pub steps: usize,
    /// Which sharing regimes to enumerate. Default
    /// [`ScheduleMode::Spatial`].
    pub schedule: ScheduleMode,
    /// Temporal-schedule period bound in seconds. Default 0.5.
    pub max_period_s: f64,
    /// Largest per-tenant interleave factor (sub-slices per period).
    /// Default 1.
    pub max_interleave: usize,
    /// Partial-reconfiguration cost model (and the overlay synthesis
    /// overhead factor) temporal plans are scored under.
    pub reconfig: ReconfigModel,
    /// Solo DES frames calibrating each tenant's temporal admission.
    /// Default 6.
    pub calib_frames: usize,
    /// Admission ceiling on frames per slice. Default 4096.
    pub max_slice_frames: usize,
    /// Frames for the DES validation of frontier plans (0 = closed-form
    /// only). Validated plans record their simulated fps in the plan
    /// artifact ([`TenantRecord::sim_fps`]).
    pub sim_frames: usize,
    /// Branch-and-bound pruning inside each board's [`Sharder`] search
    /// ([`Sharder::prune`]): frontier and objective-pick plan contents are
    /// identical to the exhaustive search, but the exhaustive `plans`
    /// listing may shrink. Default `false`.
    pub prune: bool,
}

impl Planner {
    /// Plan onto one board.
    pub fn on(board: Board) -> Planner {
        Planner::across(vec![board])
    }

    /// Plan across several candidate boards (their plan spaces merge into
    /// one frontier).
    pub fn across(boards: Vec<Board>) -> Planner {
        Planner {
            boards,
            steps: 16,
            schedule: ScheduleMode::Spatial,
            max_period_s: 0.5,
            max_interleave: 1,
            reconfig: ReconfigModel::default(),
            calib_frames: 6,
            max_slice_frames: 4096,
            sim_frames: 0,
            prune: false,
        }
    }

    /// Set the split granularity.
    pub fn steps(mut self, steps: usize) -> Planner {
        self.steps = steps;
        self
    }

    /// Set the sharing regime(s) to enumerate.
    pub fn schedule(mut self, mode: ScheduleMode) -> Planner {
        self.schedule = mode;
        self
    }

    /// Set the temporal period bound (seconds).
    pub fn max_period(mut self, seconds: f64) -> Planner {
        self.max_period_s = seconds;
        self
    }

    /// Set the largest per-tenant interleave factor.
    pub fn interleave(mut self, k: usize) -> Planner {
        self.max_interleave = k;
        self
    }

    /// Set the reconfiguration cost model.
    pub fn reconfig(mut self, model: ReconfigModel) -> Planner {
        self.reconfig = model;
        self
    }

    /// Enable the DES validation pass on frontier plans (`frames` per
    /// tenant for resident plans; temporal plans execute one full period).
    pub fn validate(mut self, frames: usize) -> Planner {
        self.sim_frames = frames;
        self
    }

    /// Enable branch-and-bound pruning in each board's search (the CLI's
    /// `--prune`).
    pub fn prune(mut self, on: bool) -> Planner {
        self.prune = on;
        self
    }

    /// Enumerate the workload's plan space on every board, keep the
    /// feasible (constraint-satisfying) plans, and reduce them to the
    /// merged Pareto frontier over per-tenant (fps ↑, worst-case
    /// latency ↓) vectors. On a single board the plan order, frontier,
    /// and objective picks are exactly [`Sharder::search`]'s (the facade
    /// adds no search logic of its own); across boards, per-board plan
    /// sets concatenate in board order and the frontier is recomputed
    /// over the union. A board where the workload is infeasible is
    /// skipped when other boards remain; planning fails only when *no*
    /// board admits a plan (with every board's reason listed).
    pub fn plan(&self, workload: &Workload) -> crate::Result<PlanSet> {
        workload.validate()?;
        anyhow::ensure!(!self.boards.is_empty(), "planner has no boards");
        let mut plans: Vec<DeploymentPlan> = Vec::new();
        let mut errors: Vec<String> = Vec::new();
        for board in &self.boards {
            let sharder = Sharder {
                steps: self.steps,
                sim_frames: self.sim_frames,
                schedule: self.schedule,
                reconfig: self.reconfig.clone(),
                max_interleave: self.max_interleave,
                max_period_s: self.max_period_s,
                calib_frames: self.calib_frames,
                max_slice_frames: self.max_slice_frames,
                prune: self.prune,
                ..Sharder::new(board.clone(), workload.to_tenants())
            };
            match sharder.search() {
                Ok(result) => {
                    for p in &result.plans {
                        plans.push(DeploymentPlan::from_shard(
                            board,
                            workload.mode,
                            self.steps,
                            &self.reconfig,
                            &workload.tenants,
                            p,
                        )?);
                    }
                }
                Err(e) if self.boards.len() > 1 => errors.push(format!("{}: {e}", board.name)),
                Err(e) => return Err(e),
            }
        }
        anyhow::ensure!(
            !plans.is_empty(),
            "plan: the workload is infeasible on every candidate board:\n{}",
            errors.join("\n")
        );

        let objectives: Vec<(Vec<f64>, Vec<f64>)> = plans
            .iter()
            .map(|p| {
                (
                    p.fps_vec().expect("planner-produced plans carry records"),
                    p.latency_vec().expect("planner-produced plans carry records"),
                )
            })
            .collect();
        // Same reduction as [`crate::shard::frontier`]: strict dominance
        // plus exact-tie dedup (first representative wins) — crate-shared
        // predicates keep the two in lockstep on a single board.
        let frontier: Vec<usize> = (0..plans.len())
            .filter(|&i| {
                !(0..plans.len()).any(|j| {
                    j != i
                        && shard::vec_dominates(
                            &objectives[j].0,
                            &objectives[j].1,
                            &objectives[i].0,
                            &objectives[i].1,
                        )
                }) && !(0..i).any(|j| objectives[j] == objectives[i])
            })
            .collect();
        let argmax = |key: &dyn Fn(&DeploymentPlan) -> f64| -> usize {
            let mut best = 0;
            for i in 1..plans.len() {
                if key(&plans[i]) > key(&plans[best]) {
                    best = i;
                }
            }
            best
        };
        let best_min = argmax(&|p| p.min_fps().unwrap_or(f64::NEG_INFINITY));
        let best_weighted = argmax(&|p| p.weighted_fps().unwrap_or(f64::NEG_INFINITY));
        let best = match workload.objective {
            Objective::MaxMinFps => best_min,
            Objective::MaxWeightedFps => best_weighted,
        };
        Ok(PlanSet {
            plans,
            frontier,
            best_min,
            best_weighted,
            best,
            objective: workload.objective,
        })
    }
}

/// [`Planner::plan`]'s output: every feasible plan plus the interesting
/// subsets.
#[derive(Debug, Clone)]
pub struct PlanSet {
    /// All feasible plans, boards concatenated in planner order, each
    /// board's plans in its deterministic enumeration order.
    pub plans: Vec<DeploymentPlan>,
    /// Indices of the non-dominated plans under the merged per-tenant
    /// (fps ↑, worst-case latency ↓) objective.
    pub frontier: Vec<usize>,
    /// Index of the plan maximizing min-fps (first wins ties).
    pub best_min: usize,
    /// Index of the plan maximizing weighted fps (first wins ties).
    pub best_weighted: usize,
    /// Index of the workload-objective pick (`best_min` or
    /// `best_weighted`).
    pub best: usize,
    /// The objective that selected `best`.
    pub objective: Objective,
}

impl PlanSet {
    /// JSON document for `flexipipe plan --json`: the frontier plans, the
    /// objective pick inline under `best` (what [`DeploymentPlan::load`]
    /// reads, so one file feeds `flexipipe simulate --plan` and
    /// `flexipipe serve --plan`), and the scalar picks as *indices into
    /// the `frontier` array* (`null` in the rare case a tie-broken pick
    /// is not itself on the frontier) — plans embed whole networks, so
    /// the picks are referenced rather than copied.
    pub fn to_json(&self) -> Value {
        let in_frontier = |i: usize| -> Value {
            match self.frontier.iter().position(|&f| f == i) {
                Some(pos) => num(pos),
                None => Value::Null,
            }
        };
        obj(vec![
            ("version", num(PLAN_VERSION)),
            ("objective", Value::Str(self.objective.label().to_string())),
            ("feasible_plans", num(self.plans.len())),
            (
                "frontier",
                Value::Arr(self.frontier.iter().map(|&i| self.plans[i].to_json()).collect()),
            ),
            ("best_min_fps_frontier_index", in_frontier(self.best_min)),
            ("best_weighted_fps_frontier_index", in_frontier(self.best_weighted)),
            ("best_frontier_index", in_frontier(self.best)),
            ("best", self.plans[self.best].to_json()),
        ])
    }
}

// ---------------------------------------------------------------------------
// Failover re-planning
// ---------------------------------------------------------------------------

/// One tenant dropped by failover re-planning, with the planner's reason.
/// Shedding is always explicit: a tenant either appears in the replanned
/// deployment or in this report — never silently vanishes.
#[derive(Debug, Clone)]
pub struct ShedEntry {
    /// The dropped tenant's model name.
    pub net: String,
    /// Why it was dropped (the planner's infeasibility cause).
    pub reason: String,
}

/// Which phase of [`Planner::replan`] decided the outcome. Phase 1b
/// (delta admission) only applies to spatial incumbents — temporal and
/// overlay schedules re-derive admission from scratch, so a failed warm
/// start sends them straight to the full search. Recording the phase
/// makes that fallback explicit: a consumer can always tell whether the
/// delta probe ran, was skipped by regime, or was exhausted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanPhase {
    /// Phase 1: the incumbent's θ/α vectors and schedule survived on the
    /// degraded board unchanged.
    WarmStart,
    /// Phase 1b: a ±1-quantum θ/α neighbor of the spatial incumbent was
    /// admitted (never reported for temporal/overlay incumbents, whose
    /// regime skips the probe by design).
    DeltaAdmission,
    /// Phase 2: the full search ran on the surviving board — the warm
    /// region was infeasible, or the incumbent's regime skips delta
    /// admission. Also reported when every tenant was shed (the search
    /// ran and found nothing).
    FullSearch,
}

impl ReplanPhase {
    /// Stable label used in the `replan` JSON document.
    pub fn label(&self) -> &'static str {
        match self {
            ReplanPhase::WarmStart => "warm-start",
            ReplanPhase::DeltaAdmission => "delta-admission",
            ReplanPhase::FullSearch => "full-search",
        }
    }
}

/// Outcome of [`Planner::replan`]: the failover deployment (if any
/// tenant set was admissible on the surviving capacity), the explicit
/// shed report, the surviving board the decision was made against, and
/// the reconfiguration delta from the incumbent.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// The replanned deployment; `None` when no tenant subset was
    /// feasible on the surviving capacity (every tenant is then in
    /// `shed`).
    pub plan: Option<DeploymentPlan>,
    /// Tenants dropped to make the rest fit, in shedding order.
    pub shed: Vec<ShedEntry>,
    /// The surviving board capacity the re-plan was computed against.
    pub board: Board,
    /// Delta from the incumbent to the replanned deployment (the
    /// drain-overlapped reconfiguration sequence a live service executes
    /// via [`crate::coordinator::PlannedService::apply`]); `None` when
    /// `plan` is `None`.
    pub diff: Option<crate::fault::PlanDiff>,
    /// Which phase produced this outcome (warm start, delta admission,
    /// or the full search) — the regime-dependent fallback made
    /// explicit.
    pub phase: ReplanPhase,
}

impl ReplanOutcome {
    /// JSON document for `flexipipe replan` (deterministic field order).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("replanned", Value::Bool(self.plan.is_some())),
            ("phase", Value::Str(self.phase.label().to_string())),
            ("board", board_to_json(&self.board)),
            (
                "shed",
                Value::Arr(
                    self.shed
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("net", Value::Str(s.net.clone())),
                                ("reason", Value::Str(s.reason.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diff",
                self.diff.as_ref().map_or(Value::Null, |d| d.to_json()),
            ),
            (
                "plan",
                self.plan.as_ref().map_or(Value::Null, |p| p.to_json()),
            ),
        ])
    }
}

/// Instantiate one warm re-plan candidate and DES-check it against every
/// tenant's fps floors and latency SLOs. On success the candidate's stage
/// configs and planning records are filled in and `true` is returned;
/// any failure (the pipeline no longer fits, or a bound is missed) leaves
/// the candidate unusable and returns `false`. Shared by
/// [`Planner::replan`]'s warm-start and delta-admission phases.
fn warm_candidate_meets(cand: &mut DeploymentPlan, frames: usize) -> bool {
    let Ok(allocs) = cand.instantiate() else {
        return false;
    };
    let refs: Vec<&Allocation> = allocs.iter().collect();
    let freq = cand.board.freq_hz;
    let (fps, sojourn_s): (Vec<f64>, Vec<f64>) = match &cand.regime {
        Regime::Temporal(info) if info.period_cycles > 0 => {
            let ts = crate::sim::simulate_schedule(&refs, &info.schedule_slices(), true);
            let soj = ts.worst_sojourn.iter().map(|&c| c as f64 / freq).collect();
            (ts.tenant_fps, soj)
        }
        regime => {
            let shares: Vec<f64> = match regime {
                Regime::Spatial => cand.tenants.iter().map(|t| t.ddr_share).collect(),
                Regime::Temporal(_) => vec![1.0],
            };
            let reports =
                crate::sim::simulate_multi_provisioned(&refs, &shares, &cand.board, frames);
            let fps = reports.iter().map(|r| r.fps).collect();
            let soj = reports
                .iter()
                .map(|r| r.frame_done.first().copied().unwrap_or(r.makespan) as f64 / freq)
                .collect();
            (fps, soj)
        }
    };
    let meets = cand.tenants.iter().enumerate().all(|(i, t)| {
        fps_floor(&t.constraints).map_or(true, |floor| fps[i] >= floor)
            && slo_ceiling(&t.constraints).map_or(true, |slo| sojourn_s[i] <= slo)
    });
    if !meets {
        return false;
    }
    for (i, t) in cand.tenants.iter_mut().enumerate() {
        let report = allocs[i].evaluate();
        t.stages = allocs[i].stages.iter().map(|s| s.cfg).collect();
        t.record = Some(TenantRecord {
            fps: fps[i],
            latency_s: sojourn_s[i],
            dsps: report.dsps,
            bram18: report.bram18,
            sim_fps: None,
        });
    }
    true
}

/// The incumbent's θ/α neighborhood for delta admission: every per-tenant
/// `(dsp_parts, bram_parts)` assignment within ±1 quantum of the
/// incumbent's on each coordinate, keeping every slice non-empty and each
/// axis within the plan's `steps`. Ordered smallest total perturbation
/// first (ties in generation order), with the unperturbed incumbent
/// excluded — Phase 1 already checked it. Empty for many-tenant plans
/// whose 9ⁿ combination space stops being a "neighborhood".
fn quanta_neighborhood(plan: &DeploymentPlan) -> Vec<(Vec<usize>, Vec<usize>)> {
    let n = plan.tenants.len();
    match 9usize.checked_pow(n as u32) {
        Some(space) if space <= 1_000 => {}
        _ => return Vec::new(),
    }
    let deltas = [0isize, -1, 1];
    let mut out: Vec<(usize, (Vec<usize>, Vec<usize>))> = Vec::new();
    // Base-3 counter over 2n digits: digit i perturbs tenant i's DSP
    // quanta, digit n+i its BRAM quanta.
    let mut digits = vec![0usize; 2 * n];
    loop {
        let mut dsp = Vec::with_capacity(n);
        let mut bram = Vec::with_capacity(n);
        let mut dist = 0usize;
        let mut valid = true;
        for (i, t) in plan.tenants.iter().enumerate() {
            let dd = deltas[digits[i]];
            let bd = deltas[digits[n + i]];
            dist += dd.unsigned_abs() + bd.unsigned_abs();
            let d = t.dsp_parts as isize + dd;
            let b = t.bram_parts as isize + bd;
            if d < 1 || b < 1 {
                valid = false;
                break;
            }
            dsp.push(d as usize);
            bram.push(b as usize);
        }
        if valid
            && dist > 0
            && dsp.iter().sum::<usize>() <= plan.steps
            && bram.iter().sum::<usize>() <= plan.steps
        {
            out.push((dist, (dsp, bram)));
        }
        // Increment the counter; done once it wraps.
        let mut pos = 0;
        loop {
            if pos == 2 * n {
                out.sort_by_key(|&(dist, _)| dist);
                return out.into_iter().map(|(_, v)| v).collect();
            }
            digits[pos] += 1;
            if digits[pos] < 3 {
                break;
            }
            digits[pos] = 0;
            pos += 1;
        }
    }
}

/// Tightest fps floor among a tenant's constraints.
pub(crate) fn fps_floor(cs: &[Constraint]) -> Option<f64> {
    cs.iter()
        .filter_map(|c| match c {
            Constraint::MinFps(f) => Some(*f),
            Constraint::Slo(_) => None,
        })
        .fold(None, |acc, f| Some(acc.map_or(f, |a: f64| a.max(f))))
}

/// Tightest latency ceiling among a tenant's constraints.
fn slo_ceiling(cs: &[Constraint]) -> Option<f64> {
    cs.iter()
        .filter_map(|c| match c {
            Constraint::Slo(s) => Some(*s),
            Constraint::MinFps(_) => None,
        })
        .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.min(s))))
}

impl Planner {
    /// Failover re-planning: given the incumbent deployment and a fault
    /// event, produce a plan for the **surviving** capacity
    /// ([`crate::fault::FaultPlan::surviving_board`]) that honors every
    /// tenant's `min_fps` floors and SLOs — or an explicit shed report
    /// for the tenants that had to be dropped (no silent drops, ever).
    ///
    /// Three phases:
    ///
    /// 1. **Warm start.** The incumbent's θ/α vectors and schedule are
    ///    kept; only the board is swapped for the surviving one (recorded
    ///    stage configs are cleared so the allocator re-derives each
    ///    pipeline on the degraded fabric). If the warm-started plan still
    ///    instantiates and a DES run meets every floor and SLO, it is the
    ///    answer — no search, minimal disruption.
    /// 1b. **Delta admission.** For spatial incumbents whose warm start
    ///    missed a bound, the θ/α *neighborhood* is probed next: every
    ///    per-tenant quanta assignment within ±1 of the incumbent's,
    ///    smallest total perturbation first, each checked exactly like the
    ///    warm start. Workload drift or a modest capacity loss is usually
    ///    absorbed by shifting one quantum between tenants — the full
    ///    search below only runs when the whole warm region is infeasible.
    ///    (Temporal schedules re-derive admission from scratch anyway, so
    ///    they go straight to the search.)
    /// 2. **Full re-plan with graceful degradation.** Otherwise the
    ///    planner searches the surviving board for the whole tenant set;
    ///    while the workload is infeasible, the lowest-weight tenant
    ///    (ties: latest in plan order) is shed with the planner's reason,
    ///    and the search repeats on the remainder. A successful search
    ///    meets every admitted tenant's floors by construction
    ///    ([`Planner::plan`] enforces constraints as admission filters).
    ///
    /// The outcome carries the reconfiguration delta from the incumbent
    /// ([`crate::fault::PlanDiff`]) so a live service can execute the
    /// failover with drain-overlapped swaps, and records which phase
    /// decided it ([`ReplanOutcome::phase`]) — so the regime-dependent
    /// skip of Phase 1b is explicit, never silent.
    pub fn replan(
        &self,
        incumbent: &DeploymentPlan,
        faults: &crate::fault::FaultPlan,
    ) -> crate::Result<ReplanOutcome> {
        faults.validate()?;
        let board = faults.surviving_board(&incumbent.board);
        let frames = self.sim_frames.max(2);

        // Phase 1: warm start from the incumbent's θ vectors.
        let mut cand = incumbent.clone();
        cand.board = board.clone();
        for t in &mut cand.tenants {
            // The allocator re-derives stage configs on the degraded
            // fabric; stale records would trip the drift check.
            t.stages.clear();
            t.record = None;
        }
        if warm_candidate_meets(&mut cand, frames) {
            let diff = incumbent.diff(&cand)?;
            return Ok(ReplanOutcome {
                plan: Some(cand),
                shed: Vec::new(),
                board,
                diff: Some(diff),
                phase: ReplanPhase::WarmStart,
            });
        }

        // Phase 1b: delta admission — probe the incumbent's θ/α
        // neighborhood (±1 quantum per tenant, smallest perturbation
        // first) with the same instantiate-and-DES check before paying
        // for the full search.
        if matches!(incumbent.regime, Regime::Spatial) {
            for (dsp, bram) in quanta_neighborhood(incumbent) {
                let mut cand = incumbent.clone();
                cand.board = board.clone();
                for (i, t) in cand.tenants.iter_mut().enumerate() {
                    t.stages.clear();
                    t.record = None;
                    t.dsp_parts = dsp[i];
                    t.bram_parts = bram[i];
                    // β follows Θ, exactly as the spatial search
                    // provisions it.
                    t.ddr_share = dsp[i] as f64 / cand.steps as f64;
                }
                if warm_candidate_meets(&mut cand, frames) {
                    let diff = incumbent.diff(&cand)?;
                    return Ok(ReplanOutcome {
                        plan: Some(cand),
                        shed: Vec::new(),
                        board,
                        diff: Some(diff),
                        phase: ReplanPhase::DeltaAdmission,
                    });
                }
            }
        }

        // Phase 2: full re-plan on the surviving board, shedding the
        // lowest-weight tenant each time the remainder is infeasible.
        let planner = Planner {
            boards: vec![board.clone()],
            ..self.clone()
        };
        let mut active: Vec<TenantSpec> = incumbent
            .tenants
            .iter()
            .map(|t| TenantSpec {
                net: t.net.clone(),
                weight: t.weight,
                constraints: t.constraints.clone(),
            })
            .collect();
        let mut shed = Vec::new();
        while !active.is_empty() {
            let workload = Workload {
                tenants: active.clone(),
                mode: incumbent.mode,
                objective: Objective::MaxMinFps,
            };
            match planner.plan(&workload) {
                Ok(set) => {
                    let new_plan = set.plans[set.best].clone();
                    let diff = incumbent.diff(&new_plan)?;
                    return Ok(ReplanOutcome {
                        plan: Some(new_plan),
                        shed,
                        board,
                        diff: Some(diff),
                        phase: ReplanPhase::FullSearch,
                    });
                }
                Err(e) => {
                    // Shed the lowest-weight tenant; `<=` picks the last
                    // of equal weights, so earlier (higher-priority by
                    // plan order) tenants survive ties.
                    let mut victim = 0;
                    for i in 1..active.len() {
                        if active[i].weight <= active[victim].weight {
                            victim = i;
                        }
                    }
                    let t = active.remove(victim);
                    shed.push(ShedEntry {
                        net: t.net.name.clone(),
                        reason: format!("infeasible on surviving capacity: {e}"),
                    });
                }
            }
        }
        Ok(ReplanOutcome {
            plan: None,
            shed,
            board,
            diff: None,
            phase: ReplanPhase::FullSearch,
        })
    }
}

// ---------------------------------------------------------------------------
// DeploymentPlan
// ---------------------------------------------------------------------------

/// Planning-time figures recorded for one tenant. Informational: the plan
/// re-derives ground truth by re-running the (deterministic) allocator and
/// DES on load, so hand-authored plans may omit the record entirely — but
/// planner-produced records let a consumer diff a plan's promises against
/// a later re-simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantRecord {
    /// Analytic effective fps the planner scored this tenant at.
    pub fps: f64,
    /// Analytic worst-case frame sojourn in seconds.
    pub latency_s: f64,
    /// DSP slices the tenant's pipeline uses.
    pub dsps: usize,
    /// BRAM18 blocks the tenant's pipeline uses.
    pub bram18: usize,
    /// DES-confirmed fps, when the planner ran its validation pass —
    /// what a later [`crate::sim::Simulate`] run reproduces
    /// bit-identically.
    pub sim_fps: Option<f64>,
}

/// One tenant's slice of a [`DeploymentPlan`].
#[derive(Debug, Clone)]
pub struct PlanTenant {
    /// The model, embedded in full (a plan file is self-contained — no
    /// zoo or path lookups on load).
    pub net: Network,
    /// Weighted-fps weight.
    pub weight: f64,
    /// The constraints this tenant was admitted under.
    pub constraints: Vec<Constraint>,
    /// DSP-side quanta (`dsp_parts/steps` of Θ, LUT/FF, and β). Temporal
    /// tenants hold the whole board (`dsp_parts == steps`) during their
    /// slices.
    pub dsp_parts: usize,
    /// BRAM quanta (`bram_parts/steps` of α).
    pub bram_parts: usize,
    /// Provisioned share of the physical DDR port this tenant's streams
    /// receive (spatial: `dsp_parts/steps`, the split Algorithm 2
    /// budgeted; temporal: 1.0 — the full port during its slice).
    pub ddr_share: f64,
    /// Per-stage engine configs `(C', M', K)` recorded for drift
    /// detection: [`DeploymentPlan::instantiate`] re-runs the allocator
    /// and errors if its output diverges from the record (empty = skip
    /// the check, for hand-authored plans).
    pub stages: Vec<EngineConfig>,
    /// Planning-time figures (`None` for hand-authored plans).
    pub record: Option<TenantRecord>,
}

/// A versioned, serializable deployment: the single artifact passed
/// between planning ([`Planner`]), simulation ([`crate::sim::Simulate`]),
/// and serving ([`crate::coordinator::Coordinator::start_planned`]).
///
/// A plan is **self-contained** (board resource model and tenant networks
/// embedded) and **reconstructible**: it stores the θ/α quanta and the
/// schedule layout, and [`DeploymentPlan::instantiate`] re-derives each
/// tenant's exact [`Allocation`] with the deterministic Sec. 4 allocator,
/// cross-checking the recorded stage configs. JSON round-trips preserve
/// every `f64` bit (shortest-round-trip float formatting), so a plan
/// written to disk re-simulates bit-identically to the in-process search.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    /// Format version ([`PLAN_VERSION`] when produced by this build).
    pub version: usize,
    /// The physical board, resource model embedded.
    pub board: Board,
    /// Quantization mode every tenant runs at.
    pub mode: QuantMode,
    /// Split granularity the θ/α (and time) quanta are expressed in.
    pub steps: usize,
    /// Per-tenant slices, in plan order.
    pub tenants: Vec<PlanTenant>,
    /// The sharing regime, including the full temporal schedule layout
    /// for time-multiplexed and overlay plans.
    pub regime: Regime,
    /// Reconfiguration cost model the schedule was planned under
    /// (including the overlay synthesis overhead factor).
    pub reconfig: ReconfigModel,
}

impl DeploymentPlan {
    /// Build a plan from one [`Sharder`] result plan (what [`Planner`]
    /// emits; public so custom `Sharder` drivers can produce the same
    /// artifact). `specs` supplies the workload-level weight/constraint
    /// data the `ShardPlan` does not carry, in the same tenant order —
    /// a length mismatch is an error, not a panic.
    pub fn from_shard(
        board: &Board,
        mode: QuantMode,
        steps: usize,
        reconfig: &ReconfigModel,
        specs: &[TenantSpec],
        plan: &ShardPlan,
    ) -> crate::Result<DeploymentPlan> {
        anyhow::ensure!(
            specs.len() == plan.tenants.len(),
            "one TenantSpec per ShardPlan tenant ({} specs vs {} tenants)",
            specs.len(),
            plan.tenants.len()
        );
        let tenants = plan
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| PlanTenant {
                net: specs[i].net.clone(),
                weight: specs[i].weight,
                constraints: specs[i].constraints.clone(),
                dsp_parts: t.dsp_parts,
                bram_parts: t.bram_parts,
                ddr_share: match &plan.regime {
                    Regime::Spatial => t.dsp_parts as f64 / steps as f64,
                    Regime::Temporal(_) => 1.0,
                },
                stages: t.alloc.stages.iter().map(|s| s.cfg).collect(),
                record: Some(TenantRecord {
                    fps: plan.fps[i],
                    latency_s: plan.latency_s[i],
                    dsps: t.report.dsps,
                    bram18: t.report.bram18,
                    sim_fps: plan.sim.as_ref().map(|s| s[i].fps),
                }),
            })
            .collect();
        Ok(DeploymentPlan {
            version: PLAN_VERSION,
            board: board.clone(),
            mode,
            steps,
            tenants,
            regime: plan.regime.clone(),
            reconfig: reconfig.clone(),
        })
    }

    /// Recorded per-tenant fps vector (`None` when any tenant lacks a
    /// record).
    pub fn fps_vec(&self) -> Option<Vec<f64>> {
        self.tenants.iter().map(|t| t.record.as_ref().map(|r| r.fps)).collect()
    }

    /// Recorded per-tenant worst-case latency vector (seconds).
    pub fn latency_vec(&self) -> Option<Vec<f64>> {
        self.tenants
            .iter()
            .map(|t| t.record.as_ref().map(|r| r.latency_s))
            .collect()
    }

    /// Analytic worst-case frame sojourn per tenant in **cycles** — the
    /// bound measured serving tails ([`crate::ingest::serve_trace`]) are
    /// validated against. Temporal and overlay plans carry it in the
    /// schedule itself
    /// ([`crate::shard::TemporalInfo::latency_cycles`] — present even in
    /// hand-authored plans); spatial plans fall back to the
    /// planning-time record (`latency_s` at the board clock), so the
    /// result is `None` for a hand-authored spatial plan without
    /// records.
    pub fn worst_sojourn_cycles(&self) -> Option<Vec<u64>> {
        match &self.regime {
            Regime::Temporal(info) => Some(info.latency_cycles.clone()),
            Regime::Spatial => self.latency_vec().map(|v| {
                v.iter()
                    .map(|s| (s * self.board.freq_hz).ceil() as u64)
                    .collect()
            }),
        }
    }

    /// Recorded min-fps objective.
    pub fn min_fps(&self) -> Option<f64> {
        self.fps_vec()
            .map(|v| v.into_iter().fold(f64::INFINITY, f64::min))
    }

    /// Recorded weighted-fps objective.
    pub fn weighted_fps(&self) -> Option<f64> {
        self.fps_vec().map(|v| {
            v.iter()
                .zip(&self.tenants)
                .map(|(f, t)| f * t.weight)
                .sum()
        })
    }

    /// Rebuild every tenant's exact [`Allocation`] from the plan: cut the
    /// tenant's sub-board from the embedded board model, run the
    /// deterministic Sec. 4 allocator on it, check the result fits the
    /// slice, and cross-check the recorded stage configs (a mismatch
    /// means the plan was produced by a different allocator version —
    /// the error says to regenerate it). This is the single rehydration
    /// path under both [`crate::sim::Simulate`] and
    /// [`crate::coordinator::Coordinator::start_planned`].
    pub fn instantiate(&self) -> crate::Result<Vec<Allocation>> {
        anyhow::ensure!(
            (PLAN_VERSION_MIN..=PLAN_VERSION).contains(&self.version),
            "unsupported deployment-plan version {}: this build reads versions \
             {PLAN_VERSION_MIN}..={PLAN_VERSION}",
            self.version
        );
        anyhow::ensure!(!self.tenants.is_empty(), "deployment plan has no tenants");
        anyhow::ensure!(self.steps >= 1, "deployment plan has zero split steps");
        // Hand-authored files can carry nonphysical numbers; refuse them
        // here rather than let 0/0 and ∞ propagate into the DES figures.
        anyhow::ensure!(
            self.board.freq_hz > 0.0
                && self.board.freq_hz.is_finite()
                && self.board.ddr_bytes_per_sec > 0.0
                && self.board.ddr_bytes_per_sec.is_finite(),
            "plan board has nonphysical rates (freq_hz {}, ddr_bytes_per_sec {})",
            self.board.freq_hz,
            self.board.ddr_bytes_per_sec
        );
        anyhow::ensure!(
            self.reconfig.overlay_overhead >= 1.0,
            "plan reconfig model has overlay_overhead {} < 1.0 (the element-wise-max \
             footprint is already the optimistic bound — the planner rejects this too)",
            self.reconfig.overlay_overhead
        );
        // Regime-level schedule validation up front: hand-authored plans
        // are a supported input, so a malformed schedule must be refused
        // with the real cause here — never panic inside the DES engines.
        match &self.regime {
            Regime::Spatial => {
                // Aggregate feasibility: the slices must partition (not
                // oversubscribe) the physical board and the DDR port.
                let dsp: usize = self.tenants.iter().map(|t| t.dsp_parts).sum();
                let bram: usize = self.tenants.iter().map(|t| t.bram_parts).sum();
                anyhow::ensure!(
                    dsp <= self.steps && bram <= self.steps,
                    "spatial plan oversubscribes the board: Θ quanta sum to {dsp} and α \
                     quanta to {bram} of {} steps",
                    self.steps
                );
                let share: f64 = self.tenants.iter().map(|t| t.ddr_share).sum();
                anyhow::ensure!(
                    share <= 1.0 + 1e-9,
                    "spatial plan oversubscribes the DDR port: provisioned shares sum to \
                     {share:.6}"
                );
            }
            Regime::Temporal(info) if info.period_cycles == 0 => {
                // The degenerate schedule is continuous solo operation —
                // it only exists for a lone tenant.
                anyhow::ensure!(
                    self.tenants.len() == 1,
                    "temporal plan has period_cycles = 0 (continuous solo) but declares \
                     {} tenants",
                    self.tenants.len()
                );
            }
            Regime::Temporal(info) => {
                anyhow::ensure!(
                    info.slices.iter().all(|s| s.tenant < self.tenants.len()),
                    "schedule slice references a tenant the plan does not declare"
                );
                // Every tenant must actually be served: the schedule
                // executor requires ≥ 1 sub-slice with ≥ 1 admitted frame
                // per tenant (anything else is a plan that silently — or
                // loudly — drops a tenant).
                for t in 0..self.tenants.len() {
                    anyhow::ensure!(
                        info.slices.iter().any(|s| s.tenant == t && s.frames >= 1),
                        "temporal schedule admits no frames for tenant {t} ('{}')",
                        self.tenants[t].net.name
                    );
                }
                // Temporal tenants hold the whole board during their
                // slices (the field contract `dsp_parts == steps`).
                anyhow::ensure!(
                    self.tenants
                        .iter()
                        .all(|t| t.dsp_parts == self.steps && t.bram_parts == self.steps),
                    "temporal plan tenants must hold the whole board during their slices \
                     (θ/α quanta == steps)"
                );
            }
        }
        let mut out = Vec::with_capacity(self.tenants.len());
        for (i, t) in self.tenants.iter().enumerate() {
            t.net.validate()?;
            anyhow::ensure!(
                (1..=self.steps).contains(&t.dsp_parts)
                    && (1..=self.steps).contains(&t.bram_parts),
                "tenant {i} ('{}'): θ/α quanta out of range (1..={} of {} steps)",
                t.net.name,
                self.steps,
                self.steps
            );
            anyhow::ensure!(
                t.ddr_share > 0.0 && t.ddr_share <= 1.0,
                "tenant {i} ('{}'): DDR share {} outside (0, 1]",
                t.net.name,
                t.ddr_share
            );
            let sub = shard::sub_board(&self.board, t.dsp_parts, t.bram_parts, self.steps);
            let alloc = FlexAllocator::default().allocate(&t.net, &sub, self.mode)?;
            let report = alloc.evaluate();
            anyhow::ensure!(
                report.dsps <= sub.dsps && report.bram18 <= sub.bram18(),
                "tenant {i} ('{}') no longer fits its slice ({}/{} DSPs, {}/{} BRAM18) — \
                 the plan is infeasible on this board model",
                t.net.name,
                report.dsps,
                sub.dsps,
                report.bram18,
                sub.bram18()
            );
            if !t.stages.is_empty() {
                let got: Vec<EngineConfig> = alloc.stages.iter().map(|s| s.cfg).collect();
                anyhow::ensure!(
                    got == t.stages,
                    "tenant {i} ('{}'): this build's allocator produced different stage \
                     configs than the plan records — the plan was built by a different \
                     allocator version; regenerate it with `flexipipe plan`",
                    t.net.name
                );
            }
            out.push(alloc);
        }
        Ok(out)
    }

    /// Serialize to the versioned JSON plan format (deterministic field
    /// order; every `f64` round-trips bit-exactly).
    pub fn to_json(&self) -> Value {
        let tenants: Vec<Value> = self.tenants.iter().map(tenant_to_json).collect();
        let mut pairs = vec![
            ("version", num(self.version)),
            ("board", board_to_json(&self.board)),
            ("bits", num(self.mode.bits())),
            ("steps", num(self.steps)),
            ("regime", Value::Str(self.regime.label().to_string())),
            ("reconfig", reconfig_to_json(&self.reconfig)),
            ("tenants", Value::Arr(tenants)),
        ];
        if let Regime::Temporal(info) = &self.regime {
            pairs.push(("temporal", temporal_to_json(info)));
        }
        obj(pairs)
    }

    /// Deserialize from the versioned JSON plan format. Rejects unknown
    /// `version` values outright (satellite-pinned), so a plan file can
    /// never be silently misread across format changes.
    pub fn from_json(v: &Value) -> crate::Result<DeploymentPlan> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(
            (PLAN_VERSION_MIN..=PLAN_VERSION).contains(&version),
            "unsupported deployment-plan version {version}: this build reads versions \
             {PLAN_VERSION_MIN}..={PLAN_VERSION} — regenerate the plan with `flexipipe plan`"
        );
        let board = board_from_json(v.req("board")?)?;
        let mode = QuantMode::from_bits(v.usize_field("bits")?)?;
        let steps = v.usize_field("steps")?;
        let tenants = v
            .req("tenants")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'tenants' must be an array"))?
            .iter()
            .map(tenant_from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        anyhow::ensure!(!tenants.is_empty(), "deployment plan has no tenants");
        let reconfig = reconfig_from_json(v.req("reconfig")?)?;
        let label = v.str_field("regime")?;
        let regime = match label {
            "spatial" => {
                anyhow::ensure!(
                    v.get("temporal").is_none(),
                    "spatial plan carries a 'temporal' section"
                );
                Regime::Spatial
            }
            "temporal" | "overlay" => {
                let info = temporal_from_json(v.req("temporal")?)?;
                anyhow::ensure!(
                    (label == "overlay") == info.overlay,
                    "regime label '{label}' contradicts the schedule's overlay flag"
                );
                Regime::Temporal(info)
            }
            other => anyhow::bail!("unknown regime '{other}' (spatial temporal overlay)"),
        };
        Ok(DeploymentPlan {
            version,
            board,
            mode,
            steps,
            tenants,
            regime,
            reconfig,
        })
    }

    /// Write the plan to a file (pretty-printed JSON).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a plan from a file. Accepts either a bare plan object or a
    /// whole `flexipipe plan --json` document (a [`PlanSet`] dump), in
    /// which case the `best` plan is read — so the planner's output file
    /// feeds `simulate --plan` / `serve --plan` directly. Every failure —
    /// unreadable file, malformed JSON, unsupported format version —
    /// carries the plan path.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<DeploymentPlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
        match v.get("best") {
            Some(best) => DeploymentPlan::from_json(best),
            None => DeploymentPlan::from_json(&v),
        }
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }
}

// ---------------------------------------------------------------------------
// JSON field codecs
// ---------------------------------------------------------------------------

pub(crate) fn board_to_json(b: &Board) -> Value {
    obj(vec![
        ("name", Value::Str(b.name.clone())),
        ("dsps", num(b.dsps)),
        ("luts", num(b.luts)),
        ("ffs", num(b.ffs)),
        ("bram36", num(b.bram36)),
        ("ddr_bytes_per_sec", Value::Num(b.ddr_bytes_per_sec)),
        ("freq_hz", Value::Num(b.freq_hz)),
    ])
}

pub(crate) fn board_from_json(v: &Value) -> crate::Result<Board> {
    Ok(Board {
        name: v.str_field("name")?.to_string(),
        dsps: v.usize_field("dsps")?,
        luts: v.usize_field("luts")?,
        ffs: v.usize_field("ffs")?,
        bram36: v.usize_field("bram36")?,
        ddr_bytes_per_sec: v.f64_field("ddr_bytes_per_sec")?,
        freq_hz: v.f64_field("freq_hz")?,
    })
}

pub(crate) fn reconfig_to_json(m: &ReconfigModel) -> Value {
    obj(vec![
        ("bytes_per_lut", Value::Num(m.bytes_per_lut)),
        ("bytes_per_dsp", Value::Num(m.bytes_per_dsp)),
        ("bytes_per_bram18", Value::Num(m.bytes_per_bram18)),
        ("base_bytes", Value::Num(m.base_bytes)),
        ("port_bytes_per_sec", Value::Num(m.port_bytes_per_sec)),
        ("overlay_overhead", Value::Num(m.overlay_overhead)),
    ])
}

pub(crate) fn reconfig_from_json(v: &Value) -> crate::Result<ReconfigModel> {
    Ok(ReconfigModel {
        bytes_per_lut: v.f64_field("bytes_per_lut")?,
        bytes_per_dsp: v.f64_field("bytes_per_dsp")?,
        bytes_per_bram18: v.f64_field("bytes_per_bram18")?,
        base_bytes: v.f64_field("base_bytes")?,
        port_bytes_per_sec: v.f64_field("port_bytes_per_sec")?,
        overlay_overhead: v.f64_field("overlay_overhead")?,
    })
}

fn constraint_to_json(c: &Constraint) -> Value {
    match c {
        Constraint::Slo(s) => obj(vec![
            ("kind", Value::Str("slo".to_string())),
            ("seconds", Value::Num(*s)),
        ]),
        Constraint::MinFps(f) => obj(vec![
            ("kind", Value::Str("min_fps".to_string())),
            ("fps", Value::Num(*f)),
        ]),
    }
}

fn constraint_from_json(v: &Value) -> crate::Result<Constraint> {
    match v.str_field("kind")? {
        "slo" => Ok(Constraint::Slo(v.f64_field("seconds")?)),
        "min_fps" => Ok(Constraint::MinFps(v.f64_field("fps")?)),
        other => anyhow::bail!("unknown constraint kind '{other}' (slo min_fps)"),
    }
}

pub(crate) fn tenant_to_json(t: &PlanTenant) -> Value {
    let mut pairs = vec![
        ("model", config::to_json(&t.net)),
        ("weight", Value::Num(t.weight)),
        (
            "constraints",
            Value::Arr(t.constraints.iter().map(constraint_to_json).collect()),
        ),
        ("dsp_parts", num(t.dsp_parts)),
        ("bram_parts", num(t.bram_parts)),
        ("ddr_share", Value::Num(t.ddr_share)),
    ];
    if !t.stages.is_empty() {
        pairs.push((
            "stages",
            Value::Arr(
                t.stages
                    .iter()
                    .map(|c| {
                        obj(vec![("cp", num(c.cp)), ("mp", num(c.mp)), ("k", num(c.k))])
                    })
                    .collect(),
            ),
        ));
    }
    if let Some(r) = &t.record {
        let mut rec = vec![
            ("fps", Value::Num(r.fps)),
            ("latency_s", Value::Num(r.latency_s)),
            ("dsps", num(r.dsps)),
            ("bram18", num(r.bram18)),
        ];
        if let Some(sf) = r.sim_fps {
            rec.push(("sim_fps", Value::Num(sf)));
        }
        pairs.push(("record", obj(rec)));
    }
    obj(pairs)
}

pub(crate) fn tenant_from_json(v: &Value) -> crate::Result<PlanTenant> {
    let net = config::from_json(v.req("model")?)?;
    let constraints = v
        .req("constraints")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("'constraints' must be an array"))?
        .iter()
        .map(constraint_from_json)
        .collect::<crate::Result<Vec<_>>>()?;
    let stages = match v.get("stages") {
        None => Vec::new(),
        Some(s) => s
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'stages' must be an array"))?
            .iter()
            .map(|c| {
                Ok(EngineConfig {
                    cp: c.usize_field("cp")?,
                    mp: c.usize_field("mp")?,
                    k: c.usize_field("k")?,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?,
    };
    let record = match v.get("record") {
        None => None,
        Some(r) => Some(TenantRecord {
            fps: r.f64_field("fps")?,
            latency_s: r.f64_field("latency_s")?,
            dsps: r.usize_field("dsps")?,
            bram18: r.usize_field("bram18")?,
            sim_fps: match r.get("sim_fps") {
                None => None,
                Some(s) => Some(
                    s.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("'sim_fps' must be a number"))?,
                ),
            },
        }),
    };
    Ok(PlanTenant {
        net,
        weight: v.f64_field("weight")?,
        constraints,
        dsp_parts: v.usize_field("dsp_parts")?,
        bram_parts: v.usize_field("bram_parts")?,
        ddr_share: v.f64_field("ddr_share")?,
        stages,
        record,
    })
}

pub(crate) fn temporal_to_json(info: &TemporalInfo) -> Value {
    let usizes = |v: &[usize]| Value::Arr(v.iter().map(|&x| num(x)).collect());
    let u64s = |v: &[u64]| Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect());
    obj(vec![
        ("time_parts", usizes(&info.time_parts)),
        ("interleave", usizes(&info.interleave)),
        ("quantum_cycles", Value::Num(info.quantum_cycles as f64)),
        ("period_cycles", Value::Num(info.period_cycles as f64)),
        ("frames", usizes(&info.frames)),
        ("reconfig_cycles", u64s(&info.reconfig_cycles)),
        ("fill_cycles", u64s(&info.fill_cycles)),
        ("beat_cycles", u64s(&info.beat_cycles)),
        ("latency_cycles", u64s(&info.latency_cycles)),
        ("overlay", Value::Bool(info.overlay)),
        ("dead_frac", Value::Num(info.dead_frac)),
        (
            "slices",
            Value::Arr(
                info.slices
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("tenant", num(s.tenant)),
                            ("parts", num(s.parts)),
                            ("frames", num(s.frames)),
                            ("reconfig_cycles", Value::Num(s.reconfig_cycles as f64)),
                            ("overlap_cycles", Value::Num(s.overlap_cycles as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn u64_field(v: &Value, key: &str) -> crate::Result<u64> {
    v.req(key)?
        .as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0)
        .map(|n| n as u64)
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a non-negative integer"))
}

fn usize_list(v: &Value, key: &str) -> crate::Result<Vec<usize>> {
    v.req(key)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))?
        .iter()
        .map(|e| {
            e.as_usize()
                .ok_or_else(|| anyhow::anyhow!("'{key}' entries must be non-negative integers"))
        })
        .collect()
}

fn u64_list(v: &Value, key: &str) -> crate::Result<Vec<u64>> {
    Ok(usize_list(v, key)?.into_iter().map(|x| x as u64).collect())
}

pub(crate) fn temporal_from_json(v: &Value) -> crate::Result<TemporalInfo> {
    let slices = v
        .req("slices")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("'slices' must be an array"))?
        .iter()
        .map(|s| {
            Ok(SliceSpec {
                tenant: s.usize_field("tenant")?,
                parts: s.usize_field("parts")?,
                frames: s.usize_field("frames")?,
                reconfig_cycles: u64_field(s, "reconfig_cycles")?,
                overlap_cycles: u64_field(s, "overlap_cycles")?,
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(TemporalInfo {
        time_parts: usize_list(v, "time_parts")?,
        interleave: usize_list(v, "interleave")?,
        slices,
        quantum_cycles: u64_field(v, "quantum_cycles")?,
        period_cycles: u64_field(v, "period_cycles")?,
        frames: usize_list(v, "frames")?,
        reconfig_cycles: u64_list(v, "reconfig_cycles")?,
        fill_cycles: u64_list(v, "fill_cycles")?,
        beat_cycles: u64_list(v, "beat_cycles")?,
        latency_cycles: u64_list(v, "latency_cycles")?,
        overlay: v.bool_field("overlay")?,
        dead_frac: v.f64_field("dead_frac")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zedboard;
    use crate::model::zoo;

    #[test]
    fn workload_builder_collects_tenants_and_constraints() {
        let mut w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant_spec(TenantSpec::new(zoo::lenet()).weight(2.0).slo(0.05).min_fps(10.0))
            .objective(Objective::MaxWeightedFps);
        assert_eq!(w.tenants.len(), 2);
        assert_eq!(w.objective, Objective::MaxWeightedFps);
        w.validate().unwrap();
        w.constrain("tinycnn", Constraint::MinFps(5.0)).unwrap();
        assert!(w.constrain("nope", Constraint::Slo(0.1)).is_err());

        // Lowering merges duplicate constraints to the binding one.
        let mut dup = Workload::new(QuantMode::W8A8).tenant_spec(
            TenantSpec::new(zoo::tinycnn())
                .slo(0.05)
                .slo(0.02)
                .min_fps(10.0)
                .min_fps(30.0),
        );
        dup.objective = Objective::MaxMinFps;
        let tenants = dup.to_tenants();
        assert_eq!(tenants[0].slo_s, Some(0.02));
        assert_eq!(tenants[0].min_fps, Some(30.0));

        // Malformed workloads are rejected with the real cause.
        assert!(Workload::new(QuantMode::W8A8).validate().is_err());
        let bad = Workload::new(QuantMode::W8A8)
            .tenant_spec(TenantSpec::new(zoo::tinycnn()).min_fps(-1.0));
        assert!(bad.validate().is_err());
    }

    #[test]
    fn objective_labels_round_trip() {
        for o in [Objective::MaxMinFps, Objective::MaxWeightedFps] {
            assert_eq!(Objective::parse(o.label()).unwrap(), o);
        }
        assert_eq!(Objective::parse("min-fps").unwrap(), Objective::MaxMinFps);
        assert_eq!(Objective::parse("weighted").unwrap(), Objective::MaxWeightedFps);
        assert!(Objective::parse("fastest").is_err());
    }

    #[test]
    fn single_tenant_plans_route_to_solo_allocation() {
        // One tenant → the plain Sec. 4 allocation (the Sharder's pinned
        // single-tenant degeneracy), surfaced through the facade.
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(4).plan(&w).unwrap();
        assert_eq!(set.plans.len(), 1);
        assert_eq!(set.best, set.best_min);
        let plan = &set.plans[set.best];
        assert_eq!(plan.tenants.len(), 1);
        assert_eq!(plan.tenants[0].dsp_parts, 4);
        let direct = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zedboard(), QuantMode::W8A8)
            .unwrap()
            .evaluate();
        let rec = plan.tenants[0].record.as_ref().unwrap();
        assert_eq!(rec.fps.to_bits(), direct.fps.to_bits());
        // And the plan rehydrates to the same allocation.
        let allocs = plan.instantiate().unwrap();
        assert_eq!(allocs[0].evaluate().fps.to_bits(), direct.fps.to_bits());
    }

    #[test]
    fn planner_single_board_matches_sharder_search() {
        // The facade adds no search logic: plan order, frontier, and the
        // objective picks are exactly Sharder::search's.
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let sharder = Sharder {
            steps: 8,
            ..Sharder::new(zedboard(), w.to_tenants())
        };
        let r = sharder.search().unwrap();
        assert_eq!(set.plans.len(), r.plans.len());
        assert_eq!(set.frontier, r.frontier);
        assert_eq!(set.best_min, r.best_min);
        assert_eq!(set.best_weighted, r.best_weighted);
        for (dp, sp) in set.plans.iter().zip(&r.plans) {
            let fps = dp.fps_vec().unwrap();
            for (a, b) in fps.iter().zip(&sp.fps) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn planner_prune_preserves_frontier_and_picks() {
        // The facade-level mirror of the Sharder exactness property:
        // pruning may shrink the exhaustive listing but the frontier and
        // the objective picks keep their contents bit for bit.
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let mk = |prune: bool| {
            Planner::on(zedboard()).steps(8).prune(prune).plan(&w).unwrap()
        };
        let full = mk(false);
        let pruned = mk(true);
        let key = |s: &PlanSet, i: usize| -> (Vec<u64>, Vec<u64>) {
            (
                s.plans[i].fps_vec().unwrap().iter().map(|f| f.to_bits()).collect(),
                s.plans[i].latency_vec().unwrap().iter().map(|l| l.to_bits()).collect(),
            )
        };
        let frontier_keys = |s: &PlanSet| -> Vec<(Vec<u64>, Vec<u64>)> {
            s.frontier.iter().map(|&i| key(s, i)).collect()
        };
        assert_eq!(frontier_keys(&full), frontier_keys(&pruned));
        assert_eq!(key(&full, full.best_min), key(&pruned, pruned.best_min));
        assert_eq!(
            key(&full, full.best_weighted),
            key(&pruned, pruned.best_weighted)
        );
    }

    #[test]
    fn replan_neighborhood_is_bounded_sorted_and_valid() {
        // The warm re-admission region around a spatial incumbent:
        // every candidate is a valid quanta assignment, the incumbent
        // itself is excluded, and candidates come nearest-first.
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let plan = set.plans[set.best_min].clone();
        assert!(matches!(plan.regime, Regime::Spatial));
        let hood = quanta_neighborhood(&plan);
        assert!(!hood.is_empty());
        assert!(hood.len() <= 80, "2 tenants → at most 9² − 1 candidates");

        let dist = |dsp: &[usize], bram: &[usize]| -> usize {
            plan.tenants
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    t.dsp_parts.abs_diff(dsp[i]) + t.bram_parts.abs_diff(bram[i])
                })
                .sum()
        };
        let mut last = 0usize;
        for (dsp, bram) in &hood {
            assert!(dsp.iter().all(|&p| p >= 1) && bram.iter().all(|&p| p >= 1));
            assert!(dsp.iter().sum::<usize>() <= plan.steps);
            assert!(bram.iter().sum::<usize>() <= plan.steps);
            let d = dist(dsp, bram);
            assert!(d >= 1, "the unperturbed incumbent must be excluded");
            assert!(d >= last, "candidates must be ordered nearest-first");
            last = d;
        }
        // No duplicate candidates.
        let mut seen = std::collections::HashSet::new();
        for c in &hood {
            assert!(seen.insert(c.clone()), "duplicate candidate {c:?}");
        }
    }

    #[test]
    fn multi_board_planning_merges_frontiers() {
        use crate::board::zc706;
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::across(vec![zedboard(), zc706()])
            .steps(4)
            .plan(&w)
            .unwrap();
        // Both boards contribute plans; every frontier member is
        // non-dominated across the union.
        assert!(set.plans.iter().any(|p| p.board.name == "zedboard"));
        assert!(set.plans.iter().any(|p| p.board.name == "zc706"));
        for &i in &set.frontier {
            let (fi, li) = (
                set.plans[i].fps_vec().unwrap(),
                set.plans[i].latency_vec().unwrap(),
            );
            for (j, p) in set.plans.iter().enumerate() {
                if j == i {
                    continue;
                }
                let (fj, lj) = (p.fps_vec().unwrap(), p.latency_vec().unwrap());
                assert!(
                    !shard::vec_dominates(&fj, &lj, &fi, &li),
                    "frontier member {i} dominated by plan {j}"
                );
            }
        }
    }

    #[test]
    fn plan_json_round_trips_bit_exactly() {
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant_spec(TenantSpec::new(zoo::lenet()).weight(2.0).min_fps(1.0));
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        for &i in &set.frontier {
            let plan = &set.plans[i];
            let text = plan.to_json().to_pretty();
            let back = DeploymentPlan::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(text, back.to_json().to_pretty(), "serialization not stable");
            assert_eq!(back.version, PLAN_VERSION);
            assert_eq!(back.tenants.len(), 2);
            assert_eq!(back.tenants[1].weight, 2.0);
            assert_eq!(back.tenants[1].constraints, vec![Constraint::MinFps(1.0)]);
            let (a, b) = (plan.fps_vec().unwrap(), back.fps_vec().unwrap());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "fps must round-trip bit-exactly");
            }
        }
    }

    #[test]
    fn unknown_plan_version_is_rejected() {
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn());
        let set = Planner::on(zedboard()).steps(4).plan(&w).unwrap();
        let Value::Obj(mut m) = set.plans[set.best].to_json() else {
            panic!("plans encode as objects")
        };
        m.insert("version".to_string(), Value::Num(99.0));
        let err = DeploymentPlan::from_json(&Value::Obj(m.clone())).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        m.remove("version");
        assert!(DeploymentPlan::from_json(&Value::Obj(m)).is_err());
    }

    #[test]
    fn planset_json_best_is_loadable() {
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let dir = std::env::temp_dir().join("flexipipe_planset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("set.json");
        std::fs::write(&path, set.to_json().to_pretty()).unwrap();
        let best = DeploymentPlan::load(&path).unwrap();
        assert_eq!(
            best.to_json().to_pretty(),
            set.plans[set.best].to_json().to_pretty()
        );
        // A bare plan file loads too.
        set.plans[set.best].save(&path).unwrap();
        let bare = DeploymentPlan::load(&path).unwrap();
        assert_eq!(
            bare.to_json().to_pretty(),
            set.plans[set.best].to_json().to_pretty()
        );
    }

    #[test]
    fn instantiate_rejects_oversubscribed_spatial_plans() {
        // A hand-edited plan can claim more board than exists; the
        // rehydration path must refuse it with the real cause — never
        // simulate or serve physically impossible resources.
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let plan = set.plans[set.best].clone();
        let mut over = plan.clone();
        for t in &mut over.tenants {
            t.dsp_parts = over.steps;
            t.bram_parts = over.steps;
            t.ddr_share = 1.0;
        }
        let err = over.instantiate().unwrap_err();
        assert!(err.to_string().contains("oversubscribes"), "{err}");
        // Oversubscribing only the DDR port is refused too.
        let mut port = plan.clone();
        for t in &mut port.tenants {
            t.ddr_share = 1.0;
        }
        let err = port.instantiate().unwrap_err();
        assert!(err.to_string().contains("DDR"), "{err}");
    }

    #[test]
    fn instantiate_rejects_nonphysical_boards_and_overheads() {
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(4).plan(&w).unwrap();
        let plan = &set.plans[set.best];
        let mut frozen = plan.clone();
        frozen.board.freq_hz = 0.0;
        let err = frozen.instantiate().unwrap_err();
        assert!(err.to_string().contains("nonphysical"), "{err}");
        let mut portless = plan.clone();
        portless.board.ddr_bytes_per_sec = -1.0;
        assert!(portless.instantiate().is_err());
        let mut optimistic = plan.clone();
        optimistic.reconfig.overlay_overhead = 0.5;
        let err = optimistic.instantiate().unwrap_err();
        assert!(err.to_string().contains("overlay_overhead"), "{err}");
    }

    #[test]
    fn instantiate_rejects_malformed_temporal_schedules() {
        use crate::board::zc706;
        use crate::shard::ScheduleMode;
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zc706())
            .steps(4)
            .schedule(ScheduleMode::Temporal)
            .max_period(0.1)
            .plan(&w)
            .unwrap();
        let plan = set.plans[set.frontier[0]].clone();
        plan.instantiate().unwrap();
        // (a) A schedule that forgets a tenant must be refused, not panic
        // inside the DES.
        let mut orphaned = plan.clone();
        if let Regime::Temporal(info) = &mut orphaned.regime {
            for s in &mut info.slices {
                s.tenant = 0;
            }
        }
        let err = orphaned.instantiate().unwrap_err();
        assert!(err.to_string().contains("admits no frames"), "{err}");
        // (b) Zero-frame slices for one tenant are the same hole.
        let mut starved = plan.clone();
        if let Regime::Temporal(info) = &mut starved.regime {
            for s in info.slices.iter_mut().filter(|s| s.tenant == 1) {
                s.frames = 0;
            }
        }
        let err = starved.instantiate().unwrap_err();
        assert!(err.to_string().contains("admits no frames"), "{err}");
        // (c) period_cycles == 0 means continuous solo — impossible with
        // two tenants.
        let mut solo = plan.clone();
        if let Regime::Temporal(info) = &mut solo.regime {
            info.period_cycles = 0;
        }
        let err = solo.instantiate().unwrap_err();
        assert!(err.to_string().contains("continuous solo"), "{err}");
    }

    #[test]
    fn instantiate_rejects_allocator_drift() {
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(4).plan(&w).unwrap();
        let mut plan = set.plans[set.best].clone();
        plan.instantiate().unwrap();
        // Corrupt a recorded stage config: rehydration must refuse.
        plan.tenants[0].stages[0].cp += 1;
        let err = plan.instantiate().unwrap_err();
        assert!(err.to_string().contains("allocator"), "{err}");
        // Hand-authored plans (no recorded stages) skip the check.
        plan.tenants[0].stages.clear();
        plan.instantiate().unwrap();
    }
}
