//! Cycle-level pipeline simulator.
//!
//! The closed-form report (Eq. 2–4 in [`crate::alloc`]) assumes a perfectly
//! balanced, never-stalling pipeline. This module *executes* the dataflow
//! of Fig. 1/Fig. 2 as a discrete-event simulation at row-group granularity
//! and accounts for everything the closed form hides:
//!
//! - line-buffer occupancy (a stage can't start until its input window is
//!   resident — and can't write if the downstream buffer is full),
//! - DDR contention (weight streams from all engines + the actIn frame
//!   stream share one `β` bytes/cycle DDR port, modelled as a weighted-
//!   fair fluid server — see the DDR model note in `simulate_pipeline`),
//! - pipeline fill/drain (the makespan of `F` frames is measured),
//! - ragged tails (last row group of a frame, non-divisor `C'`,`M'`).
//!
//! Sequential-group architectures (fusion, recurrent) don't pipeline across
//! groups by construction; their makespan is the analytic per-group sum —
//! the DES applies to the pipelined archs where stalls are emergent.

use crate::alloc::{AllocReport, Allocation};
use crate::engine::buffer_geometry;
use crate::model::Layer;

/// Per-stage simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Cycles the engine spent computing groups.
    pub busy_cycles: u64,
    /// Cycles lost waiting for weights from DDR (beyond engine readiness).
    pub stall_weights: u64,
    /// Groups completed.
    pub groups_done: u64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Frames simulated.
    pub frames: usize,
    /// Total makespan in cycles.
    pub makespan: u64,
    /// Average cycles per frame over the run.
    pub cycles_per_frame: f64,
    /// Frames per second at the allocation's clock.
    pub fps: f64,
    /// Conventional GOPS.
    pub gops: f64,
    /// MAC-slot efficiency over the whole run (the paper's DSP efficiency,
    /// measured instead of derived).
    pub dsp_efficiency: f64,
    /// DDR bytes moved.
    pub ddr_bytes: u64,
    /// Fraction of DDR capacity used during the run.
    pub ddr_utilization: f64,
    /// Per-stage stats.
    pub stages: Vec<StageStats>,
}

/// Simulate an allocation for `frames` frames.
pub fn simulate(alloc: &Allocation, frames: usize) -> SimReport {
    match &alloc.groups {
        None => simulate_pipeline(alloc, frames),
        Some(_) => simulate_sequential(alloc, frames),
    }
}

// ---------------------------------------------------------------------------
// Pipelined architectures: discrete-event simulation
// ---------------------------------------------------------------------------

/// Per-stage static schedule parameters derived once.
struct StageParams {
    /// Input-window rows needed for one group: `R + G·(K−1)` (spatial) or
    /// the full input map (FC).
    window: usize,
    /// Input rows consumed (retired) per group: `G·K`.
    advance: usize,
    /// Output rows produced per group.
    k_out: usize,
    /// Output rows per frame.
    h_out: usize,
    /// Input rows per frame (from the producing stage).
    h_in: usize,
    /// Groups per frame.
    groups: u64,
    /// Cycles per group.
    t_row: u64,
    /// Weight bytes to fetch per group (0 for pools).
    weight_bytes: u64,
    /// Input line-buffer capacity in rows.
    capacity: usize,
    /// Multipliers (for efficiency accounting).
    mults: u64,
}

fn stage_params(alloc: &Allocation) -> Vec<StageParams> {
    let net = &alloc.net;
    let mut h_prev = net.input.1; // rows produced by the virtual actIn stage
    alloc
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let layer = &net.layers[s.layer_idx];
            let (pk, pm) = alloc.producer(i);
            let geo = buffer_geometry(layer, &s.cfg, pk, pm);
            let (window, advance, h_out) = match layer {
                Layer::Conv(c) => (
                    (c.r + c.stride * (s.cfg.k - 1)).min(h_prev),
                    c.stride * s.cfg.k,
                    c.h,
                ),
                Layer::Pool(p) => (
                    (p.r + p.stride * (s.cfg.k - 1)).min(h_prev),
                    p.stride * s.cfg.k,
                    p.h,
                ),
                Layer::Fc(_) => (h_prev, h_prev, 1),
            };
            let p = StageParams {
                window,
                advance,
                k_out: s.cfg.k.min(h_out),
                h_out,
                h_in: h_prev,
                groups: s.figures.groups_per_frame,
                t_row: s.figures.t_row.max(1),
                weight_bytes: s.figures.weight_bytes_per_group,
                capacity: geo.row_buffers.max(window + pk),
                mults: s.figures.mults as u64,
            };
            h_prev = h_out;
            p
        })
        .collect()
}

/// Discrete-event pipeline simulation at row-group granularity.
pub fn simulate_pipeline(alloc: &Allocation, frames: usize) -> SimReport {
    let params = stage_params(alloc);
    let n = params.len();
    let bpc = alloc.board.ddr_bytes_per_sec / alloc.freq_hz; // bytes/cycle

    // Dynamic state. `row_ready[i][f]` holds the arrival time of each of
    // stage i's input rows for frame f (rows arrive in order; the group
    // start waits for the arrival time of the last row of its window).
    let mut next_group = vec![0u64; n]; // global group index (across frames)
    let mut row_ready: Vec<Vec<Vec<u64>>> = (0..n).map(|_| vec![Vec::new(); frames]).collect();
    let mut retired = vec![vec![0u64; frames]; n]; // input rows retired, per frame
    let mut engine_free = vec![0u64; n];
    let mut stats: Vec<StageStats> = (0..n).map(|_| StageStats::default()).collect();

    // DDR model: weighted-fair-queueing fluid server. Each engine's weight
    // streamer (and the actIn unpacker) receives a bandwidth share
    // proportional to its steady-state demand — what an AXI interconnect
    // with QoS weights converges to. A FIFO burst model would let one
    // 200 MB FC weight burst head-of-line-block every conv engine, which
    // the real design avoids by interleaving (the weight buffers are
    // double-buffered and the controller round-robins requestors).
    let mut ddr_bytes = 0u64;
    let (c0, h0, w0) = alloc.net.input;
    let row_bytes = (c0 * w0 * alloc.mode.act_bytes()) as u64;
    let total_in_rows = h0 * frames;
    let actin_bpf = (h0 as u64) * row_bytes;
    let total_bpf: f64 = params
        .iter()
        .map(|p| (p.weight_bytes * p.groups) as f64)
        .sum::<f64>()
        + actin_bpf as f64;
    // Bandwidth share per stage (fluid WFQ): own demand / total demand.
    let share = |bytes_per_frame: f64| -> f64 {
        (bytes_per_frame / total_bpf).max(1e-6)
    };
    // actIn: input rows become resident at the unpacker's fair rate.
    let actin_rate = bpc * share(actin_bpf as f64); // bytes/cycle
    for r in 0..total_in_rows {
        let t = (((r as u64 + 1) * row_bytes) as f64 / actin_rate).ceil() as u64;
        row_ready[0][r / h0].push(t);
    }
    ddr_bytes += actin_bpf * frames as u64;
    let _ = total_in_rows;

    // Weight streaming: engines consume weights phase-by-phase (weight-
    // stationary = load M'·C'·R·S per phase), so a group's effective
    // duration is max(T_row, weight service time at the stage's fair
    // share) — the stream overlaps compute rather than gating the start.
    // Only the very first group of each stage pays the fill latency.
    let weight_service: Vec<u64> = params
        .iter()
        .map(|p| {
            if p.weight_bytes == 0 {
                0
            } else {
                let rate = bpc * share((p.weight_bytes * p.groups) as f64);
                (p.weight_bytes as f64 / rate).ceil() as u64
            }
        })
        .collect();

    let total_groups: u64 = params.iter().map(|p| p.groups * frames as u64).sum();
    let mut done_groups = 0u64;
    let mut now_max = 0u64;
    // Completion time of each frame (last stage's last group) — used to
    // separate the steady-state beat from the pipeline fill.
    let mut frame_done = vec![0u64; frames];

    while done_groups < total_groups {
        // Find the stage that can start its next group the earliest.
        let mut best: Option<(u64, usize, u64)> = None; // (start, stage, weight wait)
        for i in 0..n {
            let p = &params[i];
            let g = next_group[i];
            if g >= p.groups * frames as u64 {
                continue;
            }
            let f = (g / p.groups) as usize;
            let gi = g % p.groups;
            let need_rows = (gi as usize * p.advance + p.window).min(p.h_in) as u64;

            // (a) input available (with its arrival time)?
            if (row_ready[i][f].len() as u64) < need_rows {
                continue; // producer progress will enable this stage
            }
            let t_rows = row_ready[i][f][need_rows as usize - 1];
            // (d) downstream space.
            if i + 1 < n {
                let occupied = row_ready[i + 1][f].len() as u64 - retired[i + 1][f];
                if (occupied + p.k_out as u64) > params[i + 1].capacity as u64 {
                    continue; // consumer progress will free space
                }
            }
            let t_eng = engine_free[i];
            // First group pays the initial weight-buffer fill.
            let t_w = if p.weight_bytes > 0 && g == 0 {
                weight_service[i]
            } else {
                0
            };
            let start = t_rows.max(t_eng).max(t_w);
            let wwait = weight_service[i].saturating_sub(p.t_row);
            if best.map_or(true, |(b, _, _)| start < b) {
                best = Some((start, i, wwait));
            }
        }

        let Some((start, i, wwait)) = best else {
            debug_assert!(false, "pipeline deadlock at {done_groups}/{total_groups}");
            break;
        };

        let p = &params[i];
        let g = next_group[i];
        let f = (g / p.groups) as usize;
        let gi = g % p.groups;
        // Streaming overlap: the group ends when both compute and its
        // weight stream are done.
        let finish = start + p.t_row.max(weight_service[i]);

        stats[i].stall_weights += wwait;
        stats[i].busy_cycles += p.t_row;
        stats[i].groups_done += 1;
        if p.weight_bytes > 0 {
            ddr_bytes += p.weight_bytes;
        }

        engine_free[i] = finish;
        next_group[i] = g + 1;
        retired[i][f] = ((gi + 1) * p.advance as u64).min(p.h_in as u64);
        // Produce output rows for the consumer (tail group may be short).
        let already = (gi as usize * p.k_out).min(p.h_out);
        let produced = p.k_out.min(p.h_out - already).max(1) as u64;
        if i + 1 < n {
            for _ in 0..produced {
                row_ready[i + 1][f].push(finish);
            }
        }

        now_max = now_max.max(finish);
        if i == n - 1 {
            frame_done[f] = frame_done[f].max(finish);
        }
        done_groups += 1;
    }

    let makespan = now_max.max(1);
    // Steady-state beat: inter-frame completion gap once the pipeline is
    // full (fill latency belongs to the first frame only — Eq. 4 is a
    // throughput statement). Single-frame runs report the full latency.
    let cycles_per_frame = if frames > 1 {
        (frame_done[frames - 1] - frame_done[0]) as f64 / (frames - 1) as f64
    } else {
        makespan as f64
    };
    let fps = alloc.freq_hz / cycles_per_frame;
    let macs = alloc.net.macs();
    let gops = 2.0 * macs as f64 * fps / 1e9;
    let mults_total: u64 = params.iter().map(|p| p.mults).sum();
    let dsp_efficiency = macs as f64 / (mults_total as f64 * cycles_per_frame);
    let ddr_utilization = ddr_bytes as f64 / (bpc * makespan as f64);

    SimReport {
        frames,
        makespan,
        cycles_per_frame,
        fps,
        gops,
        dsp_efficiency,
        ddr_bytes,
        ddr_utilization,
        stages: stats,
    }
}

// ---------------------------------------------------------------------------
// Sequential-group architectures: analytic makespan
// ---------------------------------------------------------------------------

fn simulate_sequential(alloc: &Allocation, frames: usize) -> SimReport {
    let r: AllocReport = alloc.evaluate();
    let makespan = r.t_frame_cycles * frames as u64;
    let stats = alloc
        .stages
        .iter()
        .zip(alloc.stage_cycles())
        .map(|(s, c)| StageStats {
            busy_cycles: c * frames as u64,
            groups_done: s.figures.groups_per_frame * frames as u64,
            ..Default::default()
        })
        .collect();
    let weight_bytes: u64 = alloc
        .stages
        .iter()
        .map(|s| s.figures.weight_bytes_per_frame())
        .sum();
    SimReport {
        frames,
        makespan,
        cycles_per_frame: r.t_frame_cycles as f64,
        fps: r.fps,
        gops: r.gops,
        dsp_efficiency: r.dsp_efficiency,
        ddr_bytes: weight_bytes * frames as u64,
        ddr_utilization: (weight_bytes as f64 * r.fps) / alloc.board.ddr_bytes_per_sec,
        stages: stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flex::FlexAllocator;
    use crate::alloc::Allocator;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;
    use crate::quant::QuantMode;

    #[test]
    fn sim_matches_closed_form_on_balanced_pipeline() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::tinycnn(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let cf = alloc.evaluate();
        let sim = simulate(&alloc, 6);
        let ratio = sim.cycles_per_frame / cf.t_frame_cycles as f64;
        assert!(
            (0.9..1.7).contains(&ratio),
            "sim {:.0} vs closed-form {} (ratio {ratio:.2})",
            sim.cycles_per_frame,
            cf.t_frame_cycles
        );
    }

    #[test]
    fn sim_efficiency_near_closed_form_on_vgg16() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let sim = simulate(&alloc, 3);
        let cf = alloc.evaluate();
        assert!(
            (sim.dsp_efficiency - cf.dsp_efficiency).abs() < 0.15,
            "sim {:.3} vs cf {:.3}",
            sim.dsp_efficiency,
            cf.dsp_efficiency
        );
    }

    #[test]
    fn starved_bandwidth_shows_weight_stalls() {
        // A board with 100x less DDR bandwidth must stall on weights.
        let mut starved = zc706();
        starved.ddr_bytes_per_sec /= 100.0;
        let alloc = FlexAllocator {
            max_k_steps: 0, // disable Alg.2 so the stall is visible
            ..Default::default()
        }
        .allocate(&zoo::vgg16(), &starved, QuantMode::W16A16)
        .unwrap();
        let sim = simulate(&alloc, 2);
        let total_wstall: u64 = sim.stages.iter().map(|s| s.stall_weights).sum();
        assert!(total_wstall > 0, "expected weight stalls on starved DDR");
    }

    #[test]
    fn more_frames_amortize_fill() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zedboard(), QuantMode::W8A8)
            .unwrap();
        let s2 = simulate(&alloc, 2);
        let s8 = simulate(&alloc, 8);
        assert!(
            s8.cycles_per_frame <= s2.cycles_per_frame * 1.05,
            "per-frame cost should not grow with frames: {} vs {}",
            s8.cycles_per_frame,
            s2.cycles_per_frame
        );
    }

    #[test]
    fn all_groups_complete() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg_micro(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let frames = 4;
        let sim = simulate(&alloc, frames);
        for (i, (st, a)) in sim.stages.iter().zip(&alloc.stages).enumerate() {
            assert_eq!(
                st.groups_done,
                a.figures.groups_per_frame * frames as u64,
                "stage {i} incomplete"
            );
        }
    }
}
