//! Cycle-level pipeline simulator.
//!
//! The closed-form report (Eq. 2–4 in [`crate::alloc`]) assumes a perfectly
//! balanced, never-stalling pipeline. This module *executes* the dataflow
//! of Fig. 1/Fig. 2 as a discrete-event simulation at row-group granularity
//! and accounts for everything the closed form hides:
//!
//! - line-buffer occupancy (a stage can't start until its input window is
//!   resident — and can't write if the downstream buffer is full),
//! - DDR contention (weight streams from all engines + the actIn frame
//!   stream share one `β` bytes/cycle DDR port, modelled as a weighted-
//!   fair fluid server — see the DDR model note in [`SimSetup`]),
//! - pipeline fill/drain (the makespan of `F` frames is measured),
//! - ragged tails (last row group of a frame, non-divisor `C'`,`M'`).
//!
//! Sequential-group architectures (fusion, recurrent) don't pipeline across
//! groups by construction; their makespan is the analytic per-group sum —
//! the DES applies to the pipelined archs where stalls are emergent.
//!
//! # Public surface
//!
//! Two entry points: [`simulate`] runs one allocation's pipeline, and the
//! [`Simulate`] trait executes a whole [`crate::plan::DeploymentPlan`]
//! (spatial shared-port, time-multiplexed, or overlay) through one
//! `simulate(&plan)` call — the only way a multi-tenant deployment is
//! simulated. The specialized DES engines behind it (`simulate_multi`,
//! `simulate_multi_provisioned`, `simulate_schedule`,
//! `simulate_timeshared`, and the naive executable spec) are
//! crate-private; the hidden `engines` module re-exports them for the
//! crate's own property/golden suites and benches only.
//!
//! # Scheduler structure
//!
//! The simulation is a greedy list scheduler: repeatedly fire the startable
//! stage with the earliest start time. The ready-queue DES keeps a
//! min-heap of `(start, stage)` entries current by recomputing only the
//! stages an event can affect. Firing stage
//! `i` changes exactly the eligibility inputs of stages `i−1` (space in
//! `i`'s buffer frees), `i` (engine busy, next group), and `i+1` (new input
//! rows): per-event work is O(affected stages · log n) instead of the
//! naive O(all stages). The naive full-rescan loop is preserved as
//! `simulate_pipeline_naive` — the executable spec; both run on the same
//! [`SimState`] eligibility/firing code, and property + golden tests assert
//! identical reports. Tie-breaking matches too: the heap orders
//! `(start, stage)` ascending, which is the naive scan's
//! first-lowest-index-wins rule.
//!
//! # Event-skip invariant
//!
//! Neither DES ever advances time by polling: the pipeline wheel pops the
//! next *start event* off its heap (idle windows between events cost
//! nothing — time leaps to the next startable group), and the arrival
//! replay wheel leaps over occurrences that provably admit and serve
//! nothing (empty queue, next arrival beyond their start). A skipped
//! window is exactly one in which every stage's `start_of` is `None` or
//! every occurrence is a no-op, so skipping is *exact*: the skipping and
//! stepping schedulers are pinned byte-identical by the equivalence
//! suites (`event_wheel_matches_naive_scheduler`,
//! `replay_event_skip_matches_stepping`).

use crate::alloc::{AllocReport, Allocation};
use crate::board::Board;
use crate::engine::buffer_geometry;
use crate::model::Layer;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Per-stage simulation statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StageStats {
    /// Cycles the engine spent computing groups.
    pub busy_cycles: u64,
    /// Cycles lost waiting for weights from DDR (beyond engine readiness).
    pub stall_weights: u64,
    /// Groups completed.
    pub groups_done: u64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Frames simulated.
    pub frames: usize,
    /// Total makespan in cycles.
    pub makespan: u64,
    /// Average cycles per frame over the run.
    pub cycles_per_frame: f64,
    /// Frames per second at the allocation's clock.
    pub fps: f64,
    /// Conventional GOPS.
    pub gops: f64,
    /// MAC-slot efficiency over the whole run (the paper's DSP efficiency,
    /// measured instead of derived).
    pub dsp_efficiency: f64,
    /// DDR bytes moved.
    pub ddr_bytes: u64,
    /// Fraction of DDR capacity used during the run.
    pub ddr_utilization: f64,
    /// Per-stage stats.
    pub stages: Vec<StageStats>,
    /// Completion cycle of each simulated frame (last stage's last group).
    /// Because a frame's schedule never depends on later frames (stages
    /// process groups in frame order and the actIn stream rate is fixed),
    /// `frame_done[n-1]` of a long run *is* the makespan of an `n`-frame
    /// run — the prefix property the time-shared scheduler's calibration
    /// ([`crate::shard::schedule`]) relies on.
    pub frame_done: Vec<u64>,
    /// Completion cycle of each frame on the pipeline's *input side* (the
    /// first stage's last group of that frame). The drain tail of an
    /// `n`-frame batch is `frame_done[n-1] - input_done[n-1]`: the window
    /// in which the input-side stages sit idle while the rest of the
    /// pipeline empties — the window a drain-overlapped reconfiguration
    /// (the schedule executor behind [`Simulate`]) hides
    /// partial-bitstream streaming under.
    /// Shares `frame_done`'s prefix property (the first stage's schedule
    /// never depends on later frames either); single-stage pipelines have
    /// `input_done == frame_done` (no drain window at all). For
    /// sequential-group architectures the batch never overlaps frames, so
    /// `input_done == frame_done` there too.
    pub input_done: Vec<u64>,
}

/// Simulate an allocation for `frames` frames.
pub fn simulate(alloc: &Allocation, frames: usize) -> SimReport {
    match &alloc.groups {
        None => simulate_pipeline(alloc, frames),
        Some(_) => simulate_sequential(alloc, frames),
    }
}

// ---------------------------------------------------------------------------
// Pipelined architectures: discrete-event simulation
// ---------------------------------------------------------------------------

/// Per-stage static schedule parameters derived once.
struct StageParams {
    /// Input-window rows needed for one group: `R + G·(K−1)` (spatial) or
    /// the full input map (FC).
    window: usize,
    /// Input rows consumed (retired) per group: `G·K`.
    advance: usize,
    /// Output rows produced per group.
    k_out: usize,
    /// Output rows per frame.
    h_out: usize,
    /// Input rows per frame (from the producing stage).
    h_in: usize,
    /// Groups per frame.
    groups: u64,
    /// Cycles per group.
    t_row: u64,
    /// Weight bytes to fetch per group (0 for pools).
    weight_bytes: u64,
    /// Input line-buffer capacity in rows.
    capacity: usize,
    /// Multipliers (for efficiency accounting).
    mults: u64,
}

fn stage_params(alloc: &Allocation) -> Vec<StageParams> {
    let net = &alloc.net;
    let mut h_prev = net.input.1; // rows produced by the virtual actIn stage
    alloc
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let layer = &net.layers[s.layer_idx];
            let (pk, pm) = alloc.producer(i);
            let geo = buffer_geometry(layer, &s.cfg, pk, pm);
            let (window, advance, h_out) = match layer {
                Layer::Conv(c) => (
                    (c.r + c.stride * (s.cfg.k - 1)).min(h_prev),
                    c.stride * s.cfg.k,
                    c.h,
                ),
                Layer::Pool(p) => (
                    (p.r + p.stride * (s.cfg.k - 1)).min(h_prev),
                    p.stride * s.cfg.k,
                    p.h,
                ),
                Layer::Fc(_) => (h_prev, h_prev, 1),
            };
            let p = StageParams {
                window,
                advance,
                k_out: s.cfg.k.min(h_out),
                h_out,
                h_in: h_prev,
                groups: s.figures.groups_per_frame,
                t_row: s.figures.t_row.max(1),
                weight_bytes: s.figures.weight_bytes_per_group,
                capacity: geo.row_buffers.max(window + pk),
                mults: s.figures.mults as u64,
            };
            h_prev = h_out;
            p
        })
        .collect()
}

/// Static setup shared by both scheduler implementations.
///
/// DDR model: weighted-fair-queueing fluid server. Each engine's weight
/// streamer (and the actIn unpacker) receives a bandwidth share
/// proportional to its steady-state demand — what an AXI interconnect
/// with QoS weights converges to. A FIFO burst model would let one
/// 200 MB FC weight burst head-of-line-block every conv engine, which
/// the real design avoids by interleaving (the weight buffers are
/// double-buffered and the controller round-robins requestors).
struct SimState {
    params: Vec<StageParams>,
    n: usize,
    frames: usize,
    /// Per-stage effective weight service time at its fair DDR share.
    weight_service: Vec<u64>,
    /// Global group index (across frames) of each stage's next group.
    next_group: Vec<u64>,
    /// `row_ready[i][f]`: arrival time of each of stage i's input rows for
    /// frame f (rows arrive in order; a group start waits for the arrival
    /// time of the last row of its window).
    row_ready: Vec<Vec<Vec<u64>>>,
    /// Input rows retired, per stage per frame.
    retired: Vec<Vec<u64>>,
    engine_free: Vec<u64>,
    stats: Vec<StageStats>,
    ddr_bytes: u64,
    total_groups: u64,
    done_groups: u64,
    now_max: u64,
    /// Completion time of each frame (last stage's last group) — used to
    /// separate the steady-state beat from the pipeline fill.
    frame_done: Vec<u64>,
    /// Completion time of each frame at the first stage (input side) —
    /// the start of the frame's drain tail.
    input_done: Vec<u64>,
    /// DDR bytes per cycle of the *physical* port this pipeline draws from
    /// (the full board rate in multi-tenant runs, not the tenant's share).
    bpc: f64,
}

/// The WFQ denominator for one pipeline: every stage's per-frame weight
/// stream plus the actIn frame stream, in bytes/frame. [`simulate_multi`]
/// sums this across tenants to weigh each stream's share of the shared
/// physical port; computed with the *same arithmetic* as the single-
/// pipeline setup so a lone tenant's schedule is bit-identical.
fn demand_of(params: &[StageParams], alloc: &Allocation) -> f64 {
    let (c0, h0, w0) = alloc.net.input;
    let row_bytes = (c0 * w0 * alloc.mode.act_bytes()) as u64;
    let actin_bpf = (h0 as u64) * row_bytes;
    params
        .iter()
        .map(|p| (p.weight_bytes * p.groups) as f64)
        .sum::<f64>()
        + actin_bpf as f64
}

/// Public view of [`demand_of`]: one allocation's total DDR stream demand
/// in bytes per frame, exactly as the simulator's fluid WFQ model weighs it.
pub fn ddr_stream_demand(alloc: &Allocation) -> f64 {
    demand_of(&stage_params(alloc), alloc)
}

impl SimState {
    fn new(alloc: &Allocation, frames: usize) -> SimState {
        Self::with_ddr(alloc, frames, alloc.board.ddr_bytes_per_sec, None)
    }

    /// Like [`SimState::new`] but with the physical DDR rate and
    /// (optionally) the WFQ denominator supplied by the caller. This is how
    /// the multi-tenant simulation shares one port: every tenant's streams
    /// are weighed against `shared_demand` (the union of all tenants'
    /// streams) instead of only their own pipeline's. `None` reproduces the
    /// single-pipeline behaviour bit-for-bit.
    fn with_ddr(
        alloc: &Allocation,
        frames: usize,
        ddr_bytes_per_sec: f64,
        shared_demand: Option<f64>,
    ) -> SimState {
        let params = stage_params(alloc);
        let n = params.len();
        let bpc = ddr_bytes_per_sec / alloc.freq_hz; // bytes/cycle

        let mut ddr_bytes = 0u64;
        let (c0, h0, w0) = alloc.net.input;
        let row_bytes = (c0 * w0 * alloc.mode.act_bytes()) as u64;
        let total_in_rows = h0 * frames;
        let actin_bpf = (h0 as u64) * row_bytes;
        let total_bpf: f64 = match shared_demand {
            Some(t) => t,
            None => demand_of(&params, alloc),
        };
        // Bandwidth share per stage (fluid WFQ): own demand / total demand.
        let share = |bytes_per_frame: f64| -> f64 { (bytes_per_frame / total_bpf).max(1e-6) };
        // actIn: input rows become resident at the unpacker's fair rate.
        let mut row_ready: Vec<Vec<Vec<u64>>> = (0..n).map(|_| vec![Vec::new(); frames]).collect();
        let actin_rate = bpc * share(actin_bpf as f64); // bytes/cycle
        for r in 0..total_in_rows {
            let t = (((r as u64 + 1) * row_bytes) as f64 / actin_rate).ceil() as u64;
            row_ready[0][r / h0].push(t);
        }
        ddr_bytes += actin_bpf * frames as u64;

        // Weight streaming: engines consume weights phase-by-phase (weight-
        // stationary = load M'·C'·R·S per phase), so a group's effective
        // duration is max(T_row, weight service time at the stage's fair
        // share) — the stream overlaps compute rather than gating the
        // start. Only the very first group of each stage pays the fill
        // latency.
        let weight_service: Vec<u64> = params
            .iter()
            .map(|p| {
                if p.weight_bytes == 0 {
                    0
                } else {
                    let rate = bpc * share((p.weight_bytes * p.groups) as f64);
                    (p.weight_bytes as f64 / rate).ceil() as u64
                }
            })
            .collect();

        let total_groups: u64 = params.iter().map(|p| p.groups * frames as u64).sum();
        SimState {
            n,
            frames,
            weight_service,
            next_group: vec![0u64; n],
            row_ready,
            retired: vec![vec![0u64; frames]; n],
            engine_free: vec![0u64; n],
            stats: (0..n).map(|_| StageStats::default()).collect(),
            ddr_bytes,
            total_groups,
            done_groups: 0,
            now_max: 0,
            frame_done: vec![0u64; frames],
            input_done: vec![0u64; frames],
            bpc,
            params,
        }
    }

    /// Earliest start of stage `i`'s next group under the current state, or
    /// `None` when the stage is finished / input-starved / back-pressured.
    fn start_of(&self, i: usize) -> Option<u64> {
        let p = &self.params[i];
        let g = self.next_group[i];
        if g >= p.groups * self.frames as u64 {
            return None;
        }
        let f = (g / p.groups) as usize;
        let gi = g % p.groups;
        let need_rows = (gi as usize * p.advance + p.window).min(p.h_in) as u64;

        // (a) input available (with its arrival time)?
        if (self.row_ready[i][f].len() as u64) < need_rows {
            return None; // producer progress will enable this stage
        }
        // `need_rows == 0` can only arise from a zero-extent layer, which
        // `Network::validate` rejects with a typed error; guard the index
        // anyway so a degenerate state can never underflow `need_rows - 1`.
        let t_rows = match need_rows {
            0 => 0,
            n => self.row_ready[i][f][n as usize - 1],
        };
        // (b) downstream space.
        if i + 1 < self.n {
            let occupied = self.row_ready[i + 1][f].len() as u64 - self.retired[i + 1][f];
            if (occupied + p.k_out as u64) > self.params[i + 1].capacity as u64 {
                return None; // consumer progress will free space
            }
        }
        let t_eng = self.engine_free[i];
        // First group pays the initial weight-buffer fill.
        let t_w = if p.weight_bytes > 0 && g == 0 {
            self.weight_service[i]
        } else {
            0
        };
        Some(t_rows.max(t_eng).max(t_w))
    }

    /// Fire stage `i`'s next group at `start` (must come from
    /// [`SimState::start_of`]).
    fn fire(&mut self, i: usize, start: u64) {
        let p = &self.params[i];
        let (t_row, weight_bytes, advance, h_in, k_out, h_out, groups) = (
            p.t_row, p.weight_bytes, p.advance, p.h_in, p.k_out, p.h_out, p.groups,
        );
        let g = self.next_group[i];
        let f = (g / groups) as usize;
        let gi = g % groups;
        // Streaming overlap: the group ends when both compute and its
        // weight stream are done.
        let finish = start + t_row.max(self.weight_service[i]);
        let wwait = self.weight_service[i].saturating_sub(t_row);

        self.stats[i].stall_weights += wwait;
        self.stats[i].busy_cycles += t_row;
        self.stats[i].groups_done += 1;
        if weight_bytes > 0 {
            self.ddr_bytes += weight_bytes;
        }

        self.engine_free[i] = finish;
        self.next_group[i] = g + 1;
        self.retired[i][f] = ((gi + 1) * advance as u64).min(h_in as u64);
        // Produce output rows for the consumer (tail group may be short).
        let already = (gi as usize * k_out).min(h_out);
        let produced = k_out.min(h_out - already).max(1) as u64;
        if i + 1 < self.n {
            for _ in 0..produced {
                self.row_ready[i + 1][f].push(finish);
            }
        }

        self.now_max = self.now_max.max(finish);
        if i == 0 {
            self.input_done[f] = self.input_done[f].max(finish);
        }
        if i == self.n - 1 {
            self.frame_done[f] = self.frame_done[f].max(finish);
        }
        self.done_groups += 1;
    }

    /// Wrap up into a [`SimReport`] once all groups are done.
    fn report(self, alloc: &Allocation) -> SimReport {
        let bpc = self.bpc;
        let makespan = self.now_max.max(1);
        // Steady-state beat: inter-frame completion gap once the pipeline
        // is full (fill latency belongs to the first frame only — Eq. 4 is
        // a throughput statement). Single-frame runs report the full
        // latency.
        let cycles_per_frame = if self.frames > 1 {
            (self.frame_done[self.frames - 1] - self.frame_done[0]) as f64
                / (self.frames - 1) as f64
        } else {
            makespan as f64
        };
        let fps = alloc.freq_hz / cycles_per_frame;
        let macs = alloc.net.macs();
        let gops = 2.0 * macs as f64 * fps / 1e9;
        let mults_total: u64 = self.params.iter().map(|p| p.mults).sum();
        let dsp_efficiency = macs as f64 / (mults_total as f64 * cycles_per_frame);
        let ddr_utilization = self.ddr_bytes as f64 / (bpc * makespan as f64);

        SimReport {
            frames: self.frames,
            makespan,
            cycles_per_frame,
            fps,
            gops,
            dsp_efficiency,
            ddr_bytes: self.ddr_bytes,
            ddr_utilization,
            stages: self.stats,
            frame_done: self.frame_done,
            input_done: self.input_done,
        }
    }
}

/// Ready-queue discrete-event pipeline simulation at row-group granularity.
/// Per event: O(affected stages · log n).
pub(crate) fn simulate_pipeline(alloc: &Allocation, frames: usize) -> SimReport {
    run_ready_queue(SimState::new(alloc, frames), alloc)
}

/// Simulate `N` co-resident pipelines sharing one physical DDR port (the
/// multi-tenant validation pass of [`crate::shard`]).
///
/// The DDR model stays the fluid weighted-fair server documented on
/// [`SimState`], with the WFQ denominator widened to the union of *every*
/// tenant's streams: tenant `t`'s stage gets
/// `bpc_physical · (own_stream / Σ_all_tenants streams)` bytes/cycle. The
/// shares are static, so each tenant's event wheel runs independently
/// against its reduced rates — deterministic and order-independent, like
/// an AXI interconnect with per-requestor QoS weights that has converged.
///
/// `board` is the *physical* board (full DDR rate). Each allocation keeps
/// its own clock (`alloc.freq_hz`); sequential-group architectures fall
/// back to their analytic makespan as in [`simulate`].
///
/// Invariant (regression-tested): a tenant whose share works out to the
/// bandwidth its solo board offered — e.g. two identical tenants on a
/// board with doubled DSP/BRAM/DDR — reports a bit-identical schedule to
/// the solo run: the fluid shares make "half of twice the port" exactly
/// the original port.
pub(crate) fn simulate_multi(allocs: &[&Allocation], board: &Board, frames: usize) -> Vec<SimReport> {
    let shared: f64 = allocs.iter().map(|a| ddr_stream_demand(a)).sum();
    allocs
        .iter()
        .map(|a| match &a.groups {
            None => run_ready_queue(
                SimState::with_ddr(a, frames, board.ddr_bytes_per_sec, Some(shared)),
                a,
            ),
            Some(_) => simulate_sequential(a, frames),
        })
        .collect()
}

/// Like [`simulate_multi`], but with the port split **provisioned**:
/// tenant `i`'s streams collectively receive `shares[i]` of the physical
/// port (an AXI interconnect with fixed QoS weights), regardless of how
/// much the other tenants demand. This is the model the sharder's
/// validation pass uses, because Algorithm 2 allocated each tenant against
/// exactly that provisioned bandwidth — validating against the
/// demand-converged split of [`simulate_multi`] would measure a different
/// port division than the one the frontier was ranked on (a heavy tenant
/// would capture bandwidth its plan never promised it).
///
/// Internally: tenant `i`'s WFQ denominator becomes `own_demand /
/// shares[i]`, so its streams' shares sum to `shares[i]`. For equal
/// tenants with equal shares this coincides with [`simulate_multi`]
/// (bit-for-bit — division by an exact power of two preserves the
/// doubled-board identity).
pub(crate) fn simulate_multi_provisioned(
    allocs: &[&Allocation],
    shares: &[f64],
    board: &Board,
    frames: usize,
) -> Vec<SimReport> {
    assert_eq!(allocs.len(), shares.len(), "one port share per tenant");
    debug_assert!(shares.iter().all(|&s| s > 0.0 && s <= 1.0));
    allocs
        .iter()
        .zip(shares)
        .map(|(a, &share)| match &a.groups {
            None => {
                let denom = ddr_stream_demand(a) / share;
                run_ready_queue(
                    SimState::with_ddr(a, frames, board.ddr_bytes_per_sec, Some(denom)),
                    a,
                )
            }
            Some(_) => simulate_sequential(a, frames),
        })
        .collect()
}

/// The greedy list scheduler both public entry points run on.
fn run_ready_queue(mut st: SimState, alloc: &Allocation) -> SimReport {
    let n = st.n;

    // Min-heap of (start, stage) for currently-startable stages, with lazy
    // invalidation: `queued[i]` holds the start the heap believes; entries
    // that no longer match are discarded on pop.
    let mut queued: Vec<Option<u64>> = vec![None; n];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    for i in 0..n {
        if let Some(s) = st.start_of(i) {
            queued[i] = Some(s);
            heap.push(Reverse((s, i)));
        }
    }

    while st.done_groups < st.total_groups {
        let Some(Reverse((start, i))) = heap.pop() else {
            debug_assert!(
                false,
                "pipeline deadlock at {}/{}",
                st.done_groups, st.total_groups
            );
            break;
        };
        if queued[i] != Some(start) {
            continue; // stale entry
        }
        queued[i] = None;
        st.fire(i, start);
        // Only i−1 (space freed in i's buffer), i (engine/next group), and
        // i+1 (new input rows) can change eligibility — recompute those.
        for j in [i.wrapping_sub(1), i, i + 1] {
            if j >= n {
                continue;
            }
            let s = st.start_of(j);
            if queued[j] != s {
                queued[j] = s;
                if let Some(v) = s {
                    heap.push(Reverse((v, j)));
                }
            }
        }
    }

    st.report(alloc)
}

/// The seed's full-rescan scheduler — every iteration scans all stages for
/// the earliest startable one (O(total groups · stages)). Preserved as the
/// executable specification for [`simulate_pipeline`]; tests assert the
/// two produce identical reports.
pub(crate) fn simulate_pipeline_naive(alloc: &Allocation, frames: usize) -> SimReport {
    let mut st = SimState::new(alloc, frames);
    let n = st.n;

    while st.done_groups < st.total_groups {
        // Find the stage that can start its next group the earliest
        // (first-lowest-index wins ties, like the heap's lexicographic
        // (start, stage) order).
        let mut best: Option<(u64, usize)> = None;
        for i in 0..n {
            if let Some(start) = st.start_of(i) {
                if best.map_or(true, |(b, _)| start < b) {
                    best = Some((start, i));
                }
            }
        }
        let Some((start, i)) = best else {
            debug_assert!(
                false,
                "pipeline deadlock at {}/{}",
                st.done_groups, st.total_groups
            );
            break;
        };
        st.fire(i, start);
    }

    st.report(alloc)
}

// ---------------------------------------------------------------------------
// Time-multiplexed schedules: reconfiguration events between full-board runs
// ---------------------------------------------------------------------------

/// One sub-slice of a time-shared schedule period as the caller provisions
/// it — the executable half of the planner's
/// [`crate::shard::schedule::SliceSpec`].
#[derive(Debug, Clone)]
pub struct ScheduleSlice {
    /// Index into the `allocs` array of the tenant this sub-slice serves.
    /// A tenant may appear several times per period (interleaving).
    pub tenant: usize,
    /// Frames the planner admitted into this sub-slice.
    pub frames: usize,
    /// Provisioned sub-slice length in cycles (time quanta × quantum).
    pub slice_cycles: u64,
    /// Full partial-bitstream cost of swapping this tenant's region in, in
    /// cycles (0 when no swap happens: lone tenants, overlay plans, or a
    /// sub-slice whose cyclic predecessor serves the same tenant).
    pub reconfig_cycles: u64,
}

/// One tenant's sub-slice of a time-shared schedule period, as executed by
/// the schedule engine behind [`Simulate`].
#[derive(Debug, Clone)]
pub struct TimeshareSlice {
    /// Tenant this sub-slice serves (index into the `allocs` array).
    pub tenant: usize,
    /// Frames the schedule admitted into this slice.
    pub frames: usize,
    /// Provisioned slice length in cycles (time quanta × quantum).
    pub slice_cycles: u64,
    /// Full partial-bitstream cost of swapping this tenant's region in,
    /// in cycles, before any drain overlap is credited.
    pub reconfig_cycles: u64,
    /// Reconfiguration cycles hidden under the cyclic predecessor's drain
    /// tail (`min(reconfig, predecessor's makespan − input_done)`); the
    /// dead cycles actually charged are `reconfig_cycles − overlap_cycles`.
    /// Always 0 when the schedule runs without drain overlap.
    pub overlap_cycles: u64,
    /// Offset of this slice's start within the executed period, in cycles
    /// (the boundary where its charged window begins — reconfiguration
    /// first, then the batch).
    pub start_cycles: u64,
    /// DES makespan of the admitted batch (pipeline refill → drain — the
    /// batch starts from an empty pipeline and its last output marks the
    /// slice's useful end).
    pub makespan: u64,
    /// Cycles the slice ran past its provision
    /// (`charged reconfig + makespan − slice` when positive): the schedule
    /// stretches rather than dropping admitted frames, and the stretch
    /// lands in [`TimeshareReport::period_cycles`].
    pub overrun: u64,
    /// This sub-slice's contribution to its tenant's effective rate:
    /// `frames · f / period` (frames/second).
    pub fps: f64,
    /// The underlying single-pipeline DES report for the batch (`None`
    /// when the slice admitted zero frames).
    pub sim: Option<SimReport>,
}

/// One simulated period of a time-shared schedule (the schedule engine
/// behind [`Simulate`]; the serial PR-3 wrapper produces the same shape).
#[derive(Debug, Clone)]
pub struct TimeshareReport {
    /// Actual period in cycles:
    /// `Σ max(slice_i, charged_reconfig_i + makespan_i)`.
    pub period_cycles: u64,
    /// Executed-schedule accounting: charged reconfiguration plus
    /// intra-slice idle tails (`period − Σ makespan`). A batch's whole
    /// makespan — pipeline fill included — counts as busy here; this
    /// intentionally differs from the *analytic*
    /// `TemporalInfo::dead_frac`, which counts only steady-state frame
    /// beats as useful (refill is dead there).
    ///
    /// [`TemporalInfo::dead_frac`]: crate::shard::TemporalInfo::dead_frac
    pub dead_cycles: u64,
    /// `dead_cycles / period_cycles` (executed-schedule definition).
    pub dead_frac: f64,
    /// Effective frames/second per *tenant* (summed over all of a tenant's
    /// sub-slices), indexed like the `allocs` array.
    pub tenant_fps: Vec<f64>,
    /// Measured worst-case frame sojourn per tenant, in cycles: the
    /// longest a frame can wait from arriving (just missing a sub-slice's
    /// cutoff at its start boundary) until its batch completes in the
    /// *next* sub-slice — `max over consecutive sub-slice pairs of
    /// (start gap + charged reconfig + batch makespan)`. Comparable to the
    /// analytic `TemporalInfo::latency_cycles` bound, which uses the
    /// calibrated over-approximation of the same quantities.
    ///
    /// [`TemporalInfo::latency_cycles`]: crate::shard::TemporalInfo::latency_cycles
    pub worst_sojourn: Vec<u64>,
    /// Per-sub-slice execution record, in schedule order.
    pub slices: Vec<TimeshareSlice>,
}

/// Execute one period of a time-multiplexed schedule: for each sub-slice
/// in sequence, *drain* (the previous slice ended with its pipeline
/// empty), *reconfigure* ([`ScheduleSlice::reconfig_cycles`] dead cycles —
/// the partial bitstream swap of
/// [`crate::shard::schedule::ReconfigModel`]), then *refill* — run the
/// tenant's full-board pipeline for its admitted frames through the
/// ordinary event-wheel DES, pipeline fill and drain included in the
/// measured makespan.
///
/// With `drain_overlap`, the incoming tenant's partial bitstream streams
/// through the configuration port *while the outgoing tenant's pipeline
/// drains*: once the predecessor's input-side stages go idle
/// ([`SimReport::input_done`]) their region can be rewritten concurrently
/// with the remaining stages' drain, so only
/// `max(0, reconfig − predecessor's drain)` is charged as dead time. The
/// predecessor is cyclic (the first sub-slice overlaps the last one's
/// drain — the schedule is period-periodic). Single-stage pipelines have
/// zero drain (`input_done == frame_done`), so zero-depth tenants
/// degenerate to the serial cost exactly; and since the credit is never
/// negative, a drain-overlapped period is **never longer** than the
/// serial one (property-tested).
///
/// Because every slice starts from a drained pipeline, no simulation state
/// crosses slice boundaries: batches are simulated independently and one
/// simulated period is the whole steady state. Admission control (how many
/// frames fit a slice) belongs to the planner
/// ([`crate::shard::schedule`]); this function *executes* the planned
/// batches and reports where reality diverged — a slice whose charged
/// `reconfig + makespan` exceeds its provision stretches the period
/// (`overrun`) instead of dropping frames, so a mis-calibrated plan shows
/// up as `fps` below the analytic schedule rather than as silent loss.
///
/// Effective per-tenant fps is `Σ frames / period` — reconfiguration dead
/// time and idle tails are charged against every tenant's denominator,
/// which is exactly the amortization trade the temporal sharder searches
/// over.
pub(crate) fn simulate_schedule(
    allocs: &[&Allocation],
    seq: &[ScheduleSlice],
    drain_overlap: bool,
) -> TimeshareReport {
    assert!(!allocs.is_empty(), "time-share needs at least one tenant");
    assert!(!seq.is_empty(), "time-share needs at least one slice");
    assert!(
        seq.iter().all(|s| s.tenant < allocs.len()),
        "slice tenant index out of range"
    );
    let freq = allocs[0].freq_hz;
    debug_assert!(
        allocs.iter().all(|a| a.freq_hz == freq),
        "co-scheduled tenants share one board clock"
    );
    let m = seq.len();

    // Pass 1: simulate every batch (slices are independent — each starts
    // from a drained pipeline) and record its drain tail.
    let mut sims: Vec<Option<SimReport>> = Vec::with_capacity(m);
    let mut drains: Vec<u64> = Vec::with_capacity(m);
    for s in seq {
        let sim = (s.frames > 0).then(|| simulate(allocs[s.tenant], s.frames));
        let drain = sim
            .as_ref()
            .map_or(0, |r| r.makespan - r.input_done[r.input_done.len() - 1]);
        sims.push(sim);
        drains.push(drain);
    }

    // Pass 2: timing arithmetic — overlap credit, charged windows, starts.
    let mut slices = Vec::with_capacity(m);
    let mut busy = 0u64;
    let mut period = 0u64;
    for (j, s) in seq.iter().enumerate() {
        let makespan = sims[j].as_ref().map_or(0, |r| r.makespan);
        let overlap = if drain_overlap {
            s.reconfig_cycles.min(drains[(j + m - 1) % m])
        } else {
            0
        };
        let used = (s.reconfig_cycles - overlap) + makespan;
        slices.push(TimeshareSlice {
            tenant: s.tenant,
            frames: s.frames,
            slice_cycles: s.slice_cycles,
            reconfig_cycles: s.reconfig_cycles,
            overlap_cycles: overlap,
            start_cycles: period, // filled as the running window sum
            makespan,
            overrun: used.saturating_sub(s.slice_cycles),
            fps: 0.0,
            sim: None,
        });
        period += s.slice_cycles.max(used);
        busy += makespan;
    }
    let dead = period - busy;
    let mut tenant_fps = vec![0.0; allocs.len()];
    for s in &mut slices {
        s.fps = s.frames as f64 * freq / period.max(1) as f64;
        tenant_fps[s.tenant] += s.fps;
    }

    // Measured worst-case sojourn per tenant: a frame that just misses a
    // sub-slice's start boundary waits until the next one starts, pays its
    // charged reconfiguration, and completes within that batch's makespan.
    let mut worst_sojourn = vec![0u64; allocs.len()];
    for t in 0..allocs.len() {
        let js: Vec<usize> = (0..m).filter(|&j| slices[j].tenant == t).collect();
        for (a, &j_from) in js.iter().enumerate() {
            let j_to = js[(a + 1) % js.len()];
            let gap = if slices[j_to].start_cycles > slices[j_from].start_cycles {
                slices[j_to].start_cycles - slices[j_from].start_cycles
            } else {
                period - slices[j_from].start_cycles + slices[j_to].start_cycles
            };
            let served = slices[j_to].reconfig_cycles - slices[j_to].overlap_cycles
                + slices[j_to].makespan;
            worst_sojourn[t] = worst_sojourn[t].max(gap + served);
        }
    }

    // Hand the batch reports back (kept out of pass 2 to borrow simply).
    for (s, sim) in slices.iter_mut().zip(sims) {
        s.sim = sim;
    }
    TimeshareReport {
        period_cycles: period,
        dead_cycles: dead,
        dead_frac: dead as f64 / period.max(1) as f64,
        tenant_fps,
        worst_sojourn,
        slices,
    }
}

/// Execute one period of a one-slice-per-tenant schedule with **serial**
/// reconfiguration — the PR-3 cost model, kept as the baseline the
/// drain-overlap property tests compare against. Sub-slice `i` serves
/// tenant `i` with `frames[i]` frames in a `slice_cycles[i]` provision
/// after `reconfig_cycles[i]` dead cycles. See [`simulate_schedule`] for
/// the general (interleaved, drain-overlapped) form.
pub(crate) fn simulate_timeshared(
    allocs: &[&Allocation],
    frames: &[usize],
    slice_cycles: &[u64],
    reconfig_cycles: &[u64],
) -> TimeshareReport {
    assert_eq!(allocs.len(), frames.len(), "one frame budget per tenant");
    assert_eq!(allocs.len(), slice_cycles.len(), "one slice per tenant");
    assert_eq!(allocs.len(), reconfig_cycles.len(), "one reconfig cost per tenant");
    let seq: Vec<ScheduleSlice> = (0..allocs.len())
        .map(|i| ScheduleSlice {
            tenant: i,
            frames: frames[i],
            slice_cycles: slice_cycles[i],
            reconfig_cycles: reconfig_cycles[i],
        })
        .collect();
    simulate_schedule(allocs, &seq, false)
}

/// One tenant's replayed request stream from [`engines::replay_arrivals`]:
/// closed-loop arrival injection against an executed schedule period.
#[derive(Debug, Clone, Default)]
pub struct ReplayTenant {
    /// Sojourn (completion − arrival) per admitted request, in cycles,
    /// in admission order.
    pub sojourns: Vec<u64>,
    /// Arrivals refused because the tenant's queue already held its
    /// capacity of waiting requests.
    pub rejected: u64,
}

/// Replay per-tenant arrival streams against an **executed** schedule
/// period — closed-loop arrival injection into the DES. The executed
/// [`TimeshareReport`] timeline (slice start offsets, charged
/// reconfiguration windows, per-batch [`SimReport::frame_done`] offsets)
/// is extended periodically; each tenant's queue admits at most
/// `capacity[t]` waiting requests (`0` = unbounded) and drains only at
/// that tenant's sub-slice starts, serving at most the slice's admitted
/// frame count per occurrence — the k-th request of an occurrence's
/// batch completes at the executed `frame_done[k]` offset after the
/// charged window. `arrivals[t]` must be sorted ascending (absolute
/// cycles). The independent model in [`crate::ingest::serve_trace`]
/// computes the same quantities from the *planned* timeline; the
/// acceptance tests pin the two against each other and against the
/// analytic `TemporalInfo::latency_cycles` bound.
///
/// **Event-skip:** occurrence starts
/// `start(k) = (k / L)·period + occ[k mod L].start_cycles` are
/// non-decreasing in `k` (slice start offsets are prefix sums within a
/// period, each `< period`). When the queue is empty and the next arrival
/// lies beyond the current occurrence's start, every occurrence strictly
/// before the arrival admits nothing (arrivals are sorted ascending) and
/// serves nothing (empty queue) — so the wheel leaps `k` directly to the
/// first occurrence whose start covers the arrival instead of beating
/// through the idle window one occurrence at a time. The stepping walk is
/// kept as the executable spec (`engines::replay_arrivals_stepping`) and
/// the equivalence suite pins the two byte-identical.
///
/// [`TemporalInfo::latency_cycles`]: crate::shard::TemporalInfo::latency_cycles
pub(crate) fn replay_arrivals(
    report: &TimeshareReport,
    arrivals: &[Vec<u64>],
    capacity: &[usize],
) -> Vec<ReplayTenant> {
    replay_arrivals_impl(report, arrivals, capacity, true).0
}

/// Shared walker behind [`replay_arrivals`]: `skip` selects the
/// event-skipping wheel or the stepping reference; the second return is
/// the number of occurrence visits (the wheel's iteration count), which
/// the engagement tests use to prove the skip actually fires.
fn replay_arrivals_impl(
    report: &TimeshareReport,
    arrivals: &[Vec<u64>],
    capacity: &[usize],
    skip: bool,
) -> (Vec<ReplayTenant>, u64) {
    assert_eq!(arrivals.len(), capacity.len(), "one capacity per tenant");
    let period = report.period_cycles;
    assert!(period > 0, "replay needs an executed period");
    let mut visits = 0u64;
    let mut out = Vec::with_capacity(arrivals.len());
    for (t, arr) in arrivals.iter().enumerate() {
        // This tenant's serving occurrences within one period.
        let occ: Vec<&TimeshareSlice> = report
            .slices
            .iter()
            .filter(|s| s.tenant == t && s.frames > 0)
            .collect();
        let mut rep = ReplayTenant::default();
        if arr.is_empty() {
            out.push(rep);
            continue;
        }
        assert!(
            !occ.is_empty(),
            "replay: tenant {t} has arrivals but the schedule admits no frames for it"
        );
        debug_assert!(arr.windows(2).all(|w| w[0] <= w[1]), "arrivals must be sorted");
        let cap = capacity[t];
        let mut next = 0; // index of the first unprocessed arrival
        let mut queue: std::collections::VecDeque<u64> = std::collections::VecDeque::new();
        // Walk occurrences in time order (periodic extension) until every
        // arrival is admitted-or-rejected and the queue has drained.
        let mut k = 0u64;
        while next < arr.len() || !queue.is_empty() {
            if skip && queue.is_empty() {
                // Event-skip: with an empty queue nothing can be served
                // before the next arrival, and every occurrence starting
                // strictly before it admits nothing (arrivals are sorted),
                // so leap to the first occurrence whose start covers it.
                let target = arr[next];
                let l = occ.len() as u64;
                let p = target / period; // period index holding the target
                let k_target = match occ
                    .iter()
                    .position(|s| p * period + s.start_cycles >= target)
                {
                    Some(j) => p * l + j as u64,
                    // Every occurrence of period `p` starts too early; the
                    // first of period `p+1` starts at ≥ (p+1)·period > target.
                    None => (p + 1) * l,
                };
                k = k.max(k_target);
            }
            visits += 1;
            let s = occ[(k as usize) % occ.len()];
            let start = (k / occ.len() as u64) * period + s.start_cycles;
            // Admit arrivals up to (and at) this occurrence's start; the
            // waiting-depth bound is exact because the queue only drains
            // at occurrence starts.
            while next < arr.len() && arr[next] <= start {
                if cap == 0 || queue.len() < cap {
                    queue.push_back(arr[next]);
                } else {
                    rep.rejected += 1;
                }
                next += 1;
            }
            // Drain up to the slice's admitted batch: request j of the
            // batch completes frame_done[j] after the charged window.
            let charged = s.reconfig_cycles - s.overlap_cycles;
            let done = s.sim.as_ref().map(|r| r.frame_done.as_slice()).unwrap_or(&[]);
            let served = s.frames.min(queue.len()).min(done.len());
            for j in 0..served {
                let a = queue.pop_front().expect("served <= queue depth");
                rep.sojourns.push(start + charged + done[j] - a);
            }
            k += 1;
        }
        out.push(rep);
    }
    (out, visits)
}

// ---------------------------------------------------------------------------
// Sequential-group architectures: analytic makespan
// ---------------------------------------------------------------------------

fn simulate_sequential(alloc: &Allocation, frames: usize) -> SimReport {
    let r: AllocReport = alloc.evaluate();
    let makespan = r.t_frame_cycles * frames as u64;
    let stats = alloc
        .stages
        .iter()
        .zip(alloc.stage_cycles())
        .map(|(s, c)| StageStats {
            busy_cycles: c * frames as u64,
            groups_done: s.figures.groups_per_frame * frames as u64,
            ..Default::default()
        })
        .collect();
    let weight_bytes: u64 = alloc
        .stages
        .iter()
        .map(|s| s.figures.weight_bytes_per_frame())
        .sum();
    SimReport {
        frames,
        makespan,
        cycles_per_frame: r.t_frame_cycles as f64,
        fps: r.fps,
        gops: r.gops,
        dsp_efficiency: r.dsp_efficiency,
        ddr_bytes: weight_bytes * frames as u64,
        ddr_utilization: (weight_bytes as f64 * r.fps) / alloc.board.ddr_bytes_per_sec,
        stages: stats,
        frame_done: (1..=frames as u64).map(|f| r.t_frame_cycles * f).collect(),
        // Sequential groups never overlap frames: the input side finishes
        // with the frame itself, so there is no drain window to overlap.
        input_done: (1..=frames as u64).map(|f| r.t_frame_cycles * f).collect(),
    }
}

// ---------------------------------------------------------------------------
// Plan execution: the one public multi-tenant entry point
// ---------------------------------------------------------------------------

/// Per-tenant DES measurements for one executed
/// [`crate::plan::DeploymentPlan`].
#[derive(Debug, Clone)]
pub struct PlanSimReport {
    /// One report per tenant, in plan tenant order. Temporal and overlay
    /// plans report the effective over-the-period view (fps includes
    /// reconfiguration dead time and idle tails); spatial plans report
    /// each tenant's shared-port pipeline run.
    pub tenants: Vec<SimReport>,
}

impl PlanSimReport {
    /// Simulated effective fps per tenant (plan tenant order).
    pub fn tenant_fps(&self) -> Vec<f64> {
        self.tenants.iter().map(|r| r.fps).collect()
    }
}

/// The one simulation entry point of the plan-centric API: anything that
/// can execute a [`crate::plan::DeploymentPlan`] and report per-tenant
/// measurements. [`Simulator`] is the cycle-accurate DES implementation;
/// the trait is the seam for coarser or hardware-in-the-loop validators.
pub trait Simulate {
    /// Execute `plan` end to end: rehydrate every tenant's allocation
    /// ([`crate::plan::DeploymentPlan::instantiate`]), then run the
    /// regime-matched engine — the shared-port multi-pipeline wheel at
    /// the plan's provisioned DDR shares for spatial plans, one full
    /// drain-overlapped schedule period for temporal and overlay plans.
    fn simulate(&self, plan: &crate::plan::DeploymentPlan) -> crate::Result<PlanSimReport>;
}

/// The cycle-accurate [`Simulate`] implementation, backed by the same DES
/// engines [`crate::shard::Sharder::search`]'s validation pass runs — so
/// a plan loaded from JSON re-simulates **bit-identically** to the
/// in-process search (acceptance-pinned in `tests/plan_roundtrip.rs`).
#[derive(Debug, Clone)]
pub struct Simulator {
    /// Frames simulated per tenant for resident (spatial / solo) plans.
    /// Temporal and overlay plans execute exactly one schedule period
    /// regardless. Default 4 (matches `flexipipe simulate`).
    pub frames: usize,
}

impl Default for Simulator {
    fn default() -> Self {
        Simulator { frames: 4 }
    }
}

impl Simulate for Simulator {
    fn simulate(&self, plan: &crate::plan::DeploymentPlan) -> crate::Result<PlanSimReport> {
        let allocs = plan.instantiate()?;
        let refs: Vec<&Allocation> = allocs.iter().collect();
        let shares: Vec<f64> = plan.tenants.iter().map(|t| t.ddr_share).collect();
        let tenants = crate::shard::confirm_plan(
            &refs,
            &shares,
            &plan.board,
            &plan.regime,
            self.frames.max(1),
        );
        Ok(PlanSimReport { tenants })
    }
}

impl Simulator {
    /// Execute `plan` under a seeded [`crate::fault::FaultPlan`] and
    /// report per-tenant fps/sojourn with the faults injected into the
    /// DES engines (see the fault-semantics table in [`crate::fault`]):
    ///
    /// - the DDR brownout factor slows the port every pipeline streams
    ///   against,
    /// - reconfiguration overruns/failures rewrite the temporal
    ///   schedule's swap costs (stretching the period — frames are never
    ///   dropped),
    /// - a board loss truncates service at
    ///   [`crate::fault::BoardLoss::at_s`]: effective fps is the degraded
    ///   rate scaled by the fraction of the executed horizon served.
    ///
    /// Every stochastic choice derives from the fault plan's seed, so the
    /// report is byte-identical across runs (CI diffs two invocations of
    /// `flexipipe simulate --plan … --faults …`).
    pub fn simulate_faulted(
        &self,
        plan: &crate::plan::DeploymentPlan,
        faults: &crate::fault::FaultPlan,
    ) -> crate::Result<crate::fault::FaultSimReport> {
        use crate::fault::{FaultSimReport, FaultTenantReport};
        use crate::shard::Regime;
        faults.validate()?;
        let frames = self.frames.max(1);
        let freq = plan.board.freq_hz;
        let allocs = plan.instantiate()?;
        let shares: Vec<f64> = plan.tenants.iter().map(|t| t.ddr_share).collect();

        // Healthy baseline on the rated board — the reference the
        // degradation is measured against.
        let refs: Vec<&Allocation> = allocs.iter().collect();
        let healthy =
            crate::shard::confirm_plan(&refs, &shares, &plan.board, &plan.regime, frames);

        // The running fabric under the brownout: the committed pipelines
        // keep their resources but stream against the degraded port.
        let dboard = faults.degraded_port(&plan.board);
        let mut degraded = allocs.clone();
        for a in &mut degraded {
            a.board.ddr_bytes_per_sec = dboard.ddr_bytes_per_sec;
        }
        let drefs: Vec<&Allocation> = degraded.iter().collect();

        // Per-tenant (fps, sojourn) of the faulted fabric plus the
        // executed horizon the loss instant is interpreted against.
        let (deg_fps, sojourn_s, horizon_s) = match &plan.regime {
            Regime::Temporal(info) if info.period_cycles > 0 => {
                let seq = faults.degraded_schedule(&info.schedule_slices());
                let ts = simulate_schedule(&drefs, &seq, true);
                let soj: Vec<f64> = ts.worst_sojourn.iter().map(|&c| c as f64 / freq).collect();
                (ts.tenant_fps, soj, ts.period_cycles as f64 / freq)
            }
            regime => {
                let sh: Vec<f64> = match regime {
                    Regime::Spatial => shares.clone(),
                    // Degenerate lone-tenant temporal: continuous solo run.
                    Regime::Temporal(_) => vec![1.0],
                };
                let reports = simulate_multi_provisioned(&drefs, &sh, &dboard, frames);
                let fps: Vec<f64> = reports.iter().map(|r| r.fps).collect();
                let soj: Vec<f64> = reports
                    .iter()
                    .map(|r| {
                        r.frame_done.first().copied().unwrap_or(r.makespan) as f64 / freq
                    })
                    .collect();
                let horizon =
                    reports.iter().map(|r| r.makespan).max().unwrap_or(0) as f64 / freq;
                (fps, soj, horizon)
            }
        };

        let served_frac = match &faults.board_loss {
            Some(l) if horizon_s > 0.0 => (l.at_s / horizon_s).min(1.0),
            _ => 1.0,
        };
        let tenants = plan
            .tenants
            .iter()
            .enumerate()
            .map(|(t, pt)| FaultTenantReport {
                net: pt.net.name.clone(),
                healthy_fps: healthy[t].fps,
                degraded_fps: deg_fps[t],
                fps: deg_fps[t] * served_frac,
                sojourn_s: sojourn_s[t],
                served_frac,
            })
            .collect();
        Ok(FaultSimReport {
            seed: faults.seed,
            regime: plan.regime.label().to_string(),
            horizon_s,
            tenants,
        })
    }

    /// Execute a whole [`crate::fleet::FleetPlan`]: run every board's
    /// pinned engine once (the same [`Simulate::simulate`] path a
    /// single-board plan takes, so each board re-simulates
    /// bit-identically to its in-process search), then merge per-tenant
    /// reports through the routing table — a tenant's fleet fps is the
    /// **sum** of its replicas' simulated rates, each route's reported
    /// weight is its simulated share of that sum, and the worst-case
    /// sojourn is the **max** over replicas of the hosting plan's
    /// analytic bound (`None` when any hosting plan lacks one).
    pub fn simulate_fleet(
        &self,
        plan: &crate::fleet::FleetPlan,
    ) -> crate::Result<crate::fleet::FleetSimReport> {
        use crate::fleet::{FleetRouteSim, FleetSimReport, FleetTenantSim};
        plan.validate()?;
        let reports: Vec<PlanSimReport> = plan
            .boards
            .iter()
            .map(|p| self.simulate(&p.plan))
            .collect::<crate::Result<_>>()?;
        let mut tenants = Vec::with_capacity(plan.routing.tenants.len());
        for tr in &plan.routing.tenants {
            let mut routes = Vec::with_capacity(tr.routes.len());
            let mut total = 0.0f64;
            let mut worst: Option<f64> = Some(0.0);
            for r in &tr.routes {
                let bi = plan
                    .boards
                    .iter()
                    .position(|p| p.id == r.board)
                    .expect("validate() pinned every route to a known board");
                let pl = &plan.boards[bi].plan;
                let ti = pl
                    .tenants
                    .iter()
                    .position(|t| t.net.name == tr.net)
                    .expect("validate() pinned every route to a hosting plan");
                let fps = reports[bi].tenants[ti].fps;
                total += fps;
                worst = match (worst, pl.worst_sojourn_cycles()) {
                    (Some(w), Some(cycles)) => {
                        Some(w.max(cycles[ti] as f64 / pl.board.freq_hz))
                    }
                    _ => None,
                };
                routes.push(FleetRouteSim {
                    board: r.board.clone(),
                    fps,
                    weight: 0.0,
                });
            }
            anyhow::ensure!(
                total > 0.0,
                "tenant '{}': simulated fleet fps is zero across all routes",
                tr.net
            );
            for r in &mut routes {
                r.weight = r.fps / total;
            }
            tenants.push(FleetTenantSim {
                net: tr.net.clone(),
                fps: total,
                worst_sojourn_s: worst,
                routes,
            });
        }
        Ok(FleetSimReport { tenants })
    }
}

/// Raw DES engines behind [`simulate`] and [`Simulate`], re-exported
/// **only** for the crate's own property/golden test suites and benches.
/// Hidden from rustdoc and carrying no stability promise — applications
/// use [`simulate`] for one allocation and [`Simulate`] for a whole
/// deployment plan.
#[doc(hidden)]
pub mod engines {
    use super::*;

    /// The ready-queue pipeline DES (see `sim::simulate_pipeline`).
    pub fn simulate_pipeline(alloc: &Allocation, frames: usize) -> SimReport {
        super::simulate_pipeline(alloc, frames)
    }

    /// The seed's full-rescan scheduler — the executable spec the
    /// equivalence suites pin the fast path against.
    pub fn simulate_pipeline_naive(alloc: &Allocation, frames: usize) -> SimReport {
        super::simulate_pipeline_naive(alloc, frames)
    }

    /// Demand-converged shared-port multi-pipeline DES.
    pub fn simulate_multi(
        allocs: &[&Allocation],
        board: &Board,
        frames: usize,
    ) -> Vec<SimReport> {
        super::simulate_multi(allocs, board, frames)
    }

    /// Provisioned-share shared-port multi-pipeline DES.
    pub fn simulate_multi_provisioned(
        allocs: &[&Allocation],
        shares: &[f64],
        board: &Board,
        frames: usize,
    ) -> Vec<SimReport> {
        super::simulate_multi_provisioned(allocs, shares, board, frames)
    }

    /// General (interleaved, optionally drain-overlapped) schedule
    /// executor.
    pub fn simulate_schedule(
        allocs: &[&Allocation],
        seq: &[ScheduleSlice],
        drain_overlap: bool,
    ) -> TimeshareReport {
        super::simulate_schedule(allocs, seq, drain_overlap)
    }

    /// Closed-loop arrival replay against an executed schedule period
    /// (see `sim::replay_arrivals`).
    pub fn replay_arrivals(
        report: &TimeshareReport,
        arrivals: &[Vec<u64>],
        capacity: &[usize],
    ) -> Vec<ReplayTenant> {
        super::replay_arrivals(report, arrivals, capacity)
    }

    /// The stepping replay wheel — the executable spec the event-skipping
    /// [`replay_arrivals`] is property-pinned byte-identical to. Returns
    /// the per-tenant reports plus the occurrence-visit count.
    pub fn replay_arrivals_stepping(
        report: &TimeshareReport,
        arrivals: &[Vec<u64>],
        capacity: &[usize],
    ) -> (Vec<ReplayTenant>, u64) {
        super::replay_arrivals_impl(report, arrivals, capacity, false)
    }

    /// The event-skipping replay wheel with its occurrence-visit count
    /// exposed, so engagement tests can prove the skip fires (fewer
    /// visits than [`replay_arrivals_stepping`] on sparse arrivals).
    pub fn replay_arrivals_counted(
        report: &TimeshareReport,
        arrivals: &[Vec<u64>],
        capacity: &[usize],
    ) -> (Vec<ReplayTenant>, u64) {
        super::replay_arrivals_impl(report, arrivals, capacity, true)
    }

    /// Serial one-slice-per-tenant schedule executor (the PR-3 baseline).
    pub fn simulate_timeshared(
        allocs: &[&Allocation],
        frames: &[usize],
        slice_cycles: &[u64],
        reconfig_cycles: &[u64],
    ) -> TimeshareReport {
        super::simulate_timeshared(allocs, frames, slice_cycles, reconfig_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flex::FlexAllocator;
    use crate::alloc::Allocator;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;
    use crate::quant::QuantMode;

    #[test]
    fn sim_matches_closed_form_on_balanced_pipeline() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::tinycnn(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let cf = alloc.evaluate();
        let sim = simulate(&alloc, 6);
        let ratio = sim.cycles_per_frame / cf.t_frame_cycles as f64;
        assert!(
            (0.9..1.7).contains(&ratio),
            "sim {:.0} vs closed-form {} (ratio {ratio:.2})",
            sim.cycles_per_frame,
            cf.t_frame_cycles
        );
    }

    #[test]
    fn sim_efficiency_near_closed_form_on_vgg16() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
            .unwrap();
        let sim = simulate(&alloc, 3);
        let cf = alloc.evaluate();
        assert!(
            (sim.dsp_efficiency - cf.dsp_efficiency).abs() < 0.15,
            "sim {:.3} vs cf {:.3}",
            sim.dsp_efficiency,
            cf.dsp_efficiency
        );
    }

    #[test]
    fn event_wheel_matches_naive_scheduler() {
        for (net, frames) in [(zoo::tinycnn(), 5), (zoo::lenet(), 3), (zoo::vgg_micro(), 4)] {
            let alloc = FlexAllocator::default()
                .allocate(&net, &zc706(), QuantMode::W8A8)
                .unwrap();
            let fast = simulate_pipeline(&alloc, frames);
            let slow = simulate_pipeline_naive(&alloc, frames);
            assert_eq!(fast.makespan, slow.makespan, "{}", net.name);
            assert_eq!(
                fast.cycles_per_frame.to_bits(),
                slow.cycles_per_frame.to_bits(),
                "{}",
                net.name
            );
            assert_eq!(fast.ddr_bytes, slow.ddr_bytes);
            assert_eq!(fast.stages, slow.stages, "{}", net.name);
        }
    }

    #[test]
    fn starved_bandwidth_shows_weight_stalls() {
        // A board with 100x less DDR bandwidth must stall on weights.
        let mut starved = zc706();
        starved.ddr_bytes_per_sec /= 100.0;
        let alloc = FlexAllocator {
            max_k_steps: 0, // disable Alg.2 so the stall is visible
            ..Default::default()
        }
        .allocate(&zoo::vgg16(), &starved, QuantMode::W16A16)
        .unwrap();
        let sim = simulate(&alloc, 2);
        let total_wstall: u64 = sim.stages.iter().map(|s| s.stall_weights).sum();
        assert!(total_wstall > 0, "expected weight stalls on starved DDR");
    }

    #[test]
    fn multi_with_one_tenant_matches_single() {
        // The widened WFQ denominator over a single tenant's own streams is
        // the single-pipeline denominator: schedules must be bit-identical.
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 3);
        let multi = simulate_multi(&[&alloc], &zc706(), 3);
        assert_eq!(multi.len(), 1);
        assert_eq!(multi[0].makespan, solo.makespan);
        assert_eq!(
            multi[0].cycles_per_frame.to_bits(),
            solo.cycles_per_frame.to_bits()
        );
        assert_eq!(multi[0].stages, solo.stages);
    }

    #[test]
    fn sharing_a_starved_port_costs_weight_stalls() {
        // Two co-resident pipelines on one starved port: each stream's WFQ
        // share halves, so weight-service times grow and total weight
        // stalls must strictly exceed the solo run's.
        let mut starved = zc706();
        starved.ddr_bytes_per_sec /= 100.0;
        let alloc = FlexAllocator {
            max_k_steps: 0, // disable Alg.2 so the stall is visible
            ..Default::default()
        }
        .allocate(&zoo::vgg16(), &starved, QuantMode::W16A16)
        .unwrap();
        let solo = simulate(&alloc, 2);
        let solo_stalls: u64 = solo.stages.iter().map(|s| s.stall_weights).sum();
        assert!(solo_stalls > 0);
        let multi = simulate_multi(&[&alloc, &alloc], &starved, 2);
        for m in &multi {
            assert!(m.makespan >= solo.makespan, "sharing a port can never speed a tenant up");
            let stalls: u64 = m.stages.iter().map(|s| s.stall_weights).sum();
            assert!(
                stalls > solo_stalls,
                "halved shares must deepen weight stalls ({stalls} vs {solo_stalls})"
            );
        }
    }

    #[test]
    fn more_frames_amortize_fill() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zedboard(), QuantMode::W8A8)
            .unwrap();
        let s2 = simulate(&alloc, 2);
        let s8 = simulate(&alloc, 8);
        assert!(
            s8.cycles_per_frame <= s2.cycles_per_frame * 1.05,
            "per-frame cost should not grow with frames: {} vs {}",
            s8.cycles_per_frame,
            s2.cycles_per_frame
        );
    }

    #[test]
    fn frame_done_has_prefix_property() {
        // frame_done[n-1] of a long run must equal the makespan of an
        // n-frame run: frames never wait on later frames. The time-shared
        // scheduler's calibration is built on this.
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg_micro(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let long = simulate(&alloc, 6);
        assert_eq!(long.frame_done.len(), 6);
        assert_eq!(*long.frame_done.last().unwrap(), long.makespan);
        for n in 1..=6 {
            let short = simulate(&alloc, n);
            assert_eq!(
                short.makespan,
                long.frame_done[n - 1],
                "prefix property broken at n={n}"
            );
            assert_eq!(&short.frame_done[..], &long.frame_done[..n]);
        }
        // Completion times are nondecreasing.
        assert!(long.frame_done.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn input_done_prefix_property_and_drain_tail() {
        // input_done mirrors frame_done's prefix property (the first
        // stage's schedule never depends on later frames), never finishes
        // after the frame itself, and a multi-stage pipeline has a real
        // drain tail for the drain-overlapped reconfiguration to hide
        // bitstream streaming under.
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg_micro(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let long = simulate(&alloc, 6);
        assert_eq!(long.input_done.len(), 6);
        for n in 1..=6 {
            let short = simulate(&alloc, n);
            assert_eq!(
                &short.input_done[..],
                &long.input_done[..n],
                "input_done prefix property broken at n={n}"
            );
        }
        for (i, (&inp, &done)) in long.input_done.iter().zip(&long.frame_done).enumerate() {
            assert!(inp > 0, "frame {i} input side never completed");
            assert!(inp <= done, "frame {i}: input side finished after the frame");
        }
        assert!(long.input_done.windows(2).all(|w| w[0] <= w[1]));
        assert!(
            long.makespan > *long.input_done.last().unwrap(),
            "multi-stage pipeline must have a drain tail"
        );
    }

    #[test]
    fn single_stage_pipeline_has_zero_drain() {
        // A 1-layer pipeline's first stage is its last: input_done equals
        // frame_done, so the drain window is zero and drain-overlapped
        // schedules degenerate to the serial reconfiguration cost.
        use crate::model::{conv, Network};
        let net = Network {
            name: "conv1".into(),
            input: (8, 32, 32),
            layers: vec![conv(8, 8, 32, 32, 3, 1, 1)],
        };
        let alloc = FlexAllocator::default()
            .allocate(&net, &zc706(), QuantMode::W8A8)
            .unwrap();
        assert_eq!(alloc.stages.len(), 1);
        let s = simulate(&alloc, 3);
        assert_eq!(s.input_done, s.frame_done);
    }

    #[test]
    fn schedule_without_overlap_matches_serial_wrapper() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 2);
        let slice = solo.makespan + 5_000;
        let seq: Vec<ScheduleSlice> = (0..2)
            .map(|t| ScheduleSlice {
                tenant: t,
                frames: 2,
                slice_cycles: slice,
                reconfig_cycles: 3_000,
            })
            .collect();
        let a = simulate_schedule(&[&alloc, &alloc], &seq, false);
        let b = simulate_timeshared(&[&alloc, &alloc], &[2, 2], &[slice, slice], &[3_000, 3_000]);
        assert_eq!(a.period_cycles, b.period_cycles);
        assert_eq!(a.dead_cycles, b.dead_cycles);
        assert_eq!(a.tenant_fps, b.tenant_fps);
        assert_eq!(a.worst_sojourn, b.worst_sojourn);
        // Slice start offsets are the charged-window prefix sums, and the
        // measured sojourn is gap + charged reconfig + makespan (here the
        // gap is the whole period: one slice per tenant).
        assert_eq!(a.slices[0].start_cycles, 0);
        assert_eq!(a.slices[1].start_cycles, slice);
        for (t, s) in a.slices.iter().enumerate() {
            assert_eq!(s.overlap_cycles, 0, "no overlap requested");
            assert_eq!(
                a.worst_sojourn[t],
                a.period_cycles + s.reconfig_cycles + s.makespan
            );
        }
    }

    #[test]
    fn timeshare_accounting_is_conserved() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 3);
        let slice = solo.makespan + 10_000; // roomy provision
        let rc = 5_000u64;
        let ts = simulate_timeshared(&[&alloc, &alloc], &[3, 3], &[slice, slice], &[rc, rc]);
        assert_eq!(ts.slices.len(), 2);
        // Each slice executes the same drained-pipeline batch as a solo run.
        for s in &ts.slices {
            assert_eq!(s.makespan, solo.makespan);
            assert_eq!(s.overrun, 0, "provision covers reconfig + makespan");
        }
        // Conservation: period = Σ slices, dead = period − Σ makespans.
        assert_eq!(ts.period_cycles, 2 * slice);
        assert_eq!(ts.dead_cycles, ts.period_cycles - 2 * solo.makespan);
        assert!((ts.dead_frac - ts.dead_cycles as f64 / ts.period_cycles as f64).abs() < 1e-12);
        // Identical tenants with identical slices: identical effective fps,
        // and exactly frames·f/period.
        let want = 3.0 * alloc.freq_hz / ts.period_cycles as f64;
        assert_eq!(ts.slices[0].fps.to_bits(), ts.slices[1].fps.to_bits());
        assert_eq!(ts.slices[0].fps.to_bits(), want.to_bits());
    }

    #[test]
    fn timeshare_underprovisioned_slice_stretches_the_period() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 2);
        // Slice shorter than the batch needs: the schedule must stretch
        // (overrun), never drop admitted frames.
        let slice = solo.makespan / 2;
        let ts = simulate_timeshared(&[&alloc], &[2], &[slice], &[1_000]);
        assert_eq!(ts.slices[0].overrun, 1_000 + solo.makespan - slice);
        assert_eq!(ts.period_cycles, 1_000 + solo.makespan);
        // Zero-frame slices are pure dead time.
        let ts0 = simulate_timeshared(&[&alloc, &alloc], &[2, 0], &[slice, slice], &[0, 0]);
        assert!(ts0.slices[1].sim.is_none());
        assert_eq!(ts0.slices[1].makespan, 0);
        assert_eq!(ts0.slices[1].fps, 0.0);
    }

    #[test]
    fn drain_overlap_credit_is_bounded_and_never_costs() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 3);
        let drain = solo.makespan - *solo.input_done.last().unwrap();
        assert!(drain > 0, "lenet pipeline must have a drain tail");
        // Tight slices (provision = bare makespan) so the reconfiguration
        // charge is what separates the two cost models.
        let rc = 50_000u64;
        let seq: Vec<ScheduleSlice> = (0..2)
            .map(|t| ScheduleSlice {
                tenant: t,
                frames: 3,
                slice_cycles: solo.makespan,
                reconfig_cycles: rc,
            })
            .collect();
        let overlapped = simulate_schedule(&[&alloc, &alloc], &seq, true);
        let serial = simulate_schedule(&[&alloc, &alloc], &seq, false);
        // The credit is real, bounded by both the reconfiguration and the
        // predecessor's drain, and can only shorten the period.
        for s in &overlapped.slices {
            assert_eq!(s.overlap_cycles, rc.min(drain));
        }
        assert!(overlapped.period_cycles < serial.period_cycles);
        assert_eq!(
            overlapped.period_cycles,
            serial.period_cycles - 2 * rc.min(drain)
        );
        for t in 0..2 {
            assert!(overlapped.worst_sojourn[t] <= serial.worst_sojourn[t]);
            assert!(overlapped.tenant_fps[t] >= serial.tenant_fps[t]);
        }
    }

    #[test]
    fn simulator_reproduces_the_search_validation_pass() {
        // The Simulate trait runs the same confirm_plan engine the
        // sharder's validation pass used, on the same rehydrated
        // allocations — per-tenant fps must agree bit-for-bit.
        use crate::plan::{Planner, Workload};
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).validate(2).plan(&w).unwrap();
        let plan = &set.plans[set.frontier[0]];
        let rep = Simulator { frames: 2 }.simulate(plan).unwrap();
        assert_eq!(rep.tenants.len(), 2);
        for (t, r) in rep.tenants.iter().enumerate() {
            let recorded = plan.tenants[t]
                .record
                .as_ref()
                .and_then(|rec| rec.sim_fps)
                .expect("validated frontier plans record sim fps");
            assert_eq!(r.fps.to_bits(), recorded.to_bits(), "tenant {t}");
        }
        assert_eq!(rep.tenant_fps().len(), 2);
    }

    #[test]
    fn replay_event_skip_matches_stepping() {
        use super::engines::{replay_arrivals_counted, replay_arrivals_stepping};
        let alloc = FlexAllocator::default()
            .allocate(&zoo::lenet(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let solo = simulate(&alloc, 2);
        let slice = solo.makespan + 5_000;
        let ts =
            simulate_timeshared(&[&alloc, &alloc], &[2, 2], &[slice, slice], &[3_000, 3_000]);
        let period = ts.period_cycles;

        // Sparse arrivals with huge provably-idle gaps: the skipping wheel
        // must produce byte-identical reports in far fewer visits.
        let arrivals = vec![
            vec![0, 50 * period, 50 * period + 1, 903 * period],
            vec![7 * period + 123, 400 * period],
        ];
        let capacity = [0usize, 1];
        let (fast, fast_visits) = replay_arrivals_counted(&ts, &arrivals, &capacity);
        let (slow, slow_visits) = replay_arrivals_stepping(&ts, &arrivals, &capacity);
        assert_eq!(fast.len(), slow.len());
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!(f.sojourns, s.sojourns);
            assert_eq!(f.rejected, s.rejected);
        }
        assert!(
            fast_visits < slow_visits / 10,
            "event-skip must engage on sparse arrivals ({fast_visits} vs {slow_visits} visits)"
        );

        // Dense arrivals (queue rarely empty, rejections exercised): the
        // two wheels still agree exactly.
        let dense: Vec<Vec<u64>> =
            (0..2u64).map(|t| (0..200u64).map(|i| i * 37 + t).collect()).collect();
        let (f2, _) = replay_arrivals_counted(&ts, &dense, &[3usize, 0]);
        let (s2, _) = replay_arrivals_stepping(&ts, &dense, &[3usize, 0]);
        for (f, s) in f2.iter().zip(&s2) {
            assert_eq!(f.sojourns, s.sojourns);
            assert_eq!(f.rejected, s.rejected);
        }
    }

    #[test]
    fn all_groups_complete() {
        let alloc = FlexAllocator::default()
            .allocate(&zoo::vgg_micro(), &zc706(), QuantMode::W8A8)
            .unwrap();
        let frames = 4;
        let sim = simulate(&alloc, frames);
        for (i, (st, a)) in sim.stages.iter().zip(&alloc.stages).enumerate() {
            assert_eq!(
                st.groups_done,
                a.figures.groups_per_frame * frames as u64,
                "stage {i} incomplete"
            );
        }
    }
}
