//! Design-space search: the framework's outer loop.
//!
//! The paper's framework exists to answer "what does the *balanced*
//! accelerator look like for this (model, board, precision)?" — so the
//! product-shaped workload is not one allocation but a sweep:
//! boards × models × precisions × DSP budgets × architectures, scored and
//! reduced to a Pareto frontier. [`DesignSpace`] is that sweep as an API:
//!
//! - **Shared precomputation**: the per-layer decomposition staircases
//!   ([`NetTables`]) depend only on layer dimensions, so they are built
//!   once per model and shared (by reference) across every board/mode/
//!   budget job of the sweep.
//! - **Parallel fan-out**: jobs are distributed over scoped worker threads
//!   with an atomic work-stealing cursor. Results land in per-job slots,
//!   so the output order is deterministic (job enumeration order)
//!   regardless of thread count or scheduling.
//! - **Frontier reduction**: [`pareto_frontier`] returns the non-dominated
//!   points under (maximize fps, minimize power, minimize DSPs). Callers
//!   normally group points by (model, mode) first — a frontier across
//!   different models compares apples to oranges.
//!
//! Consumed by the `flexipipe search` CLI subcommand, the `design_space`
//! example, and `benches/{hotpath,bandwidth_sweep}.rs`.

use crate::alloc::flex::{FlexAllocator, NetTables, ThetaSeed};
use crate::alloc::{allocator_for, AllocReport, ArchKind};
use crate::board::Board;
use crate::model::Network;
use crate::power::PowerModel;
use crate::quant::QuantMode;
use crate::shard::{self, ScheduleMode, Sharder, Tenant};
use crate::sim::{self, SimReport};
use crate::util::json::{self, Value};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

/// One evaluated point of the design space.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Board name.
    pub board: String,
    /// Model name.
    pub model: String,
    /// Quantization mode.
    pub mode: QuantMode,
    /// Architecture that produced the allocation.
    pub arch: ArchKind,
    /// DSPs available to the allocator (after any budget override).
    pub dsps_avail: usize,
    /// Closed-form report.
    pub report: AllocReport,
    /// Estimated power (W).
    pub power_w: f64,
    /// Largest row parallelism Algorithm 2 chose.
    pub max_k: usize,
    /// Cycle-accurate confirmation, when `sim_frames > 0`.
    pub sim: Option<SimReport>,
}

impl DesignPoint {
    /// JSON encoding (for `--json` dumps and the perf-trajectory bench).
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("board", Value::Str(self.board.clone())),
            ("model", Value::Str(self.model.clone())),
            ("bits", Value::Num(self.mode.bits() as f64)),
            ("arch", Value::Str(self.arch.label().to_string())),
            ("dsps_avail", Value::Num(self.dsps_avail as f64)),
            ("fps", Value::Num(self.report.fps)),
            ("gops", Value::Num(self.report.gops)),
            ("dsp_efficiency", Value::Num(self.report.dsp_efficiency)),
            ("dsps", Value::Num(self.report.dsps as f64)),
            ("bram18", Value::Num(self.report.bram18 as f64)),
            ("ddr_gbps", Value::Num(self.report.ddr_bytes_per_sec / 1e9)),
            ("power_w", Value::Num(self.power_w)),
            ("max_k", Value::Num(self.max_k as f64)),
        ];
        if let Some(s) = &self.sim {
            pairs.push(("sim_fps", Value::Num(s.fps)));
            pairs.push(("sim_cycles_per_frame", Value::Num(s.cycles_per_frame)));
        }
        json::obj(pairs)
    }
}

/// A boards × models × modes × DSP-budgets × architectures sweep.
///
/// All fields are public; [`DesignSpace::default`] gives the common shape
/// (16-bit, flex architecture, board-default DSP budget, closed-form only,
/// auto thread count) so callers only fill in boards and models.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    /// Boards to sweep (cloned per job; mutate e.g. `ddr_bytes_per_sec`
    /// beforehand for bandwidth sweeps).
    pub boards: Vec<Board>,
    /// Models to sweep.
    pub models: Vec<Network>,
    /// Quantization modes.
    pub modes: Vec<QuantMode>,
    /// Architectures to allocate with.
    pub archs: Vec<ArchKind>,
    /// DSP budget overrides; `None` keeps the board's own count.
    pub dsp_budgets: Vec<Option<usize>>,
    /// Frames to run through the cycle simulator per point (0 = skip).
    pub sim_frames: usize,
    /// Worker threads; 0 = `std::thread::available_parallelism()`.
    pub threads: usize,
    /// Co-resident tenant groups for [`DesignSpace::sweep_shards`]: each
    /// inner vec is one set of models to shard a board across (the CLI's
    /// `--tenants vgg16+alexnet,vgg16+zf` axis). Ignored by the
    /// single-model [`DesignSpace::sweep`].
    pub tenant_groups: Vec<Vec<Network>>,
    /// Split granularity handed to the [`Sharder`] per shard job.
    pub shard_steps: usize,
    /// Sharding regime(s) for [`DesignSpace::sweep_shards`]: spatial
    /// splits, temporal schedules, the static-region overlay, or all
    /// merged (`--schedule`, `--overlay`).
    pub schedule: ScheduleMode,
    /// Temporal-schedule period bound in seconds handed to each
    /// [`Sharder`] (`--max-period`).
    pub max_period_s: f64,
    /// Largest per-tenant interleave factor the temporal planner may use
    /// (`--interleave`; 1 = whole slices, the PR-3 layout).
    pub max_interleave: usize,
    /// Per-model latency SLOs in seconds applied to every shard job's
    /// matching tenants (`--slo vgg16=33ms,...` parsed by
    /// [`crate::shard::parse_slos`]). Models absent from a tenant group
    /// are ignored there.
    pub slos: Vec<(String, f64)>,
    /// Per-model effective-fps floors applied to every shard job's
    /// matching tenants (`--min-fps vgg16=25,...` parsed by
    /// [`crate::shard::parse_min_fps`]) — plans starving a floored
    /// tenant are dropped at admission. Models absent from a tenant
    /// group are ignored there.
    pub min_fps: Vec<(String, f64)>,
    /// Warm-start neighboring DSP-budget points of a sweep chain by
    /// carrying the settled Algorithm 1 θ vector forward (flex arch only;
    /// regression-tested bit-identical to cold starts). Default on.
    pub warm_start: bool,
    /// Branch-and-bound pruning inside each shard job's [`Sharder`]
    /// search (`--prune`): skip quantum-lattice subtrees whose admissible
    /// fps upper bound is dominated by the incumbent frontier. Exact —
    /// the frontier and objective picks are pinned bit-identical to the
    /// exhaustive search. Default off.
    pub prune: bool,
}

impl Default for DesignSpace {
    fn default() -> Self {
        DesignSpace {
            boards: Vec::new(),
            models: Vec::new(),
            modes: vec![QuantMode::W16A16],
            archs: vec![ArchKind::FlexPipeline],
            dsp_budgets: vec![None],
            sim_frames: 0,
            threads: 0,
            tenant_groups: Vec::new(),
            shard_steps: 16,
            schedule: ScheduleMode::Spatial,
            max_period_s: 0.5,
            max_interleave: 1,
            slos: Vec::new(),
            min_fps: Vec::new(),
            warm_start: true,
            prune: false,
        }
    }
}

/// Work-saved statistics of one [`DesignSpace::sweep`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Design points the sweep covers (the product of its axes).
    pub points: usize,
    /// Points reused verbatim from their budget-chain predecessor because
    /// Algorithm 1's settled θ vector plateaued — no figures, Algorithm 2,
    /// evaluation, power model or DES ran for them.
    pub plateau_reused: usize,
}

/// One enumerated job (indices into the `DesignSpace` vectors).
struct Job {
    board: usize,
    model: usize,
    mode: QuantMode,
    arch: ArchKind,
    dsps: Option<usize>,
}

/// One parallel work unit of [`DesignSpace::sweep`]: a whole flex-arch
/// budget chain (sequential, carrying the θ seed) or a single job.
#[derive(Clone, Copy)]
enum Unit {
    Chain(usize),
    Job(usize),
}

/// One evaluated shard job of [`DesignSpace::sweep_shards`]: a board ×
/// tenant-group × precision point, carrying the full split-search result.
#[derive(Debug, Clone)]
pub struct ShardPoint {
    /// Board name.
    pub board: String,
    /// Co-resident model names, in tenant order.
    pub models: Vec<String>,
    /// Quantization mode shared by the group.
    pub mode: QuantMode,
    /// The split-space search output.
    pub result: shard::ShardResult,
}

impl ShardPoint {
    /// JSON encoding (board/models/bits + the shard frontier).
    pub fn to_json(&self, steps: usize) -> Value {
        json::obj(vec![
            ("board", Value::Str(self.board.clone())),
            (
                "models",
                Value::Arr(self.models.iter().map(|m| Value::Str(m.clone())).collect()),
            ),
            ("bits", Value::Num(self.mode.bits() as f64)),
            ("shard", shard::result_to_json(&self.result, steps)),
        ])
    }
}

impl DesignSpace {
    /// Number of design points the sweep will evaluate.
    pub fn len(&self) -> usize {
        self.boards.len()
            * self.models.len()
            * self.modes.len()
            * self.archs.len()
            * self.dsp_budgets.len()
    }

    /// Is the sweep empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn jobs(&self) -> Vec<Job> {
        let mut jobs = Vec::with_capacity(self.len());
        for board in 0..self.boards.len() {
            for model in 0..self.models.len() {
                for &mode in &self.modes {
                    for &arch in &self.archs {
                        for &dsps in &self.dsp_budgets {
                            jobs.push(Job {
                                board,
                                model,
                                mode,
                                arch,
                                dsps,
                            });
                        }
                    }
                }
            }
        }
        jobs
    }

    /// Run one sweep job. `seed` is the θ vector settled by the previous
    /// (smaller-budget) point of this job's chain — [`FlexAllocator`]
    /// warm-starts Algorithm 1 from it when usable and returns the seed for
    /// the next point; non-flex architectures pass no seed through.
    fn run_job(
        &self,
        job: &Job,
        tables: &[NetTables],
        seed: Option<&ThetaSeed>,
    ) -> crate::Result<(DesignPoint, Option<ThetaSeed>)> {
        let net = &self.models[job.model];
        let mut board = self.boards[job.board].clone();
        if let Some(d) = job.dsps {
            board.dsps = d;
        }
        let (alloc, seed_out) = match job.arch {
            // Flex reuses the model's shared decomposition tables (and the
            // chain's θ seed, when warm starts are on).
            ArchKind::FlexPipeline => {
                let (alloc, seed_out) = FlexAllocator::default().allocate_seeded(
                    net,
                    &board,
                    job.mode,
                    &tables[job.model],
                    seed.filter(|_| self.warm_start),
                )?;
                (alloc, Some(seed_out))
            }
            other => (allocator_for(other).allocate(net, &board, job.mode)?, None),
        };
        let report = alloc.evaluate();
        let power_w = PowerModel::default().estimate(&alloc, &report).total();
        let max_k = alloc.stages.iter().map(|s| s.cfg.k).max().unwrap_or(1);
        let sim = (self.sim_frames > 0).then(|| sim::simulate(&alloc, self.sim_frames));
        Ok((
            DesignPoint {
                board: board.name.clone(),
                model: net.name.clone(),
                mode: job.mode,
                arch: job.arch,
                dsps_avail: board.dsps,
                report,
                power_w,
                max_k,
                sim,
            },
            seed_out,
        ))
    }

    /// Worker threads a fan-out of `n_jobs` will use: the `threads`
    /// override (or the core count when 0), clamped to the job count.
    fn worker_count(&self, n_jobs: usize) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
        .clamp(1, n_jobs.max(1))
    }

    /// Partition the job list into parallel work units: flex-arch budget
    /// chains stay whole (their θ seed is carried sequentially), every
    /// other job — warm starts off, single-budget chains, non-flex
    /// architectures — fans out individually. Units cover the job list in
    /// ascending contiguous ranges, so flattening per-unit results
    /// reproduces the job enumeration order. Single source of truth for
    /// both [`DesignSpace::sweep`] and [`DesignSpace::workers`].
    fn sweep_units(&self, jobs: &[Job]) -> Vec<Unit> {
        let chain_len = self.dsp_budgets.len().max(1);
        debug_assert_eq!(jobs.len() % chain_len, 0, "budgets are the innermost axis");
        let mut units = Vec::new();
        for c in 0..jobs.len() / chain_len {
            let chained = self.warm_start
                && chain_len > 1
                && jobs[c * chain_len].arch == ArchKind::FlexPipeline;
            if chained {
                units.push(Unit::Chain(c));
            } else {
                units.extend((0..chain_len).map(|k| Unit::Job(c * chain_len + k)));
            }
        }
        units
    }

    /// Worker threads [`DesignSpace::sweep`] will actually use (one work
    /// unit per worker at a time — see [`DesignSpace::sweep_units`]).
    pub fn workers(&self) -> usize {
        self.worker_count(self.sweep_units(&self.jobs()).len())
    }

    /// Evaluate every point of the sweep, fanning jobs out across worker
    /// threads. Output order is the deterministic job enumeration order
    /// (boards, then models, then modes, archs, budgets) independent of
    /// `threads`.
    ///
    /// Parallel structure ([`DesignSpace::sweep_units`]): flex-arch budget
    /// *chains* — contiguous runs sharing (board, model, mode) and
    /// differing only in DSP budget, the innermost enumeration axis — run
    /// sequentially on one worker so each point carries its settled θ
    /// vector to the next budget as an Algorithm 1 warm start
    /// ([`ThetaSeed`]; bit-identical to cold starts — regression-tested).
    /// Everything that carries no seed (warm starts off via
    /// `warm_start: false`, single-budget chains, non-flex architectures)
    /// fans out per job.
    pub fn sweep(&self) -> crate::Result<Vec<DesignPoint>> {
        Ok(self.sweep_counted()?.0)
    }

    /// [`DesignSpace::sweep`] plus its [`SweepStats`]: how many points the
    /// θ-plateau skip served from their chain predecessor. Along a budget
    /// chain only `board.dsps` varies, so once Algorithm 1's settled θ
    /// vector stops growing every downstream quantity is unchanged — the
    /// chain runs [`FlexAllocator::settle_thetas`] (cheap) first and
    /// reuses the previous [`DesignPoint`] verbatim on a plateau, patching
    /// only `dsps_avail`. Bit-identical to the unskipped sweep
    /// (regression-tested).
    pub fn sweep_counted(&self) -> crate::Result<(Vec<DesignPoint>, SweepStats)> {
        anyhow::ensure!(!self.is_empty(), "empty design space (no boards or models?)");
        // Shared precomputation: decomposition staircases once per model.
        let tables: Vec<NetTables> = self.models.iter().map(NetTables::build).collect();
        let jobs = self.jobs();
        let chain_len = self.dsp_budgets.len().max(1);
        let units = self.sweep_units(&jobs);
        let plateaus = AtomicUsize::new(0);
        let results = fan_out(units.len(), self.worker_count(units.len()), |u| match units[u] {
            Unit::Job(i) => Ok(vec![self.run_job(&jobs[i], &tables, None)?.0]),
            Unit::Chain(c) => {
                let mut out: Vec<DesignPoint> = Vec::with_capacity(chain_len);
                let mut seed: Option<ThetaSeed> = None;
                for k in 0..chain_len {
                    let job = &jobs[c * chain_len + k];
                    // Plateau skip: settle θ cheaply first; when the
                    // vector equals the predecessor's, the rest of the
                    // job is a pure function of θ (only the DSP budget
                    // varies along a chain) — reuse the previous point.
                    if let (Some(prev), Some(s)) = (out.last(), seed.as_ref()) {
                        let net = &self.models[job.model];
                        let mut board = self.boards[job.board].clone();
                        if let Some(d) = job.dsps {
                            board.dsps = d;
                        }
                        let settled = FlexAllocator::default().settle_thetas(
                            net,
                            &board,
                            job.mode,
                            &tables[job.model],
                            Some(s),
                        )?;
                        if settled.theta == s.theta {
                            let mut point = prev.clone();
                            point.dsps_avail = board.dsps;
                            out.push(point);
                            seed = Some(settled);
                            plateaus.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    let (point, next) = self.run_job(job, &tables, seed.as_ref())?;
                    seed = next;
                    out.push(point);
                }
                Ok(out)
            }
        })?;
        let stats = SweepStats {
            points: jobs.len(),
            plateau_reused: plateaus.load(Ordering::Relaxed),
        };
        Ok((results.into_iter().flatten().collect(), stats))
    }

    /// Evaluate every shard job of the sweep: boards × tenant groups ×
    /// modes, each running a full [`Sharder`] split search (the
    /// `--tenants` axis). Same deterministic-output parallel fan-out as
    /// [`DesignSpace::sweep`].
    pub fn sweep_shards(&self) -> crate::Result<Vec<ShardPoint>> {
        anyhow::ensure!(
            !self.boards.is_empty() && !self.tenant_groups.is_empty(),
            "empty shard space (no boards or tenant groups?)"
        );
        // An SLO or fps floor naming no tenant in any group is a typo,
        // not a no-op — fail it like `shard`'s apply_slos does instead of
        // silently running the sweep unconstrained.
        for (flag, pairs) in [("--slo", &self.slos), ("--min-fps", &self.min_fps)] {
            for (name, _) in pairs {
                anyhow::ensure!(
                    self.tenant_groups
                        .iter()
                        .any(|g| g.iter().any(|net| &net.name == name)),
                    "{flag} names model '{name}' which appears in no tenant group"
                );
            }
        }
        struct SJob {
            board: usize,
            group: usize,
            mode: QuantMode,
        }
        let mut jobs = Vec::new();
        for board in 0..self.boards.len() {
            for group in 0..self.tenant_groups.len() {
                for &mode in &self.modes {
                    jobs.push(SJob { board, group, mode });
                }
            }
        }
        fan_out(jobs.len(), self.worker_count(jobs.len()), |i| {
            let job = &jobs[i];
            let board = self.boards[job.board].clone();
            let group = &self.tenant_groups[job.group];
            let mut tenants: Vec<Tenant> = group
                .iter()
                .map(|net| Tenant::new(net.clone(), job.mode))
                .collect();
            // Per-model SLOs: apply the ones this group actually serves
            // (globally unknown names were already rejected above; a name
            // absent from *this* group is legitimate).
            let group_slos: Vec<(String, f64)> = self
                .slos
                .iter()
                .filter(|(name, _)| group.iter().any(|net| &net.name == name))
                .cloned()
                .collect();
            if !group_slos.is_empty() {
                shard::apply_slos(&mut tenants, &group_slos)?;
            }
            let group_floors: Vec<(String, f64)> = self
                .min_fps
                .iter()
                .filter(|(name, _)| group.iter().any(|net| &net.name == name))
                .cloned()
                .collect();
            if !group_floors.is_empty() {
                shard::apply_min_fps(&mut tenants, &group_floors)?;
            }
            let sharder = Sharder {
                steps: self.shard_steps,
                sim_frames: self.sim_frames,
                schedule: self.schedule,
                max_period_s: self.max_period_s,
                max_interleave: self.max_interleave,
                prune: self.prune,
                ..Sharder::new(board.clone(), tenants)
            };
            sharder.search().map(|result| ShardPoint {
                board: board.name.clone(),
                models: group.iter().map(|n| n.name.clone()).collect(),
                mode: job.mode,
                result,
            })
        })
    }
}

/// Deterministic-order parallel fan-out shared by the sweep entry points:
/// an atomic cursor hands out job indices, results land in per-index
/// slots, so output order is the enumeration order regardless of thread
/// count or scheduling.
///
/// Failure semantics: the first job to fail (or panic — panics are caught
/// and mapped to typed errors) raises an atomic cancellation flag, so
/// workers stop claiming new jobs instead of running the rest of the
/// sweep to completion. Because the cursor hands indices out in ascending
/// order and every *claimed* job fills its slot (panics included),
/// unfilled slots form a suffix above the failure — the join path scans
/// slots in order and deterministically surfaces the lowest-index error.
/// Slot mutexes are read through [`PoisonError::into_inner`], so a
/// panicking worker can never turn into a second, unrelated panic at
/// join time.
///
/// [`PoisonError::into_inner`]: std::sync::PoisonError::into_inner
fn fan_out<T: Send>(
    n_jobs: usize,
    workers: usize,
    run: impl Fn(usize) -> crate::Result<T> + Sync,
) -> crate::Result<Vec<T>> {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let cursor = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<crate::Result<T>>>> =
        (0..n_jobs).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break; // an earlier job failed: cancel outstanding work
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n_jobs {
                    break;
                }
                let result = catch_unwind(AssertUnwindSafe(|| run(i))).unwrap_or_else(|p| {
                    Err(anyhow::anyhow!("sweep job {i} panicked: {}", panic_message(&p)))
                });
                if result.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(result);
            });
        }
    });
    let mut out = Vec::with_capacity(n_jobs);
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap_or_else(PoisonError::into_inner) {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            // Cancelled by a lower-index failure — but that failure's slot
            // precedes this one, so this arm is unreachable unless the
            // cancellation flag itself raced ahead of the error landing;
            // surface a typed error rather than panicking either way.
            None => anyhow::bail!("sweep job {i} was cancelled by an earlier failure"),
        }
    }
    Ok(out)
}

/// Best-effort text of a caught panic payload (the `&str`/`String` cases
/// `panic!` produces; anything else gets a placeholder).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Dominance under (maximize fps, minimize power, minimize DSPs used).
fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    a.report.fps >= b.report.fps
        && a.power_w <= b.power_w
        && a.report.dsps <= b.report.dsps
        && (a.report.fps > b.report.fps || a.power_w < b.power_w || a.report.dsps < b.report.dsps)
}

/// Non-dominated members of `subset` (indices into `points`).
fn frontier_of(points: &[DesignPoint], subset: &[usize]) -> Vec<usize> {
    subset
        .iter()
        .copied()
        .filter(|&i| {
            !subset
                .iter()
                .any(|&j| j != i && dominates(&points[j], &points[i]))
        })
        .collect()
}

/// Indices of the non-dominated points under (maximize fps, minimize
/// power, minimize DSPs used). Use [`frontier_by_workload`] when the
/// sweep mixes workloads — cross-model dominance is not meaningful.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<usize> {
    let all: Vec<usize> = (0..points.len()).collect();
    frontier_of(points, &all)
}

/// Pareto frontier per `(model, bits)` workload: returns
/// `((model, bits), frontier indices into points)` pairs in first-seen
/// order. Shared by the `search` CLI and the `design_space` example so
/// the two stay consistent (and no points are cloned into subsets).
pub fn frontier_by_workload(points: &[DesignPoint]) -> Vec<((String, usize), Vec<usize>)> {
    let mut keys: Vec<(String, usize)> = Vec::new();
    for p in points {
        let key = (p.model.clone(), p.mode.bits());
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    keys.into_iter()
        .map(|key| {
            let subset: Vec<usize> = (0..points.len())
                .filter(|&i| points[i].model == key.0 && points[i].mode.bits() == key.1)
                .collect();
            let front = frontier_of(points, &subset);
            (key, front)
        })
        .collect()
}

/// JSON array for a whole sweep.
pub fn sweep_to_json(points: &[DesignPoint]) -> Value {
    Value::Arr(points.iter().map(DesignPoint::to_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;

    fn small_space(threads: usize) -> DesignSpace {
        DesignSpace {
            boards: vec![zedboard(), zc706()],
            models: vec![zoo::tinycnn(), zoo::lenet()],
            modes: vec![QuantMode::W8A8, QuantMode::W16A16],
            threads,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let serial = small_space(1).sweep().unwrap();
        let parallel = small_space(4).sweep().unwrap();
        assert_eq!(serial.len(), 8);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.board, b.board);
            assert_eq!(a.model, b.model);
            assert_eq!(a.report.fps.to_bits(), b.report.fps.to_bits());
            assert_eq!(a.report.dsps, b.report.dsps);
        }
    }

    #[test]
    fn sweep_matches_direct_allocation() {
        use crate::alloc::Allocator;
        let points = small_space(0).sweep().unwrap();
        // First job: zedboard × tinycnn × 8-bit × flex × default budget.
        let direct = FlexAllocator::default()
            .allocate(&zoo::tinycnn(), &zedboard(), QuantMode::W8A8)
            .unwrap()
            .evaluate();
        assert_eq!(points[0].report.fps.to_bits(), direct.fps.to_bits());
        assert_eq!(points[0].report.bram18, direct.bram18);
    }

    #[test]
    fn warm_started_budget_sweep_is_bit_identical_to_cold() {
        // The θ-vector warm start across a budget chain must be a pure
        // optimization: every point (and hence the frontier) bit-identical
        // to cold-starting each budget. Covers two models × both
        // precisions over the documented sweep grid.
        let mk = |warm: bool, threads: usize| DesignSpace {
            boards: vec![zc706()],
            models: vec![zoo::vgg16(), zoo::lenet()],
            modes: vec![QuantMode::W16A16, QuantMode::W8A8],
            dsp_budgets: [256, 384, 512, 680, 900, 1100, 1400]
                .iter()
                .map(|&d| Some(d))
                .collect(),
            warm_start: warm,
            threads,
            ..Default::default()
        };
        let warm = mk(true, 1).sweep().unwrap();
        let cold = mk(false, 1).sweep().unwrap();
        assert_eq!(warm.len(), cold.len());
        for (a, b) in warm.iter().zip(&cold) {
            let ctx = format!("{} {}b dsps={}", a.model, a.mode.bits(), a.dsps_avail);
            assert_eq!(a.report.fps.to_bits(), b.report.fps.to_bits(), "{ctx}");
            assert_eq!(a.report.t_frame_cycles, b.report.t_frame_cycles, "{ctx}");
            assert_eq!(a.report.dsps, b.report.dsps, "{ctx}");
            assert_eq!(a.report.bram18, b.report.bram18, "{ctx}");
            assert_eq!(a.report.stage_cycles, b.report.stage_cycles, "{ctx}");
            assert_eq!(a.max_k, b.max_k, "{ctx}");
        }
        // Frontier indices must therefore agree too.
        let fw = frontier_by_workload(&warm);
        let fc = frontier_by_workload(&cold);
        assert_eq!(fw, fc);
        // And warm-started chains stay deterministic across thread counts.
        let parallel = mk(true, 4).sweep().unwrap();
        for (a, b) in warm.iter().zip(&parallel) {
            assert_eq!(a.report.fps.to_bits(), b.report.fps.to_bits());
        }
    }

    #[test]
    fn fan_out_surfaces_typed_errors_and_cancels() {
        // Error path: the failing job's error surfaces (lowest index wins
        // deterministically) and outstanding jobs are cancelled instead of
        // running the whole sweep to completion.
        let ran = AtomicUsize::new(0);
        let err = fan_out(256, 2, |i| {
            ran.fetch_add(1, Ordering::Relaxed);
            if i == 3 {
                anyhow::bail!("job {i} exploded");
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            Ok(i)
        })
        .unwrap_err();
        assert!(err.to_string().contains("exploded"), "got: {err}");
        assert!(
            ran.load(Ordering::Relaxed) < 256,
            "first failure must cancel outstanding jobs"
        );

        // Panic path: a panicking worker becomes a typed error on the
        // caller — no poisoned-mutex panic at join time.
        let err = fan_out(8, 2, |i: usize| {
            if i == 0 {
                panic!("worker panicked on purpose");
            }
            Ok(i)
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("panicked") && msg.contains("on purpose"), "got: {msg}");

        // Success path: deterministic enumeration order.
        let ok: Vec<usize> = fan_out(5, 3, Ok).unwrap();
        assert_eq!(ok, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn plateau_skip_is_bit_identical_and_engages() {
        // A dense budget chain over vgg16 has long θ plateaus (a +1 DSP
        // budget rarely moves the settled vector). The warm chain's
        // plateau skip must engage and stay bit-identical to the cold
        // (unskipped, per-job) sweep.
        let mk = |warm: bool| DesignSpace {
            boards: vec![zc706()],
            models: vec![zoo::vgg16()],
            modes: vec![QuantMode::W16A16],
            dsp_budgets: (880..=900).map(Some).collect(),
            warm_start: warm,
            threads: 1,
            ..Default::default()
        };
        let (warm, stats) = mk(true).sweep_counted().unwrap();
        let cold = mk(false).sweep().unwrap();
        assert_eq!(warm.len(), cold.len());
        for (a, b) in warm.iter().zip(&cold) {
            let ctx = format!("dsps={}", b.dsps_avail);
            assert_eq!(a.dsps_avail, b.dsps_avail, "{ctx}");
            assert_eq!(a.report.fps.to_bits(), b.report.fps.to_bits(), "{ctx}");
            assert_eq!(a.report.t_frame_cycles, b.report.t_frame_cycles, "{ctx}");
            assert_eq!(a.report.dsps, b.report.dsps, "{ctx}");
            assert_eq!(a.report.bram18, b.report.bram18, "{ctx}");
            assert_eq!(a.report.stage_cycles, b.report.stage_cycles, "{ctx}");
            assert_eq!(a.max_k, b.max_k, "{ctx}");
        }
        assert_eq!(stats.points, 21);
        assert_eq!(
            stats.plateau_reused, 17,
            "θ plateaus on the dense 880..=900 chain must be skipped"
        );
    }

    #[test]
    fn dsp_budget_override_applies() {
        let ds = DesignSpace {
            boards: vec![zc706()],
            models: vec![zoo::tinycnn()],
            dsp_budgets: vec![Some(128), Some(512)],
            threads: 1,
            ..Default::default()
        };
        let pts = ds.sweep().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].dsps_avail, 128);
        assert_eq!(pts[1].dsps_avail, 512);
        assert!(pts[0].report.dsps <= 128);
    }

    #[test]
    fn pareto_keeps_nondominated_only() {
        let mut pts = small_space(1).sweep().unwrap();
        // Degrade one point so it is strictly dominated by another with the
        // same fps: same everything but more power.
        if pts.len() >= 2 {
            let clone = pts[0].clone();
            let mut worse = clone.clone();
            worse.power_w += 100.0;
            pts.push(worse);
            let front = pareto_frontier(&pts);
            assert!(!front.contains(&(pts.len() - 1)), "dominated point kept");
        }
    }

    #[test]
    fn empty_space_errors() {
        assert!(DesignSpace::default().sweep().is_err());
        assert!(DesignSpace::default().sweep_shards().is_err());
    }

    #[test]
    fn shard_sweep_validates_floor_names_and_applies_floors() {
        let mk = |floors: Vec<(String, f64)>| DesignSpace {
            boards: vec![zedboard()],
            tenant_groups: vec![vec![zoo::tinycnn(), zoo::lenet()]],
            modes: vec![QuantMode::W8A8],
            shard_steps: 8,
            min_fps: floors,
            threads: 1,
            ..Default::default()
        };
        // A floor naming no tenant group member is a typo, not a no-op.
        assert!(mk(vec![("nope".to_string(), 10.0)]).sweep_shards().is_err());
        // A trivially-low floor prunes nothing; plans still satisfy it.
        let free = mk(Vec::new()).sweep_shards().unwrap();
        let floored = mk(vec![("lenet".to_string(), 1e-6)]).sweep_shards().unwrap();
        assert_eq!(free[0].result.plans.len(), floored[0].result.plans.len());
        assert!(floored[0].result.plans.iter().all(|p| p.fps[1] >= 1e-6));
    }

    #[test]
    fn shard_sweep_runs_tenant_groups() {
        let ds = DesignSpace {
            boards: vec![zedboard()],
            tenant_groups: vec![vec![zoo::tinycnn(), zoo::lenet()]],
            modes: vec![QuantMode::W8A8],
            shard_steps: 8,
            threads: 1,
            ..Default::default()
        };
        let pts = ds.sweep_shards().unwrap();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].models, vec!["tinycnn".to_string(), "lenet".to_string()]);
        assert!(!pts[0].result.plans.is_empty());
        assert!(!pts[0].result.frontier.is_empty());
    }
}
