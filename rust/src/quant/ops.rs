//! Golden fixed-point operators: the engine datapath, from scratch in Rust.
//!
//! These are intentionally *naive* nested loops — clarity over speed — so
//! they can serve as the third independent implementation of the paper's
//! Sec. 3.3 arithmetic (alongside the Pallas kernel and the jnp oracle).
//! The integration test `rust/tests/runtime_golden.rs` checks all three
//! agree on the AOT golden frames.

use super::{shift_sat, QuantMode};

/// A tensor of activations in CHW layout, stored as `i64` regardless of the
/// declared mode (values always fit the mode's range).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Chw {
    /// Channels.
    pub c: usize,
    /// Rows.
    pub h: usize,
    /// Columns.
    pub w: usize,
    /// Values, `c*h*w` long, row-major within each channel.
    pub data: Vec<i64>,
}

impl Chw {
    /// Zero-initialized tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Chw {
            c,
            h,
            w,
            data: vec![0; c * h * w],
        }
    }

    /// Build from raw i8 bytes (the AOT golden file layout).
    pub fn from_i8(c: usize, h: usize, w: usize, bytes: &[i8]) -> Self {
        assert_eq!(bytes.len(), c * h * w);
        Chw {
            c,
            h,
            w,
            data: bytes.iter().map(|&b| b as i64).collect(),
        }
    }

    /// Value at `(channel, row, col)`.
    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> i64 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Store `v` at `(channel, row, col)`.
    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: i64) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Padded read: outside the map returns 0 (the controller's zeroMac).
    #[inline]
    pub fn get_padded(&self, c: usize, y: isize, x: isize) -> i64 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.get(c, y as usize, x as usize)
        }
    }
}

/// Per-layer fixed-point parameters (mirror of Python `ConvParams`).
#[derive(Debug, Clone)]
pub struct ConvParams {
    /// Weights `[M][C][R][S]` flattened.
    pub w: Vec<i64>,
    /// Output channels.
    pub m: usize,
    /// Input channels.
    pub c: usize,
    /// Kernel rows.
    pub r: usize,
    /// Kernel columns.
    pub s: usize,
    /// `[M]` int32 bias in accumulator format.
    pub bias: Vec<i64>,
    /// `[C]` per-input-channel alignment left shifts.
    pub lshift: Vec<u32>,
    /// `[M]` per-output-channel scaling right shifts.
    pub rshift: Vec<u32>,
}

impl ConvParams {
    #[inline]
    fn weight(&self, m: usize, c: usize, r: usize, s: usize) -> i64 {
        self.w[((m * self.c + c) * self.r + r) * self.s + s]
    }
}

/// Fixed-point convolution: `out = sat((Σ (x<<ls)·w + bias) >> rs)`, ReLU
/// optional. The paper's engine, loop-by-loop.
pub fn conv_fixed(
    x: &Chw,
    p: &ConvParams,
    stride: usize,
    pad: usize,
    mode: QuantMode,
    relu: bool,
) -> Chw {
    assert_eq!(x.c, p.c, "channel mismatch");
    let h_out = (x.h + 2 * pad - p.r) / stride + 1;
    let w_out = (x.w + 2 * pad - p.s) / stride + 1;
    let mut out = Chw::zeros(p.m, h_out, w_out);
    let bits = mode.bits();
    for m in 0..p.m {
        for oy in 0..h_out {
            for ox in 0..w_out {
                let mut psum: i64 = p.bias[m];
                for c in 0..p.c {
                    let xs = p.lshift[c];
                    for r in 0..p.r {
                        for s in 0..p.s {
                            let iy = (oy * stride + r) as isize - pad as isize;
                            let ix = (ox * stride + s) as isize - pad as isize;
                            let xv = x.get_padded(c, iy, ix) << xs;
                            psum += xv * p.weight(m, c, r, s);
                        }
                    }
                }
                let mut v = shift_sat(psum, p.rshift[m], bits);
                if relu && v < 0 {
                    v = 0;
                }
                out.set(m, oy, ox, v);
            }
        }
    }
    out
}

/// Grouped fixed-point convolution (AlexNet's split layers): input
/// channels divide into `groups` contiguous bands, and output-channel band
/// `g` reads only input band `g` — a block-diagonal weight matrix.
///
/// Parameter layout: `p.c` is the **per-group** input channel count
/// (`C/groups`), `p.m` the *total* output channels, `p.w` is
/// `[M][C/groups][R][S]` flattened (matching `ConvShape::weights()`),
/// `p.bias`/`p.rshift` are per output channel (`M` entries) and `p.lshift`
/// per physical input channel (`C = p.c·groups` entries). `groups == 1`
/// is exactly [`conv_fixed`].
///
/// Golden equivalence (tested): the result is bit-identical to an
/// *ungrouped* [`conv_fixed`] over the full input whose weight tensor is
/// the block-diagonal embedding of `p.w` (zeros across bands).
pub fn conv_grouped_fixed(
    x: &Chw,
    p: &ConvParams,
    groups: usize,
    stride: usize,
    pad: usize,
    mode: QuantMode,
    relu: bool,
) -> Chw {
    if groups == 1 {
        return conv_fixed(x, p, stride, pad, mode, relu);
    }
    assert_eq!(x.c, p.c * groups, "p.c must be per-group channels");
    assert_eq!(p.m % groups, 0, "groups must divide M");
    assert_eq!(p.lshift.len(), x.c, "one lshift per physical input channel");
    let cg = p.c;
    let mg = p.m / groups;
    let h_out = (x.h + 2 * pad - p.r) / stride + 1;
    let w_out = (x.w + 2 * pad - p.s) / stride + 1;
    let mut out = Chw::zeros(p.m, h_out, w_out);
    let bits = mode.bits();
    // Same loop nest as conv_fixed, with output channel `m` reading only
    // its band's physical input channels — no per-call band copies (this
    // sits on the artifact-free serving path).
    for m in 0..p.m {
        let band = (m / mg) * cg; // first physical input channel of m's band
        for oy in 0..h_out {
            for ox in 0..w_out {
                let mut psum: i64 = p.bias[m];
                for c in 0..cg {
                    let xs = p.lshift[band + c];
                    for r in 0..p.r {
                        for s in 0..p.s {
                            let iy = (oy * stride + r) as isize - pad as isize;
                            let ix = (ox * stride + s) as isize - pad as isize;
                            let xv = x.get_padded(band + c, iy, ix) << xs;
                            // p.c is the per-group count, so `weight`'s
                            // [M][C/g][R][S] stride is already right.
                            psum += xv * p.weight(m, c, r, s);
                        }
                    }
                }
                let mut v = shift_sat(psum, p.rshift[m], bits);
                if relu && v < 0 {
                    v = 0;
                }
                out.set(m, oy, ox, v);
            }
        }
    }
    out
}

/// Fixed-point max pooling.
pub fn maxpool_fixed(x: &Chw, r: usize, stride: usize) -> Chw {
    let h_out = (x.h - r) / stride + 1;
    let w_out = (x.w - r) / stride + 1;
    let mut out = Chw::zeros(x.c, h_out, w_out);
    for c in 0..x.c {
        for oy in 0..h_out {
            for ox in 0..w_out {
                let mut best = i64::MIN;
                for dy in 0..r {
                    for dx in 0..r {
                        best = best.max(x.get(c, oy * stride + dy, ox * stride + dx));
                    }
                }
                out.set(c, oy, ox, best);
            }
        }
    }
    out
}

/// Fixed-point fully-connected layer. `w` is `[n_out][n_in]` flattened.
pub fn fc_fixed(
    x: &[i64],
    w: &[i64],
    bias: &[i64],
    rshift: &[u32],
    mode: QuantMode,
    relu: bool,
) -> Vec<i64> {
    let n_in = x.len();
    let n_out = bias.len();
    assert_eq!(w.len(), n_in * n_out);
    let bits = mode.bits();
    (0..n_out)
        .map(|o| {
            let mut psum = bias[o];
            for (i, &xi) in x.iter().enumerate() {
                psum += xi * w[o * n_in + i];
            }
            let mut v = shift_sat(psum, rshift[o], bits);
            if relu && v < 0 {
                v = 0;
            }
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn identity_params(c: usize) -> ConvParams {
        // 1x1 identity kernel on channel 0
        ConvParams {
            w: (0..c).map(|i| if i == 0 { 1 } else { 0 }).collect(),
            m: 1,
            c,
            r: 1,
            s: 1,
            bias: vec![0],
            lshift: vec![0; c],
            rshift: vec![0],
        }
    }

    #[test]
    fn identity_conv_passes_through() {
        let mut x = Chw::zeros(2, 3, 3);
        for i in 0..9 {
            x.set(0, i / 3, i % 3, i as i64 - 4);
        }
        let y = conv_fixed(&x, &identity_params(2), 1, 0, QuantMode::W8A8, false);
        assert_eq!(y.data, x.data[..9]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut x = Chw::zeros(1, 1, 1);
        x.set(0, 0, 0, -5);
        let y = conv_fixed(&x, &identity_params(1), 1, 0, QuantMode::W8A8, true);
        assert_eq!(y.data, vec![0]);
    }

    #[test]
    fn lshift_aligns_channels() {
        // two channels, both weight 1; channel 1 shifted left by 2
        let p = ConvParams {
            w: vec![1, 1],
            m: 1,
            c: 2,
            r: 1,
            s: 1,
            bias: vec![0],
            lshift: vec![0, 2],
            rshift: vec![0],
        };
        let mut x = Chw::zeros(2, 1, 1);
        x.set(0, 0, 0, 3);
        x.set(1, 0, 0, 5);
        let y = conv_fixed(&x, &p, 1, 0, QuantMode::W8A8, false);
        assert_eq!(y.data, vec![3 + (5 << 2)]);
    }

    #[test]
    fn padding_reads_zero() {
        let p = ConvParams {
            w: vec![1; 9],
            m: 1,
            c: 1,
            r: 3,
            s: 3,
            bias: vec![0],
            lshift: vec![0],
            rshift: vec![0],
        };
        let mut x = Chw::zeros(1, 1, 1);
        x.set(0, 0, 0, 7);
        let y = conv_fixed(&x, &p, 1, 1, QuantMode::W8A8, false);
        assert_eq!(y.data, vec![7]); // only the centre tap lands on data
    }

    #[test]
    fn maxpool_takes_window_max() {
        let mut x = Chw::zeros(1, 2, 2);
        for (i, v) in [-3, 9, 2, 5].iter().enumerate() {
            x.set(0, i / 2, i % 2, *v);
        }
        let y = maxpool_fixed(&x, 2, 2);
        assert_eq!(y.data, vec![9]);
    }

    #[test]
    fn grouped_conv_matches_block_diagonal_ungrouped() {
        // Independent oracle: a grouped conv is exactly an ungrouped conv
        // whose weight tensor is block-diagonal across channel bands. The
        // ungrouped path never looks at `groups`, so this genuinely tests
        // the band routing (slicing of x, w, bias, shifts).
        use crate::util::prop::Rng;
        let (groups, c, m, r, hw) = (2usize, 6usize, 4usize, 3usize, 5usize);
        let (cg, mg) = (c / groups, m / groups);
        let mut rng = Rng::new(0xA1EC);
        let mut x = Chw::zeros(c, hw, hw);
        for v in x.data.iter_mut() {
            *v = rng.range(-128, 127);
        }
        let grouped = ConvParams {
            w: (0..m * cg * r * r).map(|_| rng.range(-4, 4)).collect(),
            m,
            c: cg,
            r,
            s: r,
            bias: (0..m).map(|_| rng.range(-64, 64)).collect(),
            lshift: (0..c).map(|_| rng.range(0, 2) as u32).collect(),
            rshift: (0..m).map(|_| rng.range(0, 3) as u32).collect(),
        };
        // Block-diagonal embedding: full [M][C][R][S], zero across bands.
        let mut wfull = vec![0i64; m * c * r * r];
        for om in 0..m {
            let g = om / mg;
            for ic in 0..cg {
                for k in 0..r * r {
                    wfull[(om * c + g * cg + ic) * r * r + k] =
                        grouped.w[(om * cg + ic) * r * r + k];
                }
            }
        }
        let full = ConvParams {
            w: wfull,
            m,
            c,
            r,
            s: r,
            bias: grouped.bias.clone(),
            lshift: grouped.lshift.clone(),
            rshift: grouped.rshift.clone(),
        };
        for (stride, pad, relu) in [(1, 1, true), (2, 0, false)] {
            let a = conv_grouped_fixed(&x, &grouped, groups, stride, pad, QuantMode::W8A8, relu);
            let b = conv_fixed(&x, &full, stride, pad, QuantMode::W8A8, relu);
            assert_eq!(a.data, b.data, "stride={stride} pad={pad}");
            assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
        }
        // groups == 1 degenerates to the plain path.
        let a = conv_grouped_fixed(&x, &full, 1, 1, 1, QuantMode::W8A8, true);
        let b = conv_fixed(&x, &full, 1, 1, QuantMode::W8A8, true);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn fc_shift_saturates() {
        let y = fc_fixed(
            &[100, 100],
            &[100, 100, 1, 0],
            &[0, 0],
            &[0, 0],
            QuantMode::W8A8,
            false,
        );
        assert_eq!(y, vec![127, 100]); // 20000 saturates, 100 passes
    }
}
