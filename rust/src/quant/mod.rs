//! Fixed-point arithmetic: the paper's Sec. 3.3 datapath in Rust.
//!
//! Two roles:
//!
//! 1. **Mode bookkeeping** — [`QuantMode`] encodes the DSP48E1 packing rule
//!    the whole framework hangs off: one DSP does *one* 16-bit or *two*
//!    8-bit multiplies per cycle, so the multiplier budget is
//!    `Θ = DSPs × mults_per_dsp` (paper Sec. 4.1).
//! 2. **Golden datapath** — [`conv_fixed`]/[`fc_fixed`] are a from-scratch
//!    Rust implementation of the channel-wise-aligned fixed-point MAC
//!    pipeline. The integration tests run the same golden frames through
//!    (a) this code, (b) the AOT-compiled Pallas HLO via PJRT, and (c) the
//!    Python oracle's files — three independent implementations that must
//!    agree bit-exactly.

pub mod ops;


/// Quantization mode: storage width of weights and activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMode {
    /// 8-bit weights/activations, 32-bit partial sums.
    W8A8,
    /// 16-bit weights/activations, wide partial sums.
    W16A16,
}

impl QuantMode {
    /// Multiplications one DSP48E1 performs per cycle (paper Sec. 4.1:
    /// 25×18 slice → 1 multiply at 16-bit, 2 at 8-bit).
    pub fn mults_per_dsp(&self) -> usize {
        match self {
            QuantMode::W8A8 => 2,
            QuantMode::W16A16 => 1,
        }
    }

    /// Activation/weight storage bytes.
    pub fn act_bytes(&self) -> usize {
        match self {
            QuantMode::W8A8 => 1,
            QuantMode::W16A16 => 2,
        }
    }

    /// Storage bits.
    pub fn bits(&self) -> usize {
        self.act_bytes() * 8
    }

    /// Parse `8`/`16`.
    pub fn from_bits(bits: usize) -> crate::Result<Self> {
        match bits {
            8 => Ok(QuantMode::W8A8),
            16 => Ok(QuantMode::W16A16),
            other => anyhow::bail!("unsupported quantization width: {other} (8 or 16)"),
        }
    }
}

impl std::fmt::Display for QuantMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}b", self.bits())
    }
}

/// Saturate a wide accumulator to the signed `bits` range — the RTL
/// truncate-with-saturation on the psum → activation conversion.
pub fn saturate(v: i64, bits: usize) -> i64 {
    let hi = (1i64 << (bits - 1)) - 1;
    let lo = -(1i64 << (bits - 1));
    v.clamp(lo, hi)
}

/// Arithmetic right shift: the RTL barrel shifter (floor semantics — tested
/// against the Pallas kernel's `>>`).
pub fn arshift(v: i64, shift: u32) -> i64 {
    v >> shift
}

/// Scale a psum to activation width: shift then saturate (paper Sec. 3.3
/// "partial sums should be right shifted and truncated for scaling down").
pub fn shift_sat(psum: i64, rshift: u32, bits: usize) -> i64 {
    saturate(arshift(psum, rshift), bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_rule_matches_dsp48e1() {
        assert_eq!(QuantMode::W8A8.mults_per_dsp(), 2);
        assert_eq!(QuantMode::W16A16.mults_per_dsp(), 1);
    }

    #[test]
    fn arshift_is_floor_not_trunc() {
        assert_eq!(arshift(-1, 1), -1); // floor(-0.5) = -1
        assert_eq!(arshift(-3, 1), -2);
        assert_eq!(arshift(3, 1), 1);
    }

    #[test]
    fn saturate_clamps_both_rails() {
        assert_eq!(saturate(1000, 8), 127);
        assert_eq!(saturate(-1000, 8), -128);
        assert_eq!(saturate(100, 8), 100);
        assert_eq!(saturate(40_000, 16), 32_767);
    }

    #[test]
    fn from_bits_round_trips() {
        assert_eq!(QuantMode::from_bits(8).unwrap(), QuantMode::W8A8);
        assert_eq!(QuantMode::from_bits(16).unwrap(), QuantMode::W16A16);
        assert!(QuantMode::from_bits(4).is_err());
    }
}
