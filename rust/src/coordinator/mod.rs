//! Frame-serving coordinator: the Fig. 4 demo system (host ↔ accelerator)
//! as a multithreaded server.
//!
//! The paper's host PC streams input frames over PCIe into DDR, kicks the
//! accelerator, and drains output activations ("sends more input frames
//! continuously", Sec. 5.1). Here the accelerator is a [`Backend`] —
//! the PJRT-compiled artifact when `artifacts/manifest.json` exists, the
//! deterministic in-process [`SimBackend`] otherwise; the coordinator owns:
//!
//! - an ingest queue ([`Coordinator::submit`] is the host-side API),
//! - a **dynamic batcher**: the backend serves several batch sizes
//!   (`tinycnn_b1/b4/b8`); the worker picks the largest available batch
//!   ≤ the queue depth, padding only when a timeout forces a partial batch,
//! - the execute worker (one thread — PJRT CPU executions are already
//!   internally parallel),
//! - latency/throughput metrics ([`ServeStats`]).
//!
//! The backend is built *inside* the worker thread by a `Send` factory
//! closure ([`Coordinator::start_with`]) — PJRT clients are `!Send`, so
//! only the recipe crosses the thread boundary, never the client.
//!
//! No tokio in the offline vendor set: std threads + channels. The queue
//! and stats are the same shape a tokio implementation would have.

use crate::model::Network;
use crate::runtime::{Backend, PjrtBackend, SimBackend, SIM_BATCHES};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching and fault-handling policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max time the batcher waits to fill a larger batch before running a
    /// padded partial one.
    pub max_wait: Duration,
    /// Simulated host-link (PCIe) latency added per request (the demo
    /// system's transfer cost; 0 disables).
    pub link_latency: Duration,
    /// Retries after a failed backend execute, with exponential backoff
    /// (`retry_backoff × 2^attempt`), before the batch's requests are
    /// failed. Default 2.
    pub max_retries: usize,
    /// Base backoff slept before the first retry. Default 1 ms.
    pub retry_backoff: Duration,
    /// Ceiling on how long [`Coordinator::infer`] waits for a result
    /// before giving up with a timeout error (the request may still
    /// complete in the background; its result is discarded). `None`
    /// (the default) waits indefinitely.
    pub request_timeout: Option<Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_wait: Duration::from_millis(2),
            link_latency: Duration::ZERO,
            max_retries: 2,
            retry_backoff: Duration::from_millis(1),
            request_timeout: None,
        }
    }
}

/// Per-tenant serving health, driven by the worker's execute outcomes:
/// any success restores `Healthy`; a batch that fails after all retries
/// degrades the tenant; [`SHED_AFTER`] consecutive failed batches trip
/// `Shedding`, where [`Coordinator::submit`] fails fast instead of
/// queueing onto a dead backend. A shedding tenant is restored by
/// applying a replanned deployment ([`PlannedService::apply`] restarts
/// its worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// The last batch executed successfully.
    Healthy,
    /// The last batch failed (after retries), but not yet persistently.
    Degraded,
    /// [`SHED_AFTER`] consecutive batches failed — new submissions are
    /// refused until the tenant is restarted.
    Shedding,
}

/// Consecutive failed batches (after per-batch retries) before a tenant
/// transitions from [`Health::Degraded`] to [`Health::Shedding`].
pub const SHED_AFTER: u32 = 3;

impl Health {
    /// Report label (`"healthy"` / `"degraded"` / `"shedding"`).
    pub fn label(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Shedding => "shedding",
        }
    }

    fn from_u8(v: u8) -> Health {
        match v {
            0 => Health::Healthy,
            1 => Health::Degraded,
            _ => Health::Shedding,
        }
    }
}

/// One in-flight request.
struct Request {
    frame: Vec<i8>,
    enqueued: Instant,
    resp: Sender<crate::Result<Vec<i8>>>,
}

/// Receive with a deadline, draining a message that arrived exactly at
/// expiry: `recv_timeout` with a zero (or already-elapsed) timeout
/// reports `Timeout` even when a message is sitting in the channel, so
/// the expiry path must `try_recv` once before declaring the deadline
/// missed. Shared by [`Coordinator::infer`]'s request-timeout path and
/// the worker's batch-fill loop — both had the race.
pub(crate) fn recv_deadline<T>(rx: &Receiver<T>, timeout: Duration) -> Result<T, RecvTimeoutError> {
    match rx.recv_timeout(timeout) {
        Err(RecvTimeoutError::Timeout) => match rx.try_recv() {
            Ok(v) => Ok(v),
            Err(mpsc::TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
            Err(mpsc::TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
        },
        other => other,
    }
}

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Frames served.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Histogram source: per-request latencies (µs).
    pub latencies_us: Vec<u64>,
    /// Frames executed per batch size (batch → count).
    pub batch_sizes: Vec<(usize, u64)>,
    /// Padded (wasted) frame slots.
    pub padded_frames: u64,
}

impl ServeStats {
    fn record_batch(&mut self, batch: usize, used: usize) {
        self.batches += 1;
        self.padded_frames += (batch - used) as u64;
        match self.batch_sizes.iter_mut().find(|(b, _)| *b == batch) {
            Some((_, c)) => *c += used as u64,
            None => self.batch_sizes.push((batch, used as u64)),
        }
    }

    /// Latency percentile in µs (p in [0,100]).
    pub fn latency_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p / 100.0).floor() as usize;
        v[idx]
    }
}

/// The frame server.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    frame_elems: usize,
    running: Arc<AtomicBool>,
    health: Arc<AtomicU8>,
    request_timeout: Option<Duration>,
}

impl Coordinator {
    /// Start serving `net` at `bits` from an artifact directory (the PJRT
    /// path). Validation (manifest present, variants exist) lives in
    /// [`PjrtBackend::open`]; its errors surface through
    /// [`Coordinator::start_with`]'s ready-handshake.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        net: &str,
        bits: usize,
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let dir = artifact_dir.into();
        let net = net.to_string();
        Self::start_with(
            move || PjrtBackend::open(dir, &net, bits).map(|b| Box::new(b) as Box<dyn Backend>),
            policy,
        )
    }

    /// Start serving `net` through the artifact-free in-process
    /// [`SimBackend`] at the given batch sizes.
    pub fn start_sim(
        net: &Network,
        batches: &[usize],
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let net = net.clone();
        let batches = batches.to_vec();
        Self::start_with(
            move || SimBackend::new(&net, &batches).map(|b| Box::new(b) as Box<dyn Backend>),
            policy,
        )
    }

    /// Serve every tenant of a [`DeploymentPlan`] on the in-process
    /// [`SimBackend`] — the serving half of the plan-centric flow
    /// (`flexipipe serve --plan plan.json`). The plan is **validated
    /// before anything starts serving**: every tenant's allocation is
    /// rehydrated ([`DeploymentPlan::instantiate`]), so an infeasible or
    /// stale plan is refused with the real cause instead of serving a
    /// deployment the planner never admitted. One coordinator (ingest
    /// queue + dynamic batcher + worker) is started per tenant, each on a
    /// deterministic `SimBackend` over the tenant's embedded network —
    /// 8-bit plans only, since the sim datapath is the i8 reference.
    ///
    /// [`DeploymentPlan`]: crate::plan::DeploymentPlan
    /// [`DeploymentPlan::instantiate`]: crate::plan::DeploymentPlan::instantiate
    pub fn start_planned(
        plan: &crate::plan::DeploymentPlan,
        policy: BatchPolicy,
    ) -> crate::Result<PlannedService> {
        anyhow::ensure!(
            plan.mode.bits() == 8,
            "start_planned serves the in-process SimBackend, which runs the 8-bit \
             reference datapath — re-plan the workload at --bits 8 (or serve \
             compiled artifacts per tenant via Coordinator::start)"
        );
        plan.instantiate()?;
        let mut tenants = Vec::with_capacity(plan.tenants.len());
        for t in &plan.tenants {
            let coord = Coordinator::start_sim(&t.net, SIM_BATCHES, policy.clone())?;
            tenants.push((t.net.name.clone(), coord));
        }
        Ok(PlannedService {
            tenants,
            plan: plan.clone(),
            policy,
        })
    }

    /// PJRT when `artifact_dir/manifest.json` exists, [`SimBackend`] on the
    /// zoo network named `net` otherwise (8-bit only — the sim datapath is
    /// the i8 reference).
    pub fn start_auto(
        artifact_dir: impl Into<PathBuf>,
        net: &str,
        bits: usize,
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let dir = artifact_dir.into();
        if dir.join("manifest.json").exists() {
            Self::start(dir, net, bits, policy)
        } else {
            anyhow::ensure!(
                bits == 8,
                "no artifacts at {} and the SimBackend fallback serves 8-bit only",
                dir.display()
            );
            let net = crate::model::zoo::by_name(net)?;
            Self::start_sim(&net, SIM_BATCHES, policy)
        }
    }

    /// Start serving on any [`Backend`]. The factory runs on the worker
    /// thread (backends need not be `Send`; PJRT clients are not); startup
    /// errors and the backend's frame geometry surface through a
    /// ready-handshake, after every variant has been warmed once.
    pub fn start_with<F>(factory: F, policy: BatchPolicy) -> crate::Result<Coordinator>
    where
        F: FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<usize>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let running = Arc::new(AtomicBool::new(true));
        let health = Arc::new(AtomicU8::new(Health::Healthy as u8));
        let timeout = policy.request_timeout;
        let worker = {
            let stats = stats.clone();
            let running = running.clone();
            let health = health.clone();
            std::thread::spawn(move || {
                // Build + warm the backend inside the worker.
                let be = match factory() {
                    Ok(be) => be,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                if be.variants().is_empty() {
                    // Guard the batcher's `variants[0]` fallback: a custom
                    // backend with no batch variants must fail the
                    // handshake, not panic on the first submit.
                    let _ = ready_tx.send(Err(anyhow::anyhow!(
                        "backend '{}' exposes no batch variants",
                        be.platform()
                    )));
                    return;
                }
                let frame_elems = be.frame_elems();
                for (name, batch) in be.variants() {
                    if let Err(e) = be.execute_i8(&name, &vec![0i8; batch * frame_elems]) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(frame_elems));
                worker_loop(be, policy, rx, stats, running, health)
            })
        };
        let frame_elems = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker died during startup"))??;
        Ok(Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            stats,
            frame_elems,
            running,
            health,
            request_timeout: timeout,
        })
    }

    /// Current serving health (see [`Health`] for the transitions).
    pub fn health(&self) -> Health {
        Health::from_u8(self.health.load(Ordering::SeqCst))
    }

    /// Submit one frame; returns a receiver for the result. A tenant in
    /// [`Health::Shedding`] refuses new work up front — queueing onto a
    /// persistently failing backend would only grow an unserved backlog.
    pub fn submit(&self, frame: Vec<i8>) -> crate::Result<Receiver<crate::Result<Vec<i8>>>> {
        anyhow::ensure!(
            self.health() != Health::Shedding,
            "tenant is shedding load ({SHED_AFTER} consecutive batches failed) — apply a \
             replanned deployment to restore service"
        );
        anyhow::ensure!(
            frame.len() == self.frame_elems,
            "frame must have {} elements, got {}",
            self.frame_elems,
            frame.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("coordinator running")
            .send(Request {
                frame,
                enqueued: Instant::now(),
                resp: rtx,
            })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Submit and wait, honoring [`BatchPolicy::request_timeout`].
    pub fn infer(&self, frame: Vec<i8>) -> crate::Result<Vec<i8>> {
        let rx = self.submit(frame)?;
        match self.request_timeout {
            None => rx
                .recv()
                .map_err(|_| anyhow::anyhow!("coordinator dropped request"))?,
            Some(t) => match recv_deadline(&rx, t) {
                Ok(result) => result,
                Err(RecvTimeoutError::Timeout) => {
                    anyhow::bail!("request timed out after {t:?}")
                }
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("coordinator dropped request")
                }
            },
        }
    }

    /// Snapshot the stats.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the worker and return final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let s = self.stats.lock().unwrap().clone();
        s
    }
}

/// A serving fleet executing one deployment plan: one [`Coordinator`] per
/// tenant, created by [`Coordinator::start_planned`]. Tenants are
/// addressed by plan index (names may repeat — two `lenet` tenants are
/// two queues). The service keeps its plan, so a failover delta
/// ([`crate::fault::PlanDiff`]) can be executed live with
/// [`PlannedService::apply`].
pub struct PlannedService {
    tenants: Vec<(String, Coordinator)>,
    plan: crate::plan::DeploymentPlan,
    policy: BatchPolicy,
}

/// What [`PlannedService::apply`] did to each tenant, by model name.
#[derive(Debug, Clone, Default)]
pub struct ApplyReport {
    /// Tenants carried over untouched (their queues and stats survive).
    pub kept: Vec<String>,
    /// Tenants whose slice changed — worker restarted on the new config.
    pub restarted: Vec<String>,
    /// Tenants newly admitted by the target plan.
    pub added: Vec<String>,
    /// Tenants the target plan dropped — workers shut down.
    pub removed: Vec<String>,
}

impl ApplyReport {
    /// Serialize (deterministic field order). The control plane returns
    /// this document from `POST /plan/apply` and `POST /replan`, and the
    /// acceptance tests compare it bitwise against direct
    /// [`PlannedService::apply`] calls.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        let list = |v: &[String]| Value::Arr(v.iter().map(|s| Value::Str(s.clone())).collect());
        obj(vec![
            ("kept", list(&self.kept)),
            ("restarted", list(&self.restarted)),
            ("added", list(&self.added)),
            ("removed", list(&self.removed)),
        ])
    }
}

impl PlannedService {
    /// The deployment plan this service is currently executing.
    pub fn plan(&self) -> &crate::plan::DeploymentPlan {
        &self.plan
    }

    /// Execute a plan diff live: the service transitions to
    /// `self.plan().apply(diff)` with minimal disruption — kept tenants'
    /// coordinators (queues, stats, health) survive untouched; changed
    /// and added tenants get freshly started workers; removed tenants
    /// are shut down. All incoming workers are started (and the target
    /// plan fully validated) **before** anything is torn down, so a
    /// failed apply leaves the service exactly as it was.
    pub fn apply(&mut self, diff: &crate::fault::PlanDiff) -> crate::Result<ApplyReport> {
        use crate::fault::TenantOp;
        let new_plan = self.plan.apply(diff)?;
        anyhow::ensure!(
            new_plan.mode.bits() == 8,
            "the applied plan must stay 8-bit (the in-process SimBackend is the i8 \
             reference datapath)"
        );
        new_plan.instantiate()?;
        // Pre-start every incoming worker; nothing is committed yet, so
        // an error here (backend refuses the network, say) aborts with
        // the service untouched — the started workers just drop.
        let mut incoming: Vec<Coordinator> = Vec::new();
        for op in &diff.ops {
            if let TenantOp::Change { tenant, .. } | TenantOp::Add { tenant, .. } = op {
                incoming.push(Coordinator::start_sim(
                    &tenant.net,
                    SIM_BATCHES,
                    self.policy.clone(),
                )?);
            }
        }
        // Commit: rebuild the tenant list in target-plan order.
        // `DeploymentPlan::apply` already validated that each source
        // index is in range and claimed at most once.
        let mut old: Vec<Option<(String, Coordinator)>> =
            self.tenants.drain(..).map(Some).collect();
        let mut incoming = incoming.into_iter();
        let mut report = ApplyReport::default();
        let mut next = Vec::with_capacity(diff.ops.len());
        for op in &diff.ops {
            match op {
                TenantOp::Keep { from } => {
                    let (name, coord) = old[*from].take().expect("apply validated ops");
                    report.kept.push(name.clone());
                    next.push((name, coord));
                }
                TenantOp::Change { from, tenant, .. } => {
                    let (name, coord) = old[*from].take().expect("apply validated ops");
                    coord.shutdown();
                    report.restarted.push(name);
                    next.push((
                        tenant.net.name.clone(),
                        incoming.next().expect("one incoming worker per change/add"),
                    ));
                }
                TenantOp::Add { tenant, .. } => {
                    report.added.push(tenant.net.name.clone());
                    next.push((
                        tenant.net.name.clone(),
                        incoming.next().expect("one incoming worker per change/add"),
                    ));
                }
            }
        }
        for slot in old.into_iter().flatten() {
            let (name, coord) = slot;
            coord.shutdown();
            report.removed.push(name);
        }
        self.tenants = next;
        self.plan = new_plan;
        Ok(report)
    }
    /// Number of tenants being served.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Is the service empty? (Never true for a valid plan.)
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Tenant model names, in plan order.
    pub fn names(&self) -> Vec<&str> {
        self.tenants.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The coordinator serving tenant `idx` (plan order) — submit frames
    /// through it like any coordinator.
    pub fn tenant(&self, idx: usize) -> &Coordinator {
        &self.tenants[idx].1
    }

    /// Submit one frame to tenant `idx` and wait for its output.
    pub fn infer(&self, idx: usize, frame: Vec<i8>) -> crate::Result<Vec<i8>> {
        self.tenants[idx].1.infer(frame)
    }

    /// Stop every tenant's worker; returns `(name, stats)` per tenant in
    /// plan order.
    pub fn shutdown(self) -> Vec<(String, ServeStats)> {
        self.tenants
            .into_iter()
            .map(|(name, coord)| {
                let stats = coord.shutdown();
                (name, stats)
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    be: Box<dyn Backend>,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    stats: Arc<Mutex<ServeStats>>,
    running: Arc<AtomicBool>,
    health: Arc<AtomicU8>,
) {
    let variants = be.variants(); // sorted by batch ascending
    let frame_elems = be.frame_elems();
    let max_batch = variants.last().map(|v| v.1).unwrap_or(1);
    let mut queue: Vec<Request> = Vec::new();
    let mut consecutive_failures: u32 = 0;
    'serve: loop {
        // Fill the queue up to max_batch or until max_wait expires. The
        // deadline read goes through `recv_deadline`: a request that
        // landed exactly as the window closed still joins this batch
        // instead of waiting a whole extra fill cycle.
        let deadline = Instant::now() + policy.max_wait;
        while queue.len() < max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match recv_deadline(&rx, timeout) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if queue.is_empty() {
                        break 'serve;
                    }
                    break;
                }
            }
        }
        if queue.is_empty() {
            if !running.load(Ordering::SeqCst) {
                break;
            }
            continue;
        }

        // Dynamic batching: largest compiled batch ≤ queue depth; if even
        // the smallest is larger than the queue, pad the smallest.
        let (name, batch) = variants
            .iter()
            .rev()
            .find(|(_, b)| *b <= queue.len())
            .unwrap_or(&variants[0])
            .clone();
        let used = batch.min(queue.len());
        let take: Vec<Request> = queue.drain(..used).collect();

        // Assemble (and pad) the input buffer.
        let mut input = vec![0i8; batch * frame_elems];
        for (i, r) in take.iter().enumerate() {
            input[i * frame_elems..(i + 1) * frame_elems].copy_from_slice(&r.frame);
        }
        if !policy.link_latency.is_zero() {
            std::thread::sleep(policy.link_latency); // PCIe transfer model
        }
        // Bounded retry with exponential backoff: transient backend
        // errors (a dropped PJRT execution, a glitching link) must not
        // fail a whole batch of requests.
        let mut attempts = 1;
        let mut result = be.execute_i8(&name, &input);
        while result.is_err() && attempts <= policy.max_retries {
            let backoff = policy
                .retry_backoff
                .saturating_mul(1u32 << (attempts - 1).min(16) as u32);
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            result = be.execute_i8(&name, &input);
            attempts += 1;
        }

        let now = Instant::now();
        match result {
            Ok(out) => {
                consecutive_failures = 0;
                health.store(Health::Healthy as u8, Ordering::SeqCst);
                let out_elems = out.len() / batch;
                let mut st = stats.lock().unwrap();
                st.record_batch(batch, used);
                for (i, r) in take.into_iter().enumerate() {
                    st.requests += 1;
                    st.latencies_us
                        .push(now.duration_since(r.enqueued).as_micros() as u64);
                    let _ = r
                        .resp
                        .send(Ok(out[i * out_elems..(i + 1) * out_elems].to_vec()));
                }
            }
            Err(e) => {
                consecutive_failures += 1;
                let next = if consecutive_failures >= SHED_AFTER {
                    Health::Shedding
                } else {
                    Health::Degraded
                };
                health.store(next as u8, Ordering::SeqCst);
                let msg = format!("backend failed after {attempts} attempts: {e}");
                for r in take {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.latencies_us = vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(s.latency_us(0.0), 10);
        assert_eq!(s.latency_us(50.0), 50);
        assert_eq!(s.latency_us(100.0), 100);
        assert_eq!(ServeStats::default().latency_us(50.0), 0);
    }

    #[test]
    fn record_batch_tracks_padding() {
        let mut s = ServeStats::default();
        s.record_batch(8, 5);
        s.record_batch(8, 8);
        assert_eq!(s.padded_frames, 3);
        assert_eq!(s.batch_sizes, vec![(8, 13)]);
    }

    #[test]
    fn sim_backed_coordinator_answers_like_the_oracle() {
        use crate::model::zoo;
        use crate::runtime::SimBackend;
        let coord =
            Coordinator::start_sim(&zoo::tinycnn(), &[1, 2], BatchPolicy::default()).unwrap();
        let oracle = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
        let frame = vec![1i8; oracle.frame_elems()];
        let want = oracle.forward_frame(&frame).unwrap();
        assert_eq!(coord.infer(frame).unwrap(), want);
        assert!(coord.submit(vec![0i8; 5]).is_err());
    }

    #[test]
    fn start_planned_serves_every_plan_tenant() {
        use crate::board::zedboard;
        use crate::model::zoo;
        use crate::plan::{Planner, Workload};
        use crate::quant::QuantMode;
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let plan = set.plans[set.best].clone();
        let svc = Coordinator::start_planned(&plan, BatchPolicy::default()).unwrap();
        assert_eq!(svc.len(), 2);
        assert!(!svc.is_empty());
        assert_eq!(svc.names(), vec!["tinycnn", "lenet"]);
        for (t, pt) in plan.tenants.iter().enumerate() {
            let (c, h, w) = pt.net.input;
            let out = svc.infer(t, vec![0i8; c * h * w]).unwrap();
            assert!(!out.is_empty(), "tenant {t} served nothing");
        }
        let stats = svc.shutdown();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|(_, s)| s.requests == 1));
        // Non-8-bit plans are refused up front (SimBackend is the i8
        // reference datapath).
        let mut p16 = plan.clone();
        p16.mode = QuantMode::W16A16;
        assert!(Coordinator::start_planned(&p16, BatchPolicy::default()).is_err());
    }

    #[test]
    fn start_auto_falls_back_to_sim_without_artifacts() {
        let dir = std::env::temp_dir().join("flexipipe_no_artifacts_here");
        std::fs::create_dir_all(&dir).unwrap();
        let coord = Coordinator::start_auto(&dir, "lenet", 8, BatchPolicy::default()).unwrap();
        let out = coord.infer(vec![0i8; 28 * 28]).unwrap();
        assert!(!out.is_empty());
        // 16-bit has no sim fallback.
        assert!(Coordinator::start_auto(&dir, "lenet", 16, BatchPolicy::default()).is_err());
    }

    /// A [`SimBackend`] whose execute calls in `fail_from ..
    /// fail_from + fail_count` (0-based call index, warm-up included)
    /// fail with a transient error. The single worker thread makes the
    /// `Cell` counter safe.
    struct FlakyBackend {
        inner: SimBackend,
        calls: std::cell::Cell<usize>,
        fail_from: usize,
        fail_count: usize,
        delay: Duration,
    }

    impl FlakyBackend {
        fn start(
            fail_from: usize,
            fail_count: usize,
            delay: Duration,
            policy: BatchPolicy,
        ) -> Coordinator {
            use crate::model::zoo;
            Coordinator::start_with(
                move || {
                    Ok(Box::new(FlakyBackend {
                        inner: SimBackend::new(&zoo::tinycnn(), &[1])?,
                        calls: std::cell::Cell::new(0),
                        fail_from,
                        fail_count,
                        delay,
                    }) as Box<dyn Backend>)
                },
                policy,
            )
            .unwrap()
        }
    }

    impl Backend for FlakyBackend {
        fn platform(&self) -> String {
            "flaky-sim".to_string()
        }
        fn variants(&self) -> Vec<(String, usize)> {
            self.inner.variants()
        }
        fn frame_elems(&self) -> usize {
            self.inner.frame_elems()
        }
        fn out_elems(&self) -> usize {
            self.inner.out_elems()
        }
        fn execute_i8(&self, name: &str, frames: &[i8]) -> crate::Result<Vec<i8>> {
            let n = self.calls.get();
            self.calls.set(n + 1);
            if !self.delay.is_zero() && n >= 1 {
                std::thread::sleep(self.delay);
            }
            if n >= self.fail_from && n < self.fail_from.saturating_add(self.fail_count) {
                anyhow::bail!("transient backend fault (call {n})");
            }
            self.inner.execute_i8(name, frames)
        }
    }

    #[test]
    fn bounded_retry_recovers_from_a_transient_burst() {
        use crate::model::zoo;
        // Warm-up is call 0; the burst hits calls 1-2, so the first real
        // batch needs two retries to land.
        let policy = BatchPolicy {
            max_retries: 3,
            retry_backoff: Duration::from_micros(100),
            ..BatchPolicy::default()
        };
        let coord = FlakyBackend::start(1, 2, Duration::ZERO, policy);
        let oracle = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
        let frame = vec![1i8; oracle.frame_elems()];
        let want = oracle.forward_frame(&frame).unwrap();
        assert_eq!(coord.infer(frame).unwrap(), want);
        assert_eq!(coord.health(), Health::Healthy);
        assert_eq!(coord.stats().requests, 1);
    }

    #[test]
    fn persistent_failures_degrade_then_shed() {
        // Every post-warm-up call fails and retries are disabled: each
        // batch fails once, so health walks Healthy → Degraded →
        // Shedding in SHED_AFTER batches, after which submissions are
        // refused fast.
        let policy = BatchPolicy {
            max_retries: 0,
            retry_backoff: Duration::ZERO,
            ..BatchPolicy::default()
        };
        let coord = FlakyBackend::start(1, usize::MAX, Duration::ZERO, policy);
        let frame = vec![0i8; coord.frame_elems];
        assert_eq!(coord.health(), Health::Healthy);
        for i in 1..=SHED_AFTER {
            let err = coord.infer(frame.clone()).unwrap_err();
            assert!(
                err.to_string().contains("after 1 attempts"),
                "attempt count missing: {err}"
            );
            let want = if i < SHED_AFTER {
                Health::Degraded
            } else {
                Health::Shedding
            };
            assert_eq!(coord.health(), want, "after {i} failed batches");
        }
        let err = coord.infer(frame).unwrap_err();
        assert!(err.to_string().contains("shedding"), "{err}");
    }

    #[test]
    fn recv_deadline_drains_a_result_arriving_exactly_at_the_deadline() {
        // The exact-at-the-deadline limit: the deadline has fully elapsed
        // (zero remaining timeout) but the result is already in the
        // channel. The raw `recv_timeout(ZERO)` reports Timeout here;
        // `recv_deadline` must hand the message over instead.
        let (tx, rx) = mpsc::channel();
        tx.send(42u32).unwrap();
        assert_eq!(recv_deadline(&rx, Duration::ZERO), Ok(42));
        // An empty channel at expiry is still a real timeout…
        assert_eq!(
            recv_deadline(&rx, Duration::ZERO),
            Err(RecvTimeoutError::Timeout)
        );
        // …and a hung-up channel surfaces as Disconnected, drained
        // messages first.
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(recv_deadline(&rx, Duration::ZERO), Ok(7));
        assert_eq!(
            recv_deadline(&rx, Duration::ZERO),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn completed_request_at_deadline_is_not_a_timeout() {
        // Regression for the infer() race: the worker completes the
        // request and sends the result, then the caller's deadline
        // expires before it observes the message. With a zero request
        // timeout every recv_timeout returns Timeout immediately, so
        // only the try_recv drain can ever deliver — pre-fix this
        // reported "timed out" for work that had already finished.
        use crate::model::zoo;
        use crate::runtime::SimBackend;
        let policy = BatchPolicy {
            request_timeout: Some(Duration::ZERO),
            ..BatchPolicy::default()
        };
        let coord = Coordinator::start_sim(&zoo::tinycnn(), &[1], policy).unwrap();
        let oracle = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
        let frame = vec![1i8; oracle.frame_elems()];
        let want = oracle.forward_frame(&frame).unwrap();
        let rx = coord.submit(frame).unwrap();
        // Wait until the result is definitely in the channel, then take
        // the zero-remaining-timeout path infer() takes.
        let result = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("worker must answer")
            .unwrap();
        assert_eq!(result, want);
        // And end-to-end: a zero max_wait exercises the worker fill
        // loop's expired-deadline drain on every batch; requests must
        // still be served, never dropped as spurious fill timeouts.
        let policy = BatchPolicy {
            max_wait: Duration::ZERO,
            ..BatchPolicy::default()
        };
        let coord = Coordinator::start_sim(&zoo::tinycnn(), &[1], policy).unwrap();
        for _ in 0..3 {
            assert_eq!(coord.infer(vec![1i8; oracle.frame_elems()]).unwrap(), want);
        }
    }

    #[test]
    fn request_timeout_bounds_the_wait() {
        // The backend stalls 200 ms per post-warm-up call; a 5 ms
        // request timeout must surface as a timeout error instead of
        // blocking the caller.
        let policy = BatchPolicy {
            max_retries: 0,
            request_timeout: Some(Duration::from_millis(5)),
            ..BatchPolicy::default()
        };
        let coord = FlakyBackend::start(usize::MAX, 0, Duration::from_millis(200), policy);
        let err = coord.infer(vec![0i8; coord.frame_elems]).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn apply_executes_a_plan_diff_live() {
        use crate::board::zedboard;
        use crate::model::zoo;
        use crate::plan::{Planner, Workload};
        use crate::quant::QuantMode;
        let planner = Planner::on(zedboard()).steps(8);
        let a = {
            let w = Workload::new(QuantMode::W8A8)
                .tenant(zoo::tinycnn())
                .tenant(zoo::lenet());
            let set = planner.plan(&w).unwrap();
            set.plans[set.best].clone()
        };
        let b = {
            let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn());
            let set = planner.plan(&w).unwrap();
            set.plans[set.best].clone()
        };
        let mut svc = Coordinator::start_planned(&a, BatchPolicy::default()).unwrap();
        assert_eq!(svc.names(), vec!["tinycnn", "lenet"]);
        let diff = a.diff(&b).unwrap();
        let report = svc.apply(&diff).unwrap();
        // tinycnn's slice changed (solo plan → different θ and record):
        // restarted; lenet is gone: removed.
        assert_eq!(report.removed, vec!["lenet".to_string()]);
        assert_eq!(report.kept.len() + report.restarted.len(), 1);
        assert_eq!(svc.names(), vec!["tinycnn"]);
        // The live service now executes exactly plan b.
        assert_eq!(
            svc.plan().to_json().to_pretty(),
            b.to_json().to_pretty(),
            "apply must land byte-identically on the target plan"
        );
        let (c, h, w) = b.tenants[0].net.input;
        assert!(!svc.infer(0, vec![0i8; c * h * w]).unwrap().is_empty());
        svc.shutdown();
    }

    #[test]
    fn apply_rejects_a_bad_diff_and_leaves_the_service_running() {
        use crate::board::zedboard;
        use crate::fault::{PlanDiff, TenantOp};
        use crate::model::zoo;
        use crate::plan::{Planner, Workload};
        use crate::quant::QuantMode;
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let plan = set.plans[set.best].clone();
        let mut svc = Coordinator::start_planned(&plan, BatchPolicy::default()).unwrap();
        let bad = PlanDiff {
            ops: vec![TenantOp::Keep { from: 7 }],
            removed: Vec::new(),
            board: None,
            mode: None,
            steps: None,
            regime: None,
            reconfig_model: None,
        };
        let err = svc.apply(&bad).unwrap_err();
        assert!(err.to_string().contains("source tenant 7"), "{err}");
        // Untouched: both tenants still serve.
        assert_eq!(svc.len(), 2);
        let (c, h, w) = plan.tenants[0].net.input;
        assert!(!svc.infer(0, vec![0i8; c * h * w]).unwrap().is_empty());
        svc.shutdown();
    }
}
