//! Frame-serving coordinator: the Fig. 4 demo system (host ↔ accelerator)
//! as a multithreaded server.
//!
//! The paper's host PC streams input frames over PCIe into DDR, kicks the
//! accelerator, and drains output activations ("sends more input frames
//! continuously", Sec. 5.1). Here the accelerator is a [`Backend`] —
//! the PJRT-compiled artifact when `artifacts/manifest.json` exists, the
//! deterministic in-process [`SimBackend`] otherwise; the coordinator owns:
//!
//! - an ingest queue ([`Coordinator::submit`] is the host-side API),
//! - a **dynamic batcher**: the backend serves several batch sizes
//!   (`tinycnn_b1/b4/b8`); the worker picks the largest available batch
//!   ≤ the queue depth, padding only when a timeout forces a partial batch,
//! - the execute worker (one thread — PJRT CPU executions are already
//!   internally parallel),
//! - latency/throughput metrics ([`ServeStats`]).
//!
//! The backend is built *inside* the worker thread by a `Send` factory
//! closure ([`Coordinator::start_with`]) — PJRT clients are `!Send`, so
//! only the recipe crosses the thread boundary, never the client.
//!
//! No tokio in the offline vendor set: std threads + channels. The queue
//! and stats are the same shape a tokio implementation would have.

use crate::model::Network;
use crate::runtime::{Backend, PjrtBackend, SimBackend, SIM_BATCHES};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max time the batcher waits to fill a larger batch before running a
    /// padded partial one.
    pub max_wait: Duration,
    /// Simulated host-link (PCIe) latency added per request (the demo
    /// system's transfer cost; 0 disables).
    pub link_latency: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_wait: Duration::from_millis(2),
            link_latency: Duration::ZERO,
        }
    }
}

/// One in-flight request.
struct Request {
    frame: Vec<i8>,
    enqueued: Instant,
    resp: Sender<crate::Result<Vec<i8>>>,
}

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Frames served.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Histogram source: per-request latencies (µs).
    pub latencies_us: Vec<u64>,
    /// Frames executed per batch size (batch → count).
    pub batch_sizes: Vec<(usize, u64)>,
    /// Padded (wasted) frame slots.
    pub padded_frames: u64,
}

impl ServeStats {
    fn record_batch(&mut self, batch: usize, used: usize) {
        self.batches += 1;
        self.padded_frames += (batch - used) as u64;
        match self.batch_sizes.iter_mut().find(|(b, _)| *b == batch) {
            Some((_, c)) => *c += used as u64,
            None => self.batch_sizes.push((batch, used as u64)),
        }
    }

    /// Latency percentile in µs (p in [0,100]).
    pub fn latency_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p / 100.0).floor() as usize;
        v[idx]
    }
}

/// The frame server.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    frame_elems: usize,
    running: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start serving `net` at `bits` from an artifact directory (the PJRT
    /// path). Validation (manifest present, variants exist) lives in
    /// [`PjrtBackend::open`]; its errors surface through
    /// [`Coordinator::start_with`]'s ready-handshake.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        net: &str,
        bits: usize,
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let dir = artifact_dir.into();
        let net = net.to_string();
        Self::start_with(
            move || PjrtBackend::open(dir, &net, bits).map(|b| Box::new(b) as Box<dyn Backend>),
            policy,
        )
    }

    /// Start serving `net` through the artifact-free in-process
    /// [`SimBackend`] at the given batch sizes.
    pub fn start_sim(
        net: &Network,
        batches: &[usize],
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let net = net.clone();
        let batches = batches.to_vec();
        Self::start_with(
            move || SimBackend::new(&net, &batches).map(|b| Box::new(b) as Box<dyn Backend>),
            policy,
        )
    }

    /// Serve every tenant of a [`DeploymentPlan`] on the in-process
    /// [`SimBackend`] — the serving half of the plan-centric flow
    /// (`flexipipe serve --plan plan.json`). The plan is **validated
    /// before anything starts serving**: every tenant's allocation is
    /// rehydrated ([`DeploymentPlan::instantiate`]), so an infeasible or
    /// stale plan is refused with the real cause instead of serving a
    /// deployment the planner never admitted. One coordinator (ingest
    /// queue + dynamic batcher + worker) is started per tenant, each on a
    /// deterministic `SimBackend` over the tenant's embedded network —
    /// 8-bit plans only, since the sim datapath is the i8 reference.
    ///
    /// [`DeploymentPlan`]: crate::plan::DeploymentPlan
    /// [`DeploymentPlan::instantiate`]: crate::plan::DeploymentPlan::instantiate
    pub fn start_planned(
        plan: &crate::plan::DeploymentPlan,
        policy: BatchPolicy,
    ) -> crate::Result<PlannedService> {
        anyhow::ensure!(
            plan.mode.bits() == 8,
            "start_planned serves the in-process SimBackend, which runs the 8-bit \
             reference datapath — re-plan the workload at --bits 8 (or serve \
             compiled artifacts per tenant via Coordinator::start)"
        );
        plan.instantiate()?;
        let mut tenants = Vec::with_capacity(plan.tenants.len());
        for t in &plan.tenants {
            let coord = Coordinator::start_sim(&t.net, SIM_BATCHES, policy.clone())?;
            tenants.push((t.net.name.clone(), coord));
        }
        Ok(PlannedService { tenants })
    }

    /// PJRT when `artifact_dir/manifest.json` exists, [`SimBackend`] on the
    /// zoo network named `net` otherwise (8-bit only — the sim datapath is
    /// the i8 reference).
    pub fn start_auto(
        artifact_dir: impl Into<PathBuf>,
        net: &str,
        bits: usize,
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let dir = artifact_dir.into();
        if dir.join("manifest.json").exists() {
            Self::start(dir, net, bits, policy)
        } else {
            anyhow::ensure!(
                bits == 8,
                "no artifacts at {} and the SimBackend fallback serves 8-bit only",
                dir.display()
            );
            let net = crate::model::zoo::by_name(net)?;
            Self::start_sim(&net, SIM_BATCHES, policy)
        }
    }

    /// Start serving on any [`Backend`]. The factory runs on the worker
    /// thread (backends need not be `Send`; PJRT clients are not); startup
    /// errors and the backend's frame geometry surface through a
    /// ready-handshake, after every variant has been warmed once.
    pub fn start_with<F>(factory: F, policy: BatchPolicy) -> crate::Result<Coordinator>
    where
        F: FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<usize>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let running = Arc::new(AtomicBool::new(true));
        let worker = {
            let stats = stats.clone();
            let running = running.clone();
            std::thread::spawn(move || {
                // Build + warm the backend inside the worker.
                let be = match factory() {
                    Ok(be) => be,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                if be.variants().is_empty() {
                    // Guard the batcher's `variants[0]` fallback: a custom
                    // backend with no batch variants must fail the
                    // handshake, not panic on the first submit.
                    let _ = ready_tx.send(Err(anyhow::anyhow!(
                        "backend '{}' exposes no batch variants",
                        be.platform()
                    )));
                    return;
                }
                let frame_elems = be.frame_elems();
                for (name, batch) in be.variants() {
                    if let Err(e) = be.execute_i8(&name, &vec![0i8; batch * frame_elems]) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(frame_elems));
                worker_loop(be, policy, rx, stats, running)
            })
        };
        let frame_elems = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker died during startup"))??;
        Ok(Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            stats,
            frame_elems,
            running,
        })
    }

    /// Submit one frame; returns a receiver for the result.
    pub fn submit(&self, frame: Vec<i8>) -> crate::Result<Receiver<crate::Result<Vec<i8>>>> {
        anyhow::ensure!(
            frame.len() == self.frame_elems,
            "frame must have {} elements, got {}",
            self.frame_elems,
            frame.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("coordinator running")
            .send(Request {
                frame,
                enqueued: Instant::now(),
                resp: rtx,
            })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn infer(&self, frame: Vec<i8>) -> crate::Result<Vec<i8>> {
        self.submit(frame)?
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request"))?
    }

    /// Snapshot the stats.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the worker and return final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let s = self.stats.lock().unwrap().clone();
        s
    }
}

/// A serving fleet executing one deployment plan: one [`Coordinator`] per
/// tenant, created by [`Coordinator::start_planned`]. Tenants are
/// addressed by plan index (names may repeat — two `lenet` tenants are
/// two queues).
pub struct PlannedService {
    tenants: Vec<(String, Coordinator)>,
}

impl PlannedService {
    /// Number of tenants being served.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Is the service empty? (Never true for a valid plan.)
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Tenant model names, in plan order.
    pub fn names(&self) -> Vec<&str> {
        self.tenants.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The coordinator serving tenant `idx` (plan order) — submit frames
    /// through it like any coordinator.
    pub fn tenant(&self, idx: usize) -> &Coordinator {
        &self.tenants[idx].1
    }

    /// Submit one frame to tenant `idx` and wait for its output.
    pub fn infer(&self, idx: usize, frame: Vec<i8>) -> crate::Result<Vec<i8>> {
        self.tenants[idx].1.infer(frame)
    }

    /// Stop every tenant's worker; returns `(name, stats)` per tenant in
    /// plan order.
    pub fn shutdown(self) -> Vec<(String, ServeStats)> {
        self.tenants
            .into_iter()
            .map(|(name, coord)| {
                let stats = coord.shutdown();
                (name, stats)
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    be: Box<dyn Backend>,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    stats: Arc<Mutex<ServeStats>>,
    running: Arc<AtomicBool>,
) {
    let variants = be.variants(); // sorted by batch ascending
    let frame_elems = be.frame_elems();
    let max_batch = variants.last().map(|v| v.1).unwrap_or(1);
    let mut queue: Vec<Request> = Vec::new();
    'serve: loop {
        // Fill the queue up to max_batch or until max_wait expires.
        let deadline = Instant::now() + policy.max_wait;
        while queue.len() < max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if queue.is_empty() {
                        break 'serve;
                    }
                    break;
                }
            }
        }
        if queue.is_empty() {
            if !running.load(Ordering::SeqCst) {
                break;
            }
            continue;
        }

        // Dynamic batching: largest compiled batch ≤ queue depth; if even
        // the smallest is larger than the queue, pad the smallest.
        let (name, batch) = variants
            .iter()
            .rev()
            .find(|(_, b)| *b <= queue.len())
            .unwrap_or(&variants[0])
            .clone();
        let used = batch.min(queue.len());
        let take: Vec<Request> = queue.drain(..used).collect();

        // Assemble (and pad) the input buffer.
        let mut input = vec![0i8; batch * frame_elems];
        for (i, r) in take.iter().enumerate() {
            input[i * frame_elems..(i + 1) * frame_elems].copy_from_slice(&r.frame);
        }
        if !policy.link_latency.is_zero() {
            std::thread::sleep(policy.link_latency); // PCIe transfer model
        }
        let result = be.execute_i8(&name, &input);

        let now = Instant::now();
        match result {
            Ok(out) => {
                let out_elems = out.len() / batch;
                let mut st = stats.lock().unwrap();
                st.record_batch(batch, used);
                for (i, r) in take.into_iter().enumerate() {
                    st.requests += 1;
                    st.latencies_us
                        .push(now.duration_since(r.enqueued).as_micros() as u64);
                    let _ = r
                        .resp
                        .send(Ok(out[i * out_elems..(i + 1) * out_elems].to_vec()));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in take {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.latencies_us = vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(s.latency_us(0.0), 10);
        assert_eq!(s.latency_us(50.0), 50);
        assert_eq!(s.latency_us(100.0), 100);
        assert_eq!(ServeStats::default().latency_us(50.0), 0);
    }

    #[test]
    fn record_batch_tracks_padding() {
        let mut s = ServeStats::default();
        s.record_batch(8, 5);
        s.record_batch(8, 8);
        assert_eq!(s.padded_frames, 3);
        assert_eq!(s.batch_sizes, vec![(8, 13)]);
    }

    #[test]
    fn sim_backed_coordinator_answers_like_the_oracle() {
        use crate::model::zoo;
        use crate::runtime::SimBackend;
        let coord =
            Coordinator::start_sim(&zoo::tinycnn(), &[1, 2], BatchPolicy::default()).unwrap();
        let oracle = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
        let frame = vec![1i8; oracle.frame_elems()];
        let want = oracle.forward_frame(&frame).unwrap();
        assert_eq!(coord.infer(frame).unwrap(), want);
        assert!(coord.submit(vec![0i8; 5]).is_err());
    }

    #[test]
    fn start_planned_serves_every_plan_tenant() {
        use crate::board::zedboard;
        use crate::model::zoo;
        use crate::plan::{Planner, Workload};
        use crate::quant::QuantMode;
        let w = Workload::new(QuantMode::W8A8)
            .tenant(zoo::tinycnn())
            .tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let plan = set.plans[set.best].clone();
        let svc = Coordinator::start_planned(&plan, BatchPolicy::default()).unwrap();
        assert_eq!(svc.len(), 2);
        assert!(!svc.is_empty());
        assert_eq!(svc.names(), vec!["tinycnn", "lenet"]);
        for (t, pt) in plan.tenants.iter().enumerate() {
            let (c, h, w) = pt.net.input;
            let out = svc.infer(t, vec![0i8; c * h * w]).unwrap();
            assert!(!out.is_empty(), "tenant {t} served nothing");
        }
        let stats = svc.shutdown();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|(_, s)| s.requests == 1));
        // Non-8-bit plans are refused up front (SimBackend is the i8
        // reference datapath).
        let mut p16 = plan.clone();
        p16.mode = QuantMode::W16A16;
        assert!(Coordinator::start_planned(&p16, BatchPolicy::default()).is_err());
    }

    #[test]
    fn start_auto_falls_back_to_sim_without_artifacts() {
        let dir = std::env::temp_dir().join("flexipipe_no_artifacts_here");
        std::fs::create_dir_all(&dir).unwrap();
        let coord = Coordinator::start_auto(&dir, "lenet", 8, BatchPolicy::default()).unwrap();
        let out = coord.infer(vec![0i8; 28 * 28]).unwrap();
        assert!(!out.is_empty());
        // 16-bit has no sim fallback.
        assert!(Coordinator::start_auto(&dir, "lenet", 16, BatchPolicy::default()).is_err());
    }
}
