//! Frame-serving coordinator: the Fig. 4 demo system (host ↔ accelerator)
//! as a multithreaded server.
//!
//! The paper's host PC streams input frames over PCIe into DDR, kicks the
//! accelerator, and drains output activations ("sends more input frames
//! continuously", Sec. 5.1). Here the accelerator is the PJRT-compiled
//! artifact; the coordinator owns:
//!
//! - an ingest queue ([`Coordinator::submit`] is the host-side API),
//! - a **dynamic batcher**: artifacts are compiled at several batch sizes
//!   (`tinycnn_b1/b4/b8`); the worker picks the largest compiled batch
//!   ≤ the queue depth, padding only when a timeout forces a partial batch,
//! - the execute worker (one thread — PJRT CPU executions are already
//!   internally parallel),
//! - latency/throughput metrics ([`ServeStats`]).
//!
//! No tokio in the offline vendor set: std threads + channels. The queue
//! and stats are the same shape a tokio implementation would have.

use crate::runtime::{Manifest, Runtime};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max time the batcher waits to fill a larger batch before running a
    /// padded partial one.
    pub max_wait: Duration,
    /// Simulated host-link (PCIe) latency added per request (the demo
    /// system's transfer cost; 0 disables).
    pub link_latency: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_wait: Duration::from_millis(2),
            link_latency: Duration::ZERO,
        }
    }
}

/// One in-flight request.
struct Request {
    frame: Vec<i8>,
    enqueued: Instant,
    resp: Sender<crate::Result<Vec<i8>>>,
}

/// Aggregated serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// Histogram source: per-request latencies (µs).
    pub latencies_us: Vec<u64>,
    /// Frames executed per batch size (batch → count).
    pub batch_sizes: Vec<(usize, u64)>,
    /// Padded (wasted) frame slots.
    pub padded_frames: u64,
}

impl ServeStats {
    fn record_batch(&mut self, batch: usize, used: usize) {
        self.batches += 1;
        self.padded_frames += (batch - used) as u64;
        match self.batch_sizes.iter_mut().find(|(b, _)| *b == batch) {
            Some((_, c)) => *c += used as u64,
            None => self.batch_sizes.push((batch, used as u64)),
        }
    }

    /// Latency percentile in µs (p in [0,100]).
    pub fn latency_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * p / 100.0).floor() as usize;
        v[idx]
    }
}

/// The frame server.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    frame_elems: usize,
    running: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start serving `net` at `bits` from an artifact directory.
    ///
    /// The PJRT client is `!Send` (Rc internals in the xla crate), so the
    /// worker thread constructs and exclusively owns the [`Runtime`]; the
    /// caller-side handle only touches channels. Startup errors inside the
    /// worker (bad artifacts) surface through a ready-handshake.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        net: &str,
        bits: usize,
        policy: BatchPolicy,
    ) -> crate::Result<Coordinator> {
        let dir = artifact_dir.into();
        // Validate the manifest host-side first (cheap, better errors).
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let variants: Vec<(String, usize)> = manifest
            .variants(net, bits)
            .iter()
            .map(|a| (a.name.clone(), a.batch))
            .collect();
        anyhow::ensure!(
            !variants.is_empty(),
            "no artifacts for net '{net}' at {bits}-bit — run `make artifacts`"
        );
        let frame_elems = manifest.get(&variants[0].0)?.golden.frame_elems;

        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let running = Arc::new(AtomicBool::new(true));
        let worker = {
            let stats = stats.clone();
            let running = running.clone();
            std::thread::spawn(move || {
                // Build + warm the runtime inside the worker.
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for (name, _) in &variants {
                    let elems = rt.manifest().get(name).map(|a| a.input_elems());
                    let warm = elems.and_then(|n| rt.execute_i8(name, &vec![0i8; n]));
                    if let Err(e) = warm {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                worker_loop(rt, variants, frame_elems, policy, rx, stats, running)
            })
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker died during startup"))??;
        Ok(Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            stats,
            frame_elems,
            running,
        })
    }

    /// Submit one frame; returns a receiver for the result.
    pub fn submit(&self, frame: Vec<i8>) -> crate::Result<Receiver<crate::Result<Vec<i8>>>> {
        anyhow::ensure!(
            frame.len() == self.frame_elems,
            "frame must have {} elements, got {}",
            self.frame_elems,
            frame.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("coordinator running")
            .send(Request {
                frame,
                enqueued: Instant::now(),
                resp: rtx,
            })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn infer(&self, frame: Vec<i8>) -> crate::Result<Vec<i8>> {
        self.submit(frame)?
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request"))?
    }

    /// Snapshot the stats.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop the worker and return final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let s = self.stats.lock().unwrap().clone();
        s
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rt: Runtime,
    variants: Vec<(String, usize)>, // sorted by batch ascending
    frame_elems: usize,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    stats: Arc<Mutex<ServeStats>>,
    running: Arc<AtomicBool>,
) {
    let max_batch = variants.last().map(|v| v.1).unwrap_or(1);
    let mut queue: Vec<Request> = Vec::new();
    'serve: loop {
        // Fill the queue up to max_batch or until max_wait expires.
        let deadline = Instant::now() + policy.max_wait;
        while queue.len() < max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(r) => queue.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if queue.is_empty() {
                        break 'serve;
                    }
                    break;
                }
            }
        }
        if queue.is_empty() {
            if !running.load(Ordering::SeqCst) {
                break;
            }
            continue;
        }

        // Dynamic batching: largest compiled batch ≤ queue depth; if even
        // the smallest is larger than the queue, pad the smallest.
        let (name, batch) = variants
            .iter()
            .rev()
            .find(|(_, b)| *b <= queue.len())
            .unwrap_or(&variants[0])
            .clone();
        let used = batch.min(queue.len());
        let take: Vec<Request> = queue.drain(..used).collect();

        // Assemble (and pad) the input buffer.
        let mut input = vec![0i8; batch * frame_elems];
        for (i, r) in take.iter().enumerate() {
            input[i * frame_elems..(i + 1) * frame_elems].copy_from_slice(&r.frame);
        }
        if !policy.link_latency.is_zero() {
            std::thread::sleep(policy.link_latency); // PCIe transfer model
        }
        let result = rt.execute_i8(&name, &input);

        let now = Instant::now();
        match result {
            Ok(out) => {
                let out_elems = out.len() / batch;
                let mut st = stats.lock().unwrap();
                st.record_batch(batch, used);
                for (i, r) in take.into_iter().enumerate() {
                    st.requests += 1;
                    st.latencies_us
                        .push(now.duration_since(r.enqueued).as_micros() as u64);
                    let _ = r
                        .resp
                        .send(Ok(out[i * out_elems..(i + 1) * out_elems].to_vec()));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in take {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.latencies_us = vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(s.latency_us(0.0), 10);
        assert_eq!(s.latency_us(50.0), 50);
        assert_eq!(s.latency_us(100.0), 100);
        assert_eq!(ServeStats::default().latency_us(50.0), 0);
    }

    #[test]
    fn record_batch_tracks_padding() {
        let mut s = ServeStats::default();
        s.record_batch(8, 5);
        s.record_batch(8, 8);
        assert_eq!(s.padded_frames, 3);
        assert_eq!(s.batch_sizes, vec![(8, 13)]);
    }
}
