//! Table I regeneration: utilization + performance for the four paper
//! networks across the four architectures, with the paper's published
//! numbers printed alongside for comparison (EXPERIMENTS.md records both).

use crate::alloc::{allocator_for, ArchKind};
use crate::board::{zc706, Board};
use crate::model::{zoo, Network};
use crate::power::PowerModel;
use crate::quant::QuantMode;
use crate::sim;

/// One regenerated Table I column (a net × arch design point).
#[derive(Debug, Clone)]
pub struct Row {
    /// Network name.
    pub net: String,
    /// Architecture the row was allocated with.
    pub arch: ArchKind,
    /// Board clock in MHz.
    pub freq_mhz: f64,
    /// DSP slices used.
    pub dsps: usize,
    /// LUT utilization in percent of the board.
    pub lut_pct: f64,
    /// FF utilization in percent of the board.
    pub ff_pct: f64,
    /// BRAM utilization in percent of the board.
    pub bram_pct: f64,
    /// Achieved / peak MAC rate of the used DSPs (Table I's metric).
    pub dsp_efficiency: f64,
    /// Throughput at 16-bit (GOPS).
    pub gops_16b: f64,
    /// Frame rate at 16-bit (fps).
    pub fps_16b: f64,
    /// Throughput at 8-bit (GOPS).
    pub gops_8b: f64,
    /// Frame rate at 8-bit (fps).
    pub fps_8b: f64,
    /// Estimated power (W).
    pub power_w: f64,
    /// Energy efficiency at 16-bit (GOPS per watt).
    pub gops_per_w_16b: f64,
    /// Simulator cross-check: measured DSP efficiency.
    pub sim_dsp_efficiency: f64,
}

/// Paper Table I reference values: (net, reference label, dsp_eff %, GOPS
/// 16b, FPS 16b, GOPS 8b, power W). `None` = not reported ("/" in Table I).
pub struct PaperRef {
    /// Network name.
    pub net: &'static str,
    /// Reference design label (citation).
    pub label: &'static str,
    /// DSP slices the reference used.
    pub dsps: usize,
    /// Reported DSP efficiency (percent).
    pub dsp_eff: f64,
    /// Reported throughput at 16-bit (GOPS).
    pub gops_16b: f64,
    /// Reported frame rate at 16-bit (fps).
    pub fps_16b: f64,
    /// Reported throughput at 8-bit (GOPS), when given.
    pub gops_8b: Option<f64>,
    /// Reported power (W), when given.
    pub power_w: Option<f64>,
}

/// The published Table I (all on ZC706-class parts).
pub const PAPER_TABLE1: &[PaperRef] = &[
    PaperRef { net: "vgg16", label: "[1] recurrent", dsps: 780, dsp_eff: 0.585, gops_16b: 137.0, fps_16b: 4.4, gops_8b: Some(274.0), power_w: Some(9.63) },
    PaperRef { net: "vgg16", label: "[2] fusion", dsps: 824, dsp_eff: 0.696, gops_16b: 230.0, fps_16b: 7.4, gops_8b: None, power_w: Some(9.4) },
    PaperRef { net: "vgg16", label: "[3] DNNBuilder", dsps: 680, dsp_eff: 0.962, gops_16b: 262.0, fps_16b: 8.5, gops_8b: Some(524.0), power_w: Some(7.2) },
    PaperRef { net: "vgg16", label: "This Work", dsps: 900, dsp_eff: 0.980, gops_16b: 353.0, fps_16b: 11.3, gops_8b: Some(706.0), power_w: Some(7.2) },
    PaperRef { net: "alexnet", label: "[3] DNNBuilder", dsps: 808, dsp_eff: 0.763, gops_16b: 247.0, fps_16b: 170.0, gops_8b: Some(494.0), power_w: Some(7.2) },
    PaperRef { net: "alexnet", label: "This Work", dsps: 864, dsp_eff: 0.904, gops_16b: 312.0, fps_16b: 230.0, gops_8b: Some(624.0), power_w: Some(6.9) },
    PaperRef { net: "zf", label: "[3] DNNBuilder", dsps: 824, dsp_eff: 0.797, gops_16b: 263.0, fps_16b: 112.2, gops_8b: Some(526.0), power_w: None },
    PaperRef { net: "zf", label: "This Work", dsps: 892, dsp_eff: 0.908, gops_16b: 324.0, fps_16b: 138.4, gops_8b: Some(648.0), power_w: Some(7.1) },
    PaperRef { net: "yolo", label: "[3] DNNBuilder", dsps: 680, dsp_eff: 0.962, gops_16b: 234.0, fps_16b: 5.8, gops_8b: Some(468.0), power_w: None },
    PaperRef { net: "yolo", label: "This Work", dsps: 892, dsp_eff: 0.984, gops_16b: 351.0, fps_16b: 8.8, gops_8b: Some(702.0), power_w: Some(7.3) },
];

/// Build one design point (allocating, simulating, estimating power).
pub fn design_point(net: &Network, board: &Board, arch: ArchKind) -> crate::Result<Row> {
    let a16 = allocator_for(arch).allocate(net, board, QuantMode::W16A16)?;
    let r16 = a16.evaluate();
    let a8 = allocator_for(arch).allocate(net, board, QuantMode::W8A8)?;
    let r8 = a8.evaluate();
    let s16 = sim::simulate(&a16, 3);
    let power = PowerModel::default().estimate(&a16, &r16).total();
    Ok(Row {
        net: net.name.clone(),
        arch,
        freq_mhz: a16.freq_hz / 1e6,
        dsps: r16.dsps,
        lut_pct: 100.0 * r16.luts as f64 / board.luts as f64,
        ff_pct: 100.0 * r16.ffs as f64 / board.ffs as f64,
        bram_pct: 100.0 * r16.bram18 as f64 / board.bram18() as f64,
        dsp_efficiency: r16.dsp_efficiency,
        gops_16b: r16.gops,
        fps_16b: r16.fps,
        gops_8b: r8.gops,
        fps_8b: r8.fps,
        power_w: power,
        gops_per_w_16b: r16.gops / power,
        sim_dsp_efficiency: s16.dsp_efficiency,
    })
}

/// Regenerate the full Table I (4 nets × 4 architectures on ZC706).
pub fn table1() -> crate::Result<Vec<Row>> {
    let board = zc706();
    let mut rows = Vec::new();
    for net in zoo::paper_nets() {
        for arch in [
            ArchKind::Recurrent,
            ArchKind::Fusion,
            ArchKind::DnnBuilder,
            ArchKind::FlexPipeline,
        ] {
            rows.push(design_point(&net, &board, arch)?);
        }
    }
    Ok(rows)
}

/// Render rows as an aligned text table, paper references interleaved.
pub fn render(rows: &[Row], with_paper: bool) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:<16} {:>5} {:>5} {:>6} {:>6} {:>6} {:>8} {:>8} {:>7} {:>8} {:>7} {:>7} {:>8}\n",
        "model", "arch", "MHz", "DSP", "LUT%", "FF%", "BRAM%", "DSPeff%", "GOPS16", "FPS16",
        "GOPS8", "FPS8", "W", "GOPS/W"
    ));
    out.push_str(&"-".repeat(126));
    out.push('\n');
    let mut last_net = String::new();
    for r in rows {
        if with_paper && r.net != last_net {
            for p in PAPER_TABLE1.iter().filter(|p| p.net == r.net) {
                out.push_str(&format!(
                    "{:<10} {:<16} {:>5} {:>5} {:>6} {:>6} {:>6} {:>8.1} {:>8.0} {:>7.1} {:>8} {:>7} {:>7} {:>8}\n",
                    r.net,
                    format!("paper:{}", p.label),
                    "",
                    p.dsps,
                    "",
                    "",
                    "",
                    p.dsp_eff * 100.0,
                    p.gops_16b,
                    p.fps_16b,
                    p.gops_8b.map_or("/".into(), |g| format!("{g:.0}")),
                    "",
                    p.power_w.map_or("/".into(), |w| format!("{w:.1}")),
                    ""
                ));
            }
            last_net = r.net.clone();
        }
        out.push_str(&format!(
            "{:<10} {:<16} {:>5.0} {:>5} {:>6.1} {:>6.1} {:>6.1} {:>8.1} {:>8.0} {:>7.1} {:>8.0} {:>7.1} {:>7.2} {:>8.1}\n",
            r.net,
            r.arch.label(),
            r.freq_mhz,
            r.dsps,
            r.lut_pct,
            r.ff_pct,
            r.bram_pct,
            r.dsp_efficiency * 100.0,
            r.gops_16b,
            r.fps_16b,
            r.gops_8b,
            r.fps_8b,
            r.power_w,
            r.gops_per_w_16b
        ));
    }
    out
}

/// The paper's Sec. 5.2 headline ratios for VGG16 (this work vs [1],[2],[3]).
pub fn vgg16_speedups(rows: &[Row]) -> Option<(f64, f64, f64)> {
    let get = |a: ArchKind| {
        rows.iter()
            .find(|r| r.net == "vgg16" && r.arch == a)
            .map(|r| r.gops_16b)
    };
    let ours = get(ArchKind::FlexPipeline)?;
    Some((
        ours / get(ArchKind::Recurrent)?,
        ours / get(ArchKind::Fusion)?,
        ours / get(ArchKind::DnnBuilder)?,
    ))
}

/// Render a [`crate::ingest::ServeReport`] as an aligned text table: one
/// row per tenant with offered vs. plan-admitted load, admission
/// outcomes, the measured latency tail, and the p100-vs-analytic-bound
/// verdict (the human-facing companion of the machine-read JSON the
/// `serve --trace` command prints to stdout).
pub fn render_serve(report: &crate::ingest::ServeReport) -> String {
    let ms = |c: u64| c as f64 / report.freq_hz * 1e3;
    let mut out = String::new();
    out.push_str(&format!(
        "trace replay: seed {} | {} regime | {:.1} s at {:.0} MHz\n",
        report.seed,
        report.regime,
        report.duration_s,
        report.freq_hz / 1e6
    ));
    out.push_str(&format!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}\n",
        "tenant", "off/s", "plan/s", "admit", "reject", "p50 ms", "p99 ms", "p99.9 ms",
        "p100 ms", "bound ms", "in-SLO"
    ));
    out.push_str(&"-".repeat(103));
    out.push('\n');
    for t in &report.tenants {
        out.push_str(&format!(
            "{:<10} {:>8.2} {:>8.2} {:>8} {:>8} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9} {:>7}\n",
            t.net,
            t.offered_fps,
            t.plan_fps,
            t.admitted,
            t.rejected_full,
            ms(t.p50_cycles),
            ms(t.p99_cycles),
            ms(t.p999_cycles),
            ms(t.p100_cycles),
            t.worst_sojourn_cycles
                .map_or("/".into(), |b| format!("{:.2}", ms(b))),
            t.within_bound.map_or("/".into(), |ok| {
                if ok { "yes".to_string() } else { "NO".to_string() }
            }),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_beats_every_baseline_on_every_net() {
        let rows = table1().unwrap();
        for net in ["vgg16", "alexnet", "zf", "yolo"] {
            let ours = rows
                .iter()
                .find(|r| r.net == net && r.arch == ArchKind::FlexPipeline)
                .unwrap();
            for r in rows.iter().filter(|r| r.net == net) {
                if r.arch != ArchKind::FlexPipeline {
                    assert!(
                        ours.gops_16b > r.gops_16b,
                        "{net}: flex {:.0} GOPS must beat {} {:.0}",
                        ours.gops_16b,
                        r.arch.label(),
                        r.gops_16b
                    );
                }
            }
            // Paper's band: >90% DSP efficiency for all four nets. Our
            // exact-cycle model lands 82–96%: YOLO sits on an integer
            // phase-count plateau (every stage tied at the same cycle
            // count, intra-efficiency 1.0, too few spare DSPs to buy the
            // next divisor step) and pays a bandwidth-ceiling penalty the
            // closed form now models — see EXPERIMENTS.md §Deviations.
            assert!(
                ours.dsp_efficiency > 0.80,
                "{net}: efficiency {:.2}",
                ours.dsp_efficiency
            );
        }
    }

    #[test]
    fn vgg16_ratio_shape_matches_paper() {
        // Paper: 2.58x vs [1], 1.53x vs [2], 1.35x vs [3]. Substrates
        // differ, so check ordering + rough bands, not exact values.
        let rows = table1().unwrap();
        let (r1, r2, r3) = vgg16_speedups(&rows).unwrap();
        assert!(r1 > r2 && r2 > r3 && r3 > 1.0, "ordering: {r1:.2} {r2:.2} {r3:.2}");
        assert!((1.5..5.0).contains(&r1), "vs [1]: {r1:.2} (paper 2.58)");
        assert!((1.05..2.6).contains(&r2), "vs [2]: {r2:.2} (paper 1.53)");
        assert!((1.05..2.0).contains(&r3), "vs [3]: {r3:.2} (paper 1.35)");
    }

    #[test]
    fn render_contains_paper_rows() {
        let rows = table1().unwrap();
        let text = render(&rows, true);
        assert!(text.contains("paper:This Work"));
        assert!(text.contains("flex"));
    }

    #[test]
    fn utilization_within_board() {
        let rows = table1().unwrap();
        for r in rows.iter().filter(|r| r.arch == ArchKind::FlexPipeline) {
            assert!(r.lut_pct < 100.0 && r.bram_pct < 100.0 && r.ff_pct < 100.0,
                "{}: {:?}", r.net, (r.lut_pct, r.ff_pct, r.bram_pct));
        }
    }
}
