//! `flexipipe` CLI — the framework's front door, structured around the
//! plan-centric flow: **plan** a workload onto a board, then **simulate**
//! and **serve** the emitted plan file.
//!
//! ```text
//! flexipipe plan     --models vgg16,alexnet --board zc706 [--bits 16] \
//!                    [--schedule spatial|temporal|overlay|auto] [--overlay] \
//!                    [--shard-steps 16] [--weights 1,1] [--sim-frames 0] \
//!                    [--max-period 0.5] [--slo vgg16=33ms] [--min-fps alexnet=120] \
//!                    [--interleave 2] [--objective min-fps] [--json plan.json]
//! flexipipe simulate --plan plan.json [--frames 4] [--faults faults.json]
//! flexipipe serve    --plan plan.json [--frames 256]
//! flexipipe serve    --plan plan.json --trace trace.json   # seeded replay
//! flexipipe serve    --plan plan.json --listen 127.0.0.1:0 # operator API
//! flexipipe ctl      health|queues|plan|histograms [T] --addr HOST:PORT
//! flexipipe ctl      submit --tenant vgg16 [--priority 5] [--deadline 33ms] \
//!                    --addr HOST:PORT    (then: ctl poll|cancel --id N)
//! flexipipe ctl      apply target.json | replan faults.json | \
//!                    replay trace.json | shutdown   --addr HOST:PORT
//! flexipipe trace    gen --arrivals vgg16=poisson:2,alexnet=diurnal:0.5:2:5s \
//!                    [--seed 1] [--duration 20s] [--queue-cap 0] [--out trace.json]
//! flexipipe plan     --diff a.json b.json           # typed plan delta
//! flexipipe replan   --plan plan.json --faults faults.json [--json out.json]
//! flexipipe plan     --fleet fleet.json --models vgg16,alexnet,zf \
//!                    [--max-replicas 2] [--json fleet_plan.json]
//! flexipipe simulate --fleet-plan fleet_plan.json [--frames 4]
//! flexipipe replan   --fleet-plan fleet_plan.json --faults faults.json \
//!                    [--lost board-id] [--json degraded.json]
//! flexipipe allocate --model vgg16 --board zc706 --bits 16 [--arch flex]
//! flexipipe simulate --model vgg16 --board zc706 --frames 4
//! flexipipe report   [--no-paper]          # regenerate Table I
//! flexipipe serve    --net tinycnn --frames 256 [--artifacts DIR]
//! flexipipe e2e      [--artifacts DIR]     # golden-frame check + throughput
//! flexipipe sweep    --model vgg16 --param dsps --from 128 --to 1024
//! flexipipe search   --models vgg16,alexnet --boards zc706,zcu102 \
//!                    --bits 8,16 [--dsps 512,900] [--threads 0] [--json F]
//! flexipipe search   --tenants vgg16+alexnet,vgg16+zf --boards zc706
//! flexipipe shard    …                     # deprecated alias of `plan`
//! ```

use flexipipe::alloc::{allocator_for, ArchKind};
use flexipipe::control;
use flexipipe::coordinator::{BatchPolicy, Coordinator};
use flexipipe::fault::FaultPlan;
use flexipipe::fleet::{FleetPlan, FleetPlanner, FleetSpec};
use flexipipe::ingest::{self, IngestPolicy, IngestService, TraceSpec};
use flexipipe::model::{config, Network};
use flexipipe::plan::{Constraint, DeploymentPlan, Objective, Planner, TenantSpec, Workload};
use flexipipe::power::PowerModel;
use flexipipe::quant::QuantMode;
use flexipipe::runtime::{default_artifact_dir, Runtime};
use flexipipe::search::{self, DesignSpace};
use flexipipe::shard::{self, Regime, ScheduleMode};
use flexipipe::sim::{Simulate, Simulator};
use flexipipe::util::cli::{flag, opt, parse_duration_s, split_list, usage, Args, Spec};
use flexipipe::util::json::{obj, Value};
use flexipipe::{board, report, sim};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<Spec> {
    vec![
        opt("model", "zoo name or path to a network JSON", Some("vgg16")),
        opt("board", "board name (zc706 zcu102 vc707 zedboard)", Some("zc706")),
        opt("bits", "quantization width: 8 or 16", Some("16")),
        opt("arch", "flex | dnnbuilder | fusion | recurrent", Some("flex")),
        opt("frames", "frames to simulate/serve", Some("4")),
        opt("net", "artifact net to serve (tinycnn lenet vgg_micro)", Some("tinycnn")),
        opt("artifacts", "artifact directory", Some("artifacts")),
        opt("param", "sweep parameter: dsps | bandwidth | bram", Some("dsps")),
        opt("from", "sweep start", Some("128")),
        opt("to", "sweep end", Some("1024")),
        opt("steps", "sweep steps", Some("8")),
        opt(
            "trace",
            "per-stage CSV trace output path (simulate); trace-spec JSON to \
             replay deterministically (serve --plan)",
            None,
        ),
        opt("seed", "trace-spec PRNG seed (trace gen)", Some("1")),
        opt(
            "duration",
            "trace horizon, duration with s/ms/us suffix: 20s (trace gen)",
            Some("10s"),
        ),
        opt(
            "queue-cap",
            "per-tenant admission capacity; 0 derives the slice-admissible \
             depth from the plan (trace gen)",
            Some("0"),
        ),
        opt(
            "arrivals",
            "per-tenant arrival processes, model=process: vgg16=poisson:2, \
             alexnet=diurnal:0.5:2:5s, zf=bursty:3:10:10ms (trace gen)",
            None,
        ),
        opt("out", "write the generated trace spec to this path (trace gen)", None),
        opt("models", "comma-separated model list (plan/search)", None),
        opt("boards", "comma-separated board list (plan/search)", None),
        opt("archs", "comma-separated arch list (search)", Some("flex")),
        opt("dsps", "comma-separated DSP budget overrides (search)", None),
        opt(
            "tenants",
            "comma-separated co-resident groups, models joined by '+' (search)",
            None,
        ),
        opt(
            "shard-steps",
            "split granularity: 1/steps quanta (plan/search)",
            Some("16"),
        ),
        opt(
            "schedule",
            "sharing regime: spatial | temporal | overlay | auto (plan/search)",
            Some("spatial"),
        ),
        opt(
            "max-period",
            "temporal schedule period bound in seconds (plan/search)",
            Some("0.5"),
        ),
        opt(
            "slo",
            "per-tenant latency SLOs, model=duration with s/ms/us suffixes: \
             vgg16=33ms,zf=0.05s (plan/search)",
            None,
        ),
        opt(
            "min-fps",
            "per-tenant effective-fps floors, model=fps: alexnet=120 — plans \
             starving a floored tenant are dropped (plan/search)",
            None,
        ),
        opt(
            "objective",
            "which feasible plan `plan` labels best: min-fps | weighted",
            Some("min-fps"),
        ),
        opt(
            "plan",
            "deployment-plan JSON produced by `flexipipe plan --json` \
             (simulate/serve)",
            None,
        ),
        opt(
            "listen",
            "bind the operator control plane on this host:port (serve --plan); \
             port 0 picks a free port, announced as `listening on …` on stdout",
            None,
        ),
        opt("addr", "control-plane address host:port (ctl)", None),
        opt("tenant", "tenant name or index to submit to (ctl submit)", None),
        opt("priority", "admission priority 0..=255, higher first (ctl submit)", Some("0")),
        opt(
            "deadline",
            "relative request deadline: 0 (already expired) or a duration with \
             s/ms/us suffix (ctl submit)",
            None,
        ),
        opt("id", "request id printed by ctl submit (ctl poll / ctl cancel)", None),
        opt(
            "fleet",
            "fleet-spec JSON (named boards with costs): place the workload \
             across the whole fleet instead of one board (plan)",
            None,
        ),
        opt(
            "fleet-plan",
            "fleet-plan JSON produced by `flexipipe plan --fleet --json` \
             (simulate/replan)",
            None,
        ),
        opt(
            "max-replicas",
            "largest number of boards one tenant may be replicated across \
             (plan --fleet)",
            Some("2"),
        ),
        opt(
            "lost",
            "fleet board id the fault plan hits; defaults to the fleet plan's \
             first board (replan --fleet-plan)",
            None,
        ),
        opt(
            "faults",
            "fault-plan JSON: inject seeded faults into `simulate --plan` or \
             drive `replan` (see examples/faults/)",
            None,
        ),
        flag(
            "diff",
            "plan: diff two deployment-plan files (positional: a.json b.json) \
             into a minimal drain-overlapped reconfiguration sequence",
        ),
        opt(
            "interleave",
            "max sub-slices per tenant per period; k>1 trades switches for \
             latency (plan/search)",
            Some("1"),
        ),
        flag(
            "overlay",
            "static-region overlay regime: shared superset datapath, \
             zero-reconfig switches (= --schedule overlay)",
        ),
        flag(
            "prune",
            "branch-and-bound pruning of the split lattice (plan/search/replan): \
             frontier and objective picks are identical to the exhaustive \
             search, but dominated plans may be omitted from the full listing",
        ),
        flag("no-prune", "force the exhaustive lattice sweep (overrides --prune)"),
        opt("weights", "comma-separated tenant weights (plan)", None),
        opt("threads", "search worker threads, 0 = all cores", Some("0")),
        opt(
            "sim-frames",
            "confirm frontier plans with the DES: N frames per point (temporal \
             plans execute one full schedule period instead — N>0 just enables \
             the pass and records sim fps in the plan artifact)",
            Some("0"),
        ),
        opt(
            "json",
            "write results (plan document / search points) to this path",
            None,
        ),
        flag("no-paper", "omit paper reference rows from the report"),
        flag("verbose", "per-stage detail"),
    ]
}

fn run(argv: &[String]) -> flexipipe::Result<()> {
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..], &specs())?;
    match cmd {
        "allocate" => cmd_allocate(&args),
        "simulate" => cmd_simulate(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "e2e" => cmd_e2e(&args),
        "sweep" => cmd_sweep(&args),
        "search" => cmd_search(&args),
        "plan" => cmd_plan(&args),
        "replan" => cmd_replan(&args),
        "trace" => cmd_trace(&args),
        "ctl" => cmd_ctl(&args),
        "shard" => {
            // Thin deprecated alias: same flags, same output, one spine.
            eprintln!(
                "note: `flexipipe shard` is a deprecated alias of `flexipipe plan` \
                 (same flags, same output)"
            );
            cmd_plan(&args)
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{}", usage(&specs())),
    }
}

fn print_help() {
    println!(
        "flexipipe — FPGA layer-wise pipeline CNN accelerator framework\n\
         (reproduction of Yi/Sun/Fujita 2021)\n\n\
         commands: plan replan simulate serve ctl trace allocate report e2e sweep search \
         help\n\
         (shard is a deprecated alias of plan)\n\n\
         the plan-centric flow: `flexipipe plan … --json plan.json` emits a\n\
         deployment plan; `flexipipe simulate --plan plan.json` executes it in\n\
         the cycle-accurate DES; `flexipipe serve --plan plan.json` serves every\n\
         tenant on the in-process SimBackend.\n\n\
         traffic: `trace gen --arrivals …` authors a seeded open-loop workload;\n\
         `serve --plan P --trace T` replays it deterministically against the\n\
         plan's timeline and prints measured latency tails (p50/p99/p99.9/p100)\n\
         vs. the plan's analytic worst-case sojourn, with typed queue-full\n\
         rejects once offered load exceeds the plan's admitted rate.\n\n\
         fault tolerance: `simulate --plan P --faults F` replays a seeded fault\n\
         scenario through the DES; `plan --diff a.json b.json` emits the minimal\n\
         drain-overlapped reconfiguration sequence between two plans; `replan\n\
         --plan P --faults F` re-plans the workload onto the surviving capacity\n\
         with an explicit shed report.\n\n\
         operator API: `serve --plan P --listen HOST:PORT` exposes the running\n\
         service over a dependency-free HTTP control plane (health, queues,\n\
         histograms, submit with priorities + relative deadlines, plan\n\
         apply/replan, deterministic replay); `ctl SUB --addr HOST:PORT` is the\n\
         matching client — see docs/ARCHITECTURE.md for the endpoint table.\n\n\
         fleet scale: `plan --fleet fleet.json --models …` places N tenants\n\
         across M named boards (replication + spill) and emits a fleet plan =\n\
         per-board plans + routing table; `simulate --fleet-plan P` runs every\n\
         board's pinned engine and merges tenants through the routing weights;\n\
         `replan --fleet-plan P --faults F [--lost ID]` migrates tenants\n\
         displaced by a board loss onto surviving peers.\n\n{}",
        usage(&specs())
    );
}

type Common = (flexipipe::model::Network, board::Board, QuantMode, ArchKind);

fn parse_common(args: &Args) -> flexipipe::Result<Common> {
    let net = config::resolve(args.get_or("model", "vgg16"))?;
    let brd = board::by_name(args.get_or("board", "zc706"))?;
    let mode = QuantMode::from_bits(args.get_parse("bits", 16)?)?;
    let arch = ArchKind::parse(args.get_or("arch", "flex"))?;
    Ok((net, brd, mode, arch))
}

fn cmd_allocate(args: &Args) -> flexipipe::Result<()> {
    let (net, brd, mode, arch) = parse_common(args)?;
    let alloc = allocator_for(arch).allocate(&net, &brd, mode)?;
    let r = alloc.evaluate();
    let power = PowerModel::default().estimate(&alloc, &r);
    println!(
        "{} on {} ({mode}, {} arch): {:.1} fps, {:.0} GOPS, DSP {}/{} ({:.1}% efficient)",
        net.name,
        brd.name,
        arch.label(),
        r.fps,
        r.gops,
        r.dsps,
        brd.dsps,
        r.dsp_efficiency * 100.0
    );
    println!(
        "  LUT {:.1}%  FF {:.1}%  BRAM {:.1}%  DDR {:.2} GB/s  power {:.2} W ({:.1} GOPS/W)",
        100.0 * r.luts as f64 / brd.luts as f64,
        100.0 * r.ffs as f64 / brd.ffs as f64,
        100.0 * r.bram18 as f64 / brd.bram18() as f64,
        r.ddr_bytes_per_sec / 1e9,
        power.total(),
        r.gops / power.total()
    );
    if args.has("verbose") {
        println!("  per-stage (C', M', K, mults, cycles/frame):");
        for (s, c) in alloc.stages.iter().zip(&r.stage_cycles) {
            println!(
                "    {:>2} {:<14} C'={:<4} M'={:<4} K={:<3} mults={:<5} cycles={}",
                s.layer_idx,
                net.layers[s.layer_idx].label(),
                s.cfg.cp,
                s.cfg.mp,
                s.cfg.k,
                s.figures.mults,
                c
            );
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> flexipipe::Result<()> {
    if let Some(path) = args.get("fleet-plan") {
        return cmd_simulate_fleet(args, path);
    }
    if let Some(path) = args.get("plan") {
        return cmd_simulate_plan(args, path);
    }
    let (net, brd, mode, arch) = parse_common(args)?;
    let frames = args.get_parse("frames", 4usize)?;
    let alloc = allocator_for(arch).allocate(&net, &brd, mode)?;
    let cf = alloc.evaluate();
    let s = sim::simulate(&alloc, frames);
    println!(
        "{} on {} ({mode}, {}): simulated {frames} frames",
        net.name,
        brd.name,
        arch.label()
    );
    println!(
        "  closed-form: {:>10.0} cycles/frame  {:.2} fps  eff {:.1}%",
        cf.t_frame_cycles as f64,
        cf.fps,
        cf.dsp_efficiency * 100.0
    );
    println!(
        "  simulated:   {:>10.0} cycles/frame  {:.2} fps  eff {:.1}%  DDR util {:.0}%",
        s.cycles_per_frame,
        s.fps,
        s.dsp_efficiency * 100.0,
        s.ddr_utilization * 100.0
    );
    if let Some(path) = args.get("trace") {
        std::fs::write(path, flexipipe::trace::stage_csv(&alloc, &s))?;
        println!("  trace written to {path}");
    }
    if args.has("verbose") {
        for (i, st) in s.stages.iter().enumerate() {
            println!(
                "    stage {i:2} {:<14} busy={:<10} wstall={:<8} groups={}",
                net.layers[alloc.stages[i].layer_idx].label(),
                st.busy_cycles,
                st.stall_weights,
                st.groups_done
            );
        }
    }
    Ok(())
}

/// `simulate --plan plan.json`: execute one deployment plan through the
/// regime-matched DES and compare against the plan's recorded figures.
fn cmd_simulate_plan(args: &Args, path: &str) -> flexipipe::Result<()> {
    let plan = DeploymentPlan::load(path)?;
    let frames = args.get_parse("frames", 4usize)?;
    if let Some(fpath) = args.get("faults") {
        // Fault-injected run: emit ONLY the report JSON, byte-stable per
        // seed, so CI can diff two runs of the same scenario verbatim.
        let faults = FaultPlan::load(fpath)?;
        let report = Simulator { frames }.simulate_faulted(&plan, &faults)?;
        println!("{}", report.to_json().to_pretty());
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let report = Simulator { frames }.simulate(&plan)?;
    println!(
        "{path}: {} regime on {} ({} tenants, {}b, simulated in {:.2?})",
        plan.regime.label(),
        plan.board.name,
        plan.tenants.len(),
        plan.mode.bits(),
        t0.elapsed()
    );
    println!(
        "{:<12} {:>6} {:>6} {:>12} {:>12} {:>12}",
        "tenant", "Θ", "α", "planned fps", "sim fps", "cycles/frame"
    );
    for (t, r) in plan.tenants.iter().zip(&report.tenants) {
        let planned = t
            .record
            .as_ref()
            .map(|rec| format!("{:.1}", rec.fps))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<12} {:>3}/{:<2} {:>3}/{:<2} {:>12} {:>12.1} {:>12.0}",
            t.net.name,
            t.dsp_parts,
            plan.steps,
            t.bram_parts,
            plan.steps,
            planned,
            r.fps,
            r.cycles_per_frame
        );
    }
    Ok(())
}

fn cmd_report(args: &Args) -> flexipipe::Result<()> {
    let rows = report::table1()?;
    println!("{}", report::render(&rows, !args.has("no-paper")));
    if let Some((r1, r2, r3)) = report::vgg16_speedups(&rows) {
        println!(
            "VGG16 speedups (this work vs baselines): {r1:.2}x vs [1] recurrent (paper 2.58x), \
             {r2:.2}x vs [2] fusion (paper 1.53x), {r3:.2}x vs [3] DNNBuilder (paper 1.35x)"
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> flexipipe::Result<()> {
    if let Some(path) = args.get("plan") {
        if let Some(addr) = args.get("listen") {
            anyhow::ensure!(
                args.get("trace").is_none(),
                "serve --listen and --trace are mutually exclusive (use `flexipipe ctl \
                 replay` for deterministic replay against a live control plane)"
            );
            return cmd_serve_http(path, addr);
        }
        if let Some(tpath) = args.get("trace") {
            return cmd_serve_trace(path, tpath);
        }
        return cmd_serve_plan(args, path);
    }
    anyhow::ensure!(
        args.get("trace").is_none(),
        "serve --trace needs --plan plan.json (deterministic trace replay runs \
         against a deployment plan)"
    );
    anyhow::ensure!(
        args.get("listen").is_none(),
        "serve --listen needs --plan plan.json (the control plane fronts a deployment plan)"
    );
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let frames: usize = args.get_parse("frames", 256)?;
    let net = args.get_or("net", "tinycnn");
    println!("serving '{net}' from {dir}");
    let have_artifacts = std::path::Path::new(&dir).join("manifest.json").exists();
    let coord = Coordinator::start_auto(&dir, net, 8, BatchPolicy::default())?;

    // Input frames: golden files when artifacts exist (so responses are
    // oracle-checkable), deterministic noise through the SimBackend
    // otherwise.
    let (golden_in, elems) = if have_artifacts {
        let manifest = flexipipe::runtime::Manifest::load(format!("{dir}/manifest.json"))?;
        let art = manifest.variants(net, 8);
        let elems = art[0].golden.frame_elems;
        (
            flexipipe::runtime::read_i8(format!("{dir}/{}", art[0].golden.input))?,
            elems,
        )
    } else {
        println!("(no artifacts at {dir}: serving the in-process SimBackend)");
        let network = flexipipe::model::zoo::by_name(net)?;
        let (c0, h0, w0) = network.input;
        let elems = c0 * h0 * w0;
        let mut rng = flexipipe::util::prop::Rng::new(0x5EED);
        (
            (0..elems * 8).map(|_| rng.range(-128, 127) as i8).collect(),
            elems,
        )
    };
    let n_golden = golden_in.len() / elems;

    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..frames {
        let f = &golden_in[(i % n_golden) * elems..((i % n_golden) + 1) * elems];
        pending.push(coord.submit(f.to_vec())?);
    }
    for p in pending {
        p.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();
    println!(
        "served {} frames in {:.2?}: {:.1} fps, latency p50 {} µs / p99 {} µs, \
         {} batches ({} padded slots)",
        stats.requests,
        dt,
        stats.requests as f64 / dt.as_secs_f64(),
        stats.latency_us(50.0),
        stats.latency_us(99.0),
        stats.batches,
        stats.padded_frames
    );
    println!("batch mix (batch, frames): {:?}", stats.batch_sizes);
    Ok(())
}

/// `serve --plan plan.json`: start one coordinator per plan tenant on the
/// in-process SimBackend and push `--frames` deterministic frames through
/// each, round-robin.
fn cmd_serve_plan(args: &Args, path: &str) -> flexipipe::Result<()> {
    let plan = DeploymentPlan::load(path)?;
    let frames: usize = args.get_parse("frames", 256)?;
    println!(
        "serving plan {path}: {} tenants on {} ({} regime, SimBackend)",
        plan.tenants.len(),
        plan.board.name,
        plan.regime.label()
    );
    let svc = Coordinator::start_planned(&plan, BatchPolicy::default())?;

    // Deterministic per-tenant noise frames (the artifact-free input the
    // plain `serve` path uses too).
    let mut rng = flexipipe::util::prop::Rng::new(0x5EED);
    let inputs: Vec<Vec<i8>> = plan
        .tenants
        .iter()
        .map(|t| {
            let (c, h, w) = t.net.input;
            (0..c * h * w).map(|_| rng.range(-128, 127) as i8).collect()
        })
        .collect();

    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..frames {
        for (t, input) in inputs.iter().enumerate() {
            pending.push(svc.tenant(t).submit(input.clone())?);
        }
    }
    for p in pending {
        p.recv().map_err(|_| anyhow::anyhow!("dropped"))??;
    }
    let dt = t0.elapsed();
    let stats = svc.shutdown();
    let total: u64 = stats.iter().map(|(_, s)| s.requests).sum();
    println!(
        "served {total} frames across {} tenants in {dt:.2?} ({:.1} fps aggregate)",
        stats.len(),
        total as f64 / dt.as_secs_f64()
    );
    for (name, s) in &stats {
        println!(
            "  {:<12} {} frames, latency p50 {} µs / p99 {} µs, {} batches \
             ({} padded slots)",
            name,
            s.requests,
            s.latency_us(50.0),
            s.latency_us(99.0),
            s.batches,
            s.padded_frames
        );
    }
    Ok(())
}

/// `serve --plan plan.json --trace trace.json`: deterministic trace
/// replay through [`ingest::serve_trace`]. Stdout carries ONLY the
/// [`ingest::ServeReport`] JSON — byte-stable per (plan, trace) pair, so
/// CI diffs two runs verbatim (the fault path's convention); the human
/// p99-vs-bound table goes to stderr.
fn cmd_serve_trace(path: &str, tpath: &str) -> flexipipe::Result<()> {
    let plan = DeploymentPlan::load(path)?;
    let spec = TraceSpec::load(tpath)?;
    let report = ingest::serve_trace(&plan, &spec)?;
    eprintln!("{}", report::render_serve(&report));
    println!("{}", report.to_json().to_pretty());
    Ok(())
}

/// `serve --plan plan.json --listen ADDR`: run the ingestion service
/// behind the operator control plane until `POST /shutdown` (e.g.
/// `flexipipe ctl shutdown --addr …`) stops it. The first stdout line is
/// `listening on HOST:PORT` — with port 0 the kernel picks a free port,
/// so scripts parse that line to find the live address.
fn cmd_serve_http(path: &str, addr: &str) -> flexipipe::Result<()> {
    use std::io::Write as _;
    let plan = DeploymentPlan::load(path)?;
    let svc = IngestService::start(&plan, BatchPolicy::default(), IngestPolicy::default())?;
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
    let local = listener.local_addr()?;
    println!("listening on {local}");
    std::io::stdout().flush()?;
    eprintln!(
        "control plane for {path}: {} tenants on {} — stop with \
         `flexipipe ctl shutdown --addr {local}`",
        plan.tenants.len(),
        plan.board.name
    );
    let plane = control::ControlPlane::new(svc);
    control::serve(&plane, listener)?;
    eprintln!("control plane shut down: queues drained");
    Ok(())
}

/// `ctl SUB [FILE] --addr HOST:PORT`: operator client for a control
/// plane started with `serve --plan P --listen A`. Prints the JSON
/// response body on success; a non-2xx response is an error carrying the
/// status and body. Subcommands: `health` / `queues` / `plan` /
/// `histograms [TENANT]` / `submit` / `poll` / `cancel` /
/// `apply TARGET.json` (diffs the live plan against the target locally,
/// then posts the wire diff) / `replan FAULTS.json` /
/// `replay TRACE.json` / `shutdown`.
fn cmd_ctl(args: &Args) -> flexipipe::Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("ctl needs --addr host:port"))?;
    let pos = args.positional();
    let sub = pos.first().map(String::as_str).unwrap_or("");
    let file_body = |what: &str| -> flexipipe::Result<String> {
        let p = pos
            .get(1)
            .ok_or_else(|| anyhow::anyhow!("ctl {sub} needs a {what} file"))?;
        Ok(std::fs::read_to_string(p)?)
    };
    let (method, path, body) = match sub {
        "health" => ("GET", "/health".to_string(), None),
        "queues" => ("GET", "/queues".to_string(), None),
        "plan" => ("GET", "/plan".to_string(), None),
        "histograms" => match pos.get(1) {
            Some(t) => ("GET", format!("/histograms/{t}"), None),
            None => ("GET", "/histograms".to_string(), None),
        },
        "submit" => {
            let tenant = args
                .get("tenant")
                .ok_or_else(|| anyhow::anyhow!("ctl submit needs --tenant name-or-index"))?;
            let tenant = match tenant.parse::<usize>() {
                Ok(i) => Value::Num(i as f64),
                Err(_) => Value::Str(tenant.to_string()),
            };
            let mut pairs = vec![("tenant", tenant)];
            let priority: usize = args.get_parse("priority", 0)?;
            if priority > 0 {
                pairs.push(("priority", Value::Num(priority as f64)));
            }
            if let Some(d) = args.get("deadline") {
                let seconds = if d.trim() == "0" {
                    0.0
                } else {
                    parse_duration_s(d).map_err(|e| anyhow::anyhow!("--deadline: {e}"))?
                };
                pairs.push(("deadline_ms", Value::Num(seconds * 1e3)));
            }
            ("POST", "/submit".to_string(), Some(obj(pairs).to_pretty()))
        }
        "poll" | "cancel" => {
            let id = args
                .get("id")
                .ok_or_else(|| anyhow::anyhow!("ctl {sub} needs --id N"))?;
            let method = if sub == "poll" { "GET" } else { "DELETE" };
            (method, format!("/requests/{id}"), None)
        }
        "apply" => {
            let target = DeploymentPlan::load(
                pos.get(1)
                    .ok_or_else(|| anyhow::anyhow!("ctl apply needs a target plan file"))?,
            )?;
            let (status, live) = control::http_request(addr, "GET", "/plan", None)?;
            anyhow::ensure!(status == 200, "GET /plan failed ({status}): {live}");
            let live = DeploymentPlan::from_json(&flexipipe::util::json::parse(&live)?)?;
            let diff = live.diff(&target)?;
            ("POST", "/plan/apply".to_string(), Some(diff.to_wire_json().to_pretty()))
        }
        "replan" => ("POST", "/replan".to_string(), Some(file_body("fault-plan")?)),
        "replay" => ("POST", "/replay".to_string(), Some(file_body("trace-spec")?)),
        "shutdown" => ("POST", "/shutdown".to_string(), None),
        other => anyhow::bail!(
            "unknown ctl subcommand '{other}' — one of: health queues plan histograms \
             submit poll cancel apply replan replay shutdown"
        ),
    };
    let (status, resp) = control::http_request(addr, method, &path, body.as_deref())?;
    anyhow::ensure!((200..300).contains(&status), "{method} {path} → {status}: {resp}");
    println!("{resp}");
    Ok(())
}

/// `trace gen --arrivals …`: author a seeded trace spec. Stdout is the
/// spec JSON (or `--out FILE`); `serve --plan P --trace F` replays it.
fn cmd_trace(args: &Args) -> flexipipe::Result<()> {
    let pos = args.positional();
    anyhow::ensure!(
        pos.first().map(String::as_str) == Some("gen") && pos.len() == 1,
        "usage: flexipipe trace gen --arrivals vgg16=poisson:2,alexnet=diurnal:0.5:2:5s \
         [--seed N] [--duration 20s] [--queue-cap N] [--out trace.json]"
    );
    let arrivals = args
        .get("arrivals")
        .ok_or_else(|| anyhow::anyhow!("trace gen needs --arrivals model=process,…"))?;
    let spec = TraceSpec {
        seed: args.get_parse("seed", 1u64)?,
        duration_s: parse_duration_s(args.get_or("duration", "10s"))
            .map_err(|e| anyhow::anyhow!("--duration: {e}"))?,
        queue_capacity: args.get_parse("queue-cap", 0usize)?,
        tenants: ingest::parse_arrivals(arrivals)?,
    };
    spec.validate()?;
    match args.get("out") {
        Some(p) => {
            spec.save(p)?;
            eprintln!("trace spec written to {p}");
        }
        None => println!("{}", spec.to_json().to_pretty()),
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> flexipipe::Result<()> {
    let dir = match args.get("artifacts") {
        Some(d) => d.into(),
        None => default_artifact_dir(),
    };
    let rt = Runtime::load(&dir)?;
    println!("e2e golden check: platform={}", rt.platform());
    let mut checked = 0;
    let artifacts = rt.manifest().artifacts.clone();
    for a in &artifacts {
        if a.bits != 8 {
            continue;
        }
        let input = rt.golden_inputs(&a.name)?;
        let golden = rt.golden_outputs(&a.name)?;
        let elems = a.golden.frame_elems;
        let out_elems = a.golden.out_elems;
        let mut ok = true;
        let mut frame = 0;
        while frame + a.batch <= a.golden.frames {
            let chunk = &input[frame * elems..(frame + a.batch) * elems];
            let out = rt.execute_i8(&a.name, chunk)?;
            let want = &golden[frame * out_elems..(frame + a.batch) * out_elems];
            if out != want {
                ok = false;
                eprintln!(
                    "  {}: MISMATCH at frames {}..{}",
                    a.name,
                    frame,
                    frame + a.batch
                );
            }
            frame += a.batch;
        }
        println!(
            "  {:<20} {} ({} frames, bit-exact vs Python oracle)",
            a.name,
            if ok { "OK" } else { "FAIL" },
            frame
        );
        anyhow::ensure!(ok, "{} failed golden check", a.name);
        checked += 1;
    }
    anyhow::ensure!(checked > 0, "no 8-bit artifacts found in {}", dir.display());
    println!("all {checked} artifacts bit-exact");
    Ok(())
}

/// The shard regime from `--schedule`, with the `--overlay` flag as a
/// shorthand for `--schedule overlay`.
fn parse_schedule(args: &Args) -> flexipipe::Result<ScheduleMode> {
    if args.has("overlay") {
        let explicit = args.get("schedule");
        anyhow::ensure!(
            explicit.is_none() || explicit == Some("overlay"),
            "--overlay contradicts --schedule {}",
            explicit.unwrap_or_default()
        );
        return Ok(ScheduleMode::Overlay);
    }
    ScheduleMode::parse(args.get_or("schedule", "spatial"))
}

/// Resolve the `--prune` / `--no-prune` pair. Pruning is off by default;
/// `--no-prune` wins when both are given so scripts can append it to force
/// the exhaustive sweep.
fn prune_requested(args: &Args) -> bool {
    args.has("prune") && !args.has("no-prune")
}

/// `search`: parallel boards × models × modes × budgets sweep with a
/// Pareto frontier per (model, bits) workload. With `--tenants`, the sweep
/// instead shards each board across every co-resident group.
fn cmd_search(args: &Args) -> flexipipe::Result<()> {
    let split = split_list;
    // Singular --model/--board remain usable as one-element sweeps.
    let models = split(args.get("models").unwrap_or(args.get_or("model", "vgg16")));
    let boards = split(args.get("boards").unwrap_or(args.get_or("board", "zc706")));
    let bits = split(args.get_or("bits", "16"));
    let archs = split(args.get_or("archs", "flex"));

    if let Some(tenants) = args.get("tenants") {
        return cmd_search_shards(args, tenants, &boards, &bits);
    }

    let mut ds = DesignSpace {
        models: models
            .iter()
            .map(|m| config::resolve(m))
            .collect::<flexipipe::Result<Vec<_>>>()?,
        boards: boards
            .iter()
            .map(|b| board::by_name(b))
            .collect::<flexipipe::Result<Vec<_>>>()?,
        modes: bits
            .iter()
            .map(|b| {
                b.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("invalid --bits entry '{b}'"))
                    .and_then(QuantMode::from_bits)
            })
            .collect::<flexipipe::Result<Vec<_>>>()?,
        archs: archs
            .iter()
            .map(|a| ArchKind::parse(a))
            .collect::<flexipipe::Result<Vec<_>>>()?,
        sim_frames: args.get_parse("sim-frames", 0usize)?,
        threads: args.get_parse("threads", 0usize)?,
        ..Default::default()
    };
    if let Some(d) = args.get("dsps") {
        ds.dsp_budgets = split(d)
            .iter()
            .map(|v| {
                v.parse::<usize>()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("invalid --dsps entry '{v}'"))
            })
            .collect::<flexipipe::Result<Vec<_>>>()?;
    }

    let t0 = std::time::Instant::now();
    let points = ds.sweep()?;
    let dt = t0.elapsed();

    println!(
        "{:<10} {:<9} {:>4} {:<10} {:>5} {:>9} {:>8} {:>8} {:>7} {:>5}",
        "board", "model", "bits", "arch", "DSPs", "fps", "GOPS", "DSPeff%", "W", "maxK"
    );
    for p in &points {
        println!(
            "{:<10} {:<9} {:>4} {:<10} {:>5} {:>9.1} {:>8.0} {:>8.1} {:>7.2} {:>5}",
            p.board,
            p.model,
            p.mode.bits(),
            p.arch.label(),
            p.report.dsps,
            p.report.fps,
            p.report.gops,
            p.report.dsp_efficiency * 100.0,
            p.power_w,
            p.max_k
        );
    }
    println!("{} points in {:.2?} ({} threads)", points.len(), dt, ds.workers());

    // Frontier per workload (model, bits): cross-model dominance is noise.
    for ((model, bits), front) in search::frontier_by_workload(&points) {
        let desc: Vec<String> = front
            .iter()
            .map(|&i| {
                format!(
                    "{}/{} ({:.1} fps, {:.2} W, {} DSPs)",
                    points[i].board,
                    points[i].arch.label(),
                    points[i].report.fps,
                    points[i].power_w,
                    points[i].report.dsps
                )
            })
            .collect();
        println!("pareto {model}@{bits}b: {}", desc.join(" | "));
    }

    if let Some(path) = args.get("json") {
        std::fs::write(path, search::sweep_to_json(&points).to_pretty())?;
        println!("results written to {path}");
    }
    Ok(())
}

/// The `--tenants` axis of `search`: shard every board across every
/// co-resident group at every precision.
fn cmd_search_shards(
    args: &Args,
    tenants: &str,
    boards: &[String],
    bits: &[String],
) -> flexipipe::Result<()> {
    let groups: Vec<Vec<Network>> = split_list(tenants)
        .iter()
        .map(|g| {
            g.split('+')
                .map(|m| config::resolve(m.trim()))
                .collect::<flexipipe::Result<Vec<_>>>()
        })
        .collect::<flexipipe::Result<Vec<_>>>()?;
    let shard_steps: usize = args.get_parse("shard-steps", 16)?;
    let ds = DesignSpace {
        boards: boards
            .iter()
            .map(|b| board::by_name(b))
            .collect::<flexipipe::Result<Vec<_>>>()?,
        modes: bits
            .iter()
            .map(|b| {
                b.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("invalid --bits entry '{b}'"))
                    .and_then(QuantMode::from_bits)
            })
            .collect::<flexipipe::Result<Vec<_>>>()?,
        tenant_groups: groups,
        shard_steps,
        schedule: parse_schedule(args)?,
        max_period_s: args.get_parse("max-period", 0.5f64)?,
        max_interleave: args.get_parse("interleave", 1usize)?,
        slos: match args.get("slo") {
            Some(s) => shard::parse_slos(s)?,
            None => Vec::new(),
        },
        min_fps: match args.get("min-fps") {
            Some(s) => shard::parse_min_fps(s)?,
            None => Vec::new(),
        },
        sim_frames: args.get_parse("sim-frames", 0usize)?,
        threads: args.get_parse("threads", 0usize)?,
        prune: prune_requested(args),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let points = ds.sweep_shards()?;
    let dt = t0.elapsed();

    println!(
        "{:<10} {:<22} {:>4} {:>6} {:>8}  best min-fps plan (regime, per-tenant fps)",
        "board", "tenants", "bits", "plans", "frontier"
    );
    for p in &points {
        let best = &p.result.plans[p.result.best_min];
        let fps: Vec<String> = best
            .tenants
            .iter()
            .zip(&best.fps)
            .map(|(t, f)| format!("{} {:.1}", t.alloc.net.name, f))
            .collect();
        println!(
            "{:<10} {:<22} {:>4} {:>6} {:>8}  {} {}",
            p.board,
            p.models.join("+"),
            p.mode.bits(),
            p.result.plans.len(),
            p.result.frontier.len(),
            best.regime.label(),
            fps.join(" | ")
        );
    }
    let (nodes, pruned, calls) = points.iter().fold((0usize, 0usize, 0usize), |acc, p| {
        let s = &p.result.stats;
        (acc.0 + s.lattice_nodes, acc.1 + s.pruned_nodes, acc.2 + s.alloc_calls)
    });
    println!(
        "search effort: {pruned}/{nodes} lattice nodes skipped, {calls} allocator runs{}",
        if prune_requested(args) { " (pruning on)" } else { "" }
    );
    println!("{} shard points in {:.2?}", points.len(), dt);
    if let Some(path) = args.get("json") {
        let arr = Value::Arr(points.iter().map(|p| p.to_json(shard_steps)).collect());
        std::fs::write(path, arr.to_pretty())?;
        println!("results written to {path}");
    }
    Ok(())
}

/// `plan` (and its deprecated alias `shard`): plan a workload onto one or
/// more boards and emit the deployment-plan document — the frontier plus
/// the objective picks — as JSON (stdout, or `--json FILE`, which
/// `simulate --plan` / `serve --plan` consume directly).
/// Shared workload assembly for `plan` and `plan --fleet`: model list,
/// per-tenant weights, SLO/fps-floor constraints, and the objective.
fn build_workload(args: &Args) -> flexipipe::Result<(Vec<String>, Workload)> {
    let models = split_list(args.get("models").unwrap_or(args.get_or("model", "vgg16")));
    anyhow::ensure!(!models.is_empty(), "--models needs at least one model");
    let mode = QuantMode::from_bits(args.get_parse("bits", 16usize)?)?;
    let weights: Vec<f64> = match args.get("weights") {
        None => vec![1.0; models.len()],
        Some(w) => split_list(w)
            .iter()
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("invalid --weights entry '{v}'"))
            })
            .collect::<flexipipe::Result<Vec<_>>>()?,
    };
    anyhow::ensure!(
        weights.len() == models.len(),
        "--weights needs one entry per model ({} vs {})",
        weights.len(),
        models.len()
    );
    let mut workload = Workload::new(mode)
        .objective(Objective::parse(args.get_or("objective", "min-fps"))?);
    for (m, &weight) in models.iter().zip(&weights) {
        workload = workload.tenant_spec(TenantSpec::new(config::resolve(m)?).weight(weight));
    }
    if let Some(slo) = args.get("slo") {
        for (name, seconds) in shard::parse_slos(slo)? {
            workload.constrain(&name, Constraint::Slo(seconds))?;
        }
    }
    if let Some(floors) = args.get("min-fps") {
        for (name, fps) in shard::parse_min_fps(floors)? {
            workload.constrain(&name, Constraint::MinFps(fps))?;
        }
    }
    Ok((models, workload))
}

fn cmd_plan(args: &Args) -> flexipipe::Result<()> {
    if args.has("diff") {
        return cmd_plan_diff(args);
    }
    if let Some(fpath) = args.get("fleet") {
        return cmd_plan_fleet(args, fpath);
    }
    let boards = split_list(args.get("boards").unwrap_or(args.get_or("board", "zc706")))
        .iter()
        .map(|b| board::by_name(b))
        .collect::<flexipipe::Result<Vec<_>>>()?;
    let steps: usize = args.get_parse("shard-steps", 16)?;
    let schedule = parse_schedule(args)?;
    let (models, workload) = build_workload(args)?;
    let mode = workload.mode;

    let planner = Planner::across(boards)
        .steps(steps)
        .schedule(schedule)
        .max_period(args.get_parse("max-period", 0.5f64)?)
        .interleave(args.get_parse("interleave", 1usize)?)
        .validate(args.get_parse("sim-frames", 0usize)?)
        .prune(prune_requested(args));
    let t0 = std::time::Instant::now();
    let set = planner.plan(&workload)?;
    println!(
        "plan: {} tenants ({mode}, {} regime, 1/{steps} quanta, {} board{}): {} feasible \
         plans, {} on the frontier ({:.2?})",
        models.len(),
        schedule.label(),
        planner.boards.len(),
        if planner.boards.len() == 1 { "" } else { "s" },
        set.plans.len(),
        set.frontier.len(),
        t0.elapsed()
    );

    let describe = |p: &DeploymentPlan| -> String {
        match &p.regime {
            Regime::Spatial => {
                let dsp: Vec<String> =
                    p.tenants.iter().map(|t| t.dsp_parts.to_string()).collect();
                let bram: Vec<String> =
                    p.tenants.iter().map(|t| t.bram_parts.to_string()).collect();
                format!("{} spatial  Θ {} | α {}", p.board.name, dsp.join("+"), bram.join("+"))
            }
            Regime::Temporal(info) if info.period_cycles == 0 => {
                format!("{} temporal solo", p.board.name)
            }
            Regime::Temporal(info) => {
                let slices: Vec<String> = info
                    .time_parts
                    .iter()
                    .zip(&info.interleave)
                    .map(|(t, &k)| {
                        if k > 1 {
                            format!("{t}\u{00d7}{k}")
                        } else {
                            t.to_string()
                        }
                    })
                    .collect();
                format!(
                    "{} {} slices {} | period {:.1} ms | dead {:.0}%",
                    p.board.name,
                    p.regime.label(),
                    slices.join("+"),
                    info.period_cycles as f64 / p.board.freq_hz * 1e3,
                    info.dead_frac * 100.0
                )
            }
        }
    };
    let show = |label: String, idx: usize| {
        let p = &set.plans[idx];
        println!("  {label} [{}]:", describe(p));
        for t in &p.tenants {
            let (fps, lat, dsps, bram) = match &t.record {
                Some(r) => (
                    format!("{:>9.1}", r.fps),
                    format!("{:>7.2}", r.latency_s * 1e3),
                    r.dsps,
                    r.bram18,
                ),
                None => ("        -".to_string(), "      -".to_string(), 0, 0),
            };
            println!(
                "    {:<10} Θ {:>2}/{steps}  α {:>2}/{steps}  {:>4} DSPs {:>5} BRAM18 \
                 {fps} fps  lat {lat} ms",
                t.net.name, t.dsp_parts, t.bram_parts, dsps, bram,
            );
        }
    };
    show(
        format!(
            "best min-fps ({:.1})",
            set.plans[set.best_min].min_fps().unwrap_or(f64::NAN)
        ),
        set.best_min,
    );
    show(
        format!(
            "best weighted-fps ({:.1})",
            set.plans[set.best_weighted].weighted_fps().unwrap_or(f64::NAN)
        ),
        set.best_weighted,
    );
    println!("  frontier (board/regime | split | per-tenant fps | worst-case latency):");
    for &i in &set.frontier {
        let p = &set.plans[i];
        let fps: Vec<String> = p
            .fps_vec()
            .unwrap_or_default()
            .iter()
            .map(|f| format!("{f:.1}"))
            .collect();
        let lat: Vec<String> = p
            .latency_vec()
            .unwrap_or_default()
            .iter()
            .map(|l| format!("{:.1}", l * 1e3))
            .collect();
        let sim: Vec<String> = p
            .tenants
            .iter()
            .filter_map(|t| t.record.as_ref().and_then(|r| r.sim_fps))
            .map(|f| format!("{f:.1}"))
            .collect();
        let sim = if sim.is_empty() {
            String::new()
        } else {
            format!("  [sim {}]", sim.join("/"))
        };
        println!(
            "    {} | {} fps | {} ms{}",
            describe(p),
            fps.join(" / "),
            lat.join(" / "),
            sim
        );
    }
    let json = set.to_json().to_pretty();
    match args.get("json") {
        Some(path) => {
            std::fs::write(path, &json)?;
            println!("deployment plans (frontier + objective picks) written to {path}");
        }
        None => println!("{json}"),
    }
    Ok(())
}

/// `plan --fleet fleet.json`: place the workload across the whole fleet
/// and emit the fleet frontier — per-board deployment plans plus routing
/// tables — with the objective pick inline (what `simulate --fleet-plan`
/// and `replan --fleet-plan` load back).
fn cmd_plan_fleet(args: &Args, fpath: &str) -> flexipipe::Result<()> {
    let fleet = FleetSpec::load(fpath)?;
    let nboards = fleet.boards.len();
    let steps: usize = args.get_parse("shard-steps", 16)?;
    let (models, workload) = build_workload(args)?;
    let planner = FleetPlanner::over(fleet)
        .steps(steps)
        .schedule(parse_schedule(args)?)
        .max_period(args.get_parse("max-period", 0.5f64)?)
        .interleave(args.get_parse("interleave", 1usize)?)
        .validate(args.get_parse("sim-frames", 0usize)?)
        .prune(prune_requested(args))
        .replicas(args.get_parse("max-replicas", 2usize)?);
    let t0 = std::time::Instant::now();
    let set = planner.plan(&workload)?;
    let s = &set.stats;
    println!(
        "fleet plan: {} tenants across {nboards} boards ({}, 1/{steps} quanta): {} plans on \
         the frontier ({:.2?}; {} assignments — {} infeasible, {} bound-skipped, {} solved; \
         {} board solves, {} cache hits)",
        models.len(),
        workload.mode,
        set.plans.len(),
        t0.elapsed(),
        s.assignments,
        s.infeasible,
        s.bound_skipped,
        s.solved,
        s.board_solves,
        s.cache_hits
    );
    for (i, p) in set.plans.iter().enumerate() {
        let mut marks = String::new();
        if i == set.best_min {
            marks.push_str("  [best min-fps]");
        }
        if i == set.best_weighted {
            marks.push_str("  [best weighted-fps]");
        }
        let fps: Vec<String> = p
            .fps_vec()
            .unwrap_or_default()
            .iter()
            .map(|f| format!("{f:.1}"))
            .collect();
        let lat: Vec<String> = p
            .latency_vec()
            .unwrap_or_default()
            .iter()
            .map(|l| format!("{:.1}", l * 1e3))
            .collect();
        println!(
            "  [{i}] cost {:.2}  fps {} | lat {} ms{marks}",
            p.cost(),
            fps.join(" / "),
            lat.join(" / ")
        );
        for pl in &p.boards {
            let hosted: Vec<String> = pl
                .plan
                .tenants
                .iter()
                .map(|t| {
                    match &t.record {
                        Some(r) => format!("{} {:.1} fps", t.net.name, r.fps),
                        None => t.net.name.clone(),
                    }
                })
                .collect();
            println!(
                "      {} ({}, {}): {}",
                pl.id,
                pl.plan.board.name,
                pl.plan.regime.label(),
                hosted.join(", ")
            );
        }
        for tr in &p.routing.tenants {
            if tr.routes.len() > 1 {
                let split: Vec<String> = tr
                    .routes
                    .iter()
                    .map(|r| format!("{} {:.0}%", r.board, r.weight * 100.0))
                    .collect();
                println!("      routing {}: {}", tr.net, split.join(" + "));
            }
        }
    }
    let json = set.to_json().to_pretty();
    match args.get("json") {
        Some(path) => {
            std::fs::write(path, &json)?;
            println!("fleet plans (frontier + objective picks) written to {path}");
        }
        None => println!("{json}"),
    }
    Ok(())
}

/// `simulate --fleet-plan plan.json`: execute every board's pinned engine
/// and merge per-tenant reports through the routing weights. Emits ONLY
/// the report JSON on stdout (byte-stable — CI diffs two runs verbatim);
/// the human summary goes to stderr.
fn cmd_simulate_fleet(args: &Args, path: &str) -> flexipipe::Result<()> {
    let plan = FleetPlan::load(path)?;
    let frames = args.get_parse("frames", 4usize)?;
    let report = Simulator { frames }.simulate_fleet(&plan)?;
    for t in &report.tenants {
        let routes: Vec<String> = t
            .routes
            .iter()
            .map(|r| format!("{} {:.1} fps ({:.0}%)", r.board, r.fps, r.weight * 100.0))
            .collect();
        let sojourn = t
            .worst_sojourn_s
            .map(|s| format!("{:.2} ms", s * 1e3))
            .unwrap_or_else(|| "-".to_string());
        eprintln!(
            "{:<12} {:>9.1} fps  worst sojourn {sojourn}  via {}",
            t.net,
            t.fps,
            routes.join(" + ")
        );
    }
    println!("{}", report.to_json().to_pretty());
    Ok(())
}

/// `replan --fleet-plan plan.json --faults faults.json [--lost ID]`:
/// apply the fault plan to one fleet board and migrate whatever it can no
/// longer serve onto surviving peers. Prints the outcome JSON (migrations,
/// dropped replicas, shed report, degraded plan) and optionally writes the
/// degraded fleet plan to `--json`.
fn cmd_replan_fleet(args: &Args, ppath: &str) -> flexipipe::Result<()> {
    let fpath = args
        .get("faults")
        .ok_or_else(|| anyhow::anyhow!("replan --fleet-plan needs --faults faults.json"))?;
    let incumbent = FleetPlan::load(ppath)?;
    let faults = FaultPlan::load(fpath)?;
    let lost = match args.get("lost") {
        Some(id) => id.to_string(),
        None => incumbent.boards[0].id.clone(),
    };
    let planner = FleetPlanner::over(incumbent.spec())
        .steps(args.get_parse("shard-steps", 16usize)?)
        .schedule(parse_schedule(args)?)
        .max_period(args.get_parse("max-period", 0.5f64)?)
        .interleave(args.get_parse("interleave", 1usize)?)
        .validate(args.get_parse("sim-frames", 0usize)?)
        .prune(prune_requested(args));
    let outcome = planner.replan(&incumbent, &faults, &lost)?;
    println!("{}", outcome.to_json().to_pretty());
    if let Some(path) = args.get("json") {
        match &outcome.plan {
            Some(plan) => {
                plan.save(path)?;
                eprintln!("degraded fleet plan written to {path}");
            }
            None => eprintln!("no surviving fleet capacity: {path} not written"),
        }
    }
    Ok(())
}

/// `plan --diff a.json b.json`: load two deployment plans and print the
/// typed delta — per-tenant keep/change/add/remove ops with drain-overlapped
/// reconfiguration cost — as JSON.
fn cmd_plan_diff(args: &Args) -> flexipipe::Result<()> {
    let pos = args.positional();
    anyhow::ensure!(
        pos.len() == 2,
        "plan --diff takes exactly two plan files (got {}): \
         flexipipe plan --diff a.json b.json",
        pos.len()
    );
    let from = DeploymentPlan::load(&pos[0])?;
    let to = DeploymentPlan::load(&pos[1])?;
    let diff = from.diff(&to)?;
    println!("{}", diff.to_json().to_pretty());
    Ok(())
}

/// `replan --plan plan.json --faults faults.json`: re-plan the incumbent
/// workload onto the fault plan's surviving capacity. Prints the outcome —
/// shed report, plan delta, and (when feasible) the replacement plan — and
/// optionally writes the new plan to `--json`.
fn cmd_replan(args: &Args) -> flexipipe::Result<()> {
    if let Some(path) = args.get("fleet-plan") {
        return cmd_replan_fleet(args, path);
    }
    let ppath = args
        .get("plan")
        .ok_or_else(|| anyhow::anyhow!("replan needs --plan plan.json"))?;
    let fpath = args
        .get("faults")
        .ok_or_else(|| anyhow::anyhow!("replan needs --faults faults.json"))?;
    let incumbent = DeploymentPlan::load(ppath)?;
    let faults = FaultPlan::load(fpath)?;
    let planner = Planner::on(incumbent.board.clone())
        .steps(args.get_parse("shard-steps", 16usize)?)
        .schedule(parse_schedule(args)?)
        .max_period(args.get_parse("max-period", 0.5f64)?)
        .interleave(args.get_parse("interleave", 1usize)?)
        .validate(args.get_parse("sim-frames", 0usize)?)
        .prune(prune_requested(args));
    let outcome = planner.replan(&incumbent, &faults)?;
    println!("{}", outcome.to_json().to_pretty());
    if let Some(path) = args.get("json") {
        match &outcome.plan {
            Some(plan) => {
                plan.save(path)?;
                eprintln!("replanned deployment plan written to {path}");
            }
            None => eprintln!("no feasible plan on surviving capacity: {path} not written"),
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> flexipipe::Result<()> {
    let (net, brd, mode, arch) = parse_common(args)?;
    let param = args.get_or("param", "dsps");
    let from: f64 = args.get_parse("from", 128.0)?;
    let to: f64 = args.get_parse("to", 1024.0)?;
    let steps: usize = args.get_parse("steps", 8)?;
    println!("{param},fps,gops,dsp_eff,bram18,ddr_gbps");
    for i in 0..steps {
        let v = from + (to - from) * i as f64 / (steps - 1).max(1) as f64;
        let mut b = brd.clone();
        match param {
            "dsps" => b.dsps = v as usize,
            "bandwidth" => b.ddr_bytes_per_sec = v * 1e9,
            "bram" => b.bram36 = v as usize,
            other => anyhow::bail!("unknown sweep param '{other}'"),
        }
        let alloc = allocator_for(arch).allocate(&net, &b, mode)?;
        let r = alloc.evaluate();
        println!(
            "{v:.0},{:.2},{:.1},{:.4},{},{:.2}",
            r.fps,
            r.gops,
            r.dsp_efficiency,
            r.bram18,
            r.ddr_bytes_per_sec / 1e9
        );
    }
    Ok(())
}
