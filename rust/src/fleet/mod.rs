//! Fleet-scale planning: place N tenants across M heterogeneous boards.
//!
//! The [`crate::plan::Planner`] spine optimizes one board; this module
//! lifts it to a *fleet* — a set of named boards with per-board cost
//! ([`FleetSpec`]) — and solves placement as one optimization
//! ([`FleetPlanner::plan`]):
//!
//! - **Replication** of a hot tenant across several boards: its fps is
//!   the *sum* over replicas, recorded in a [`RoutingTable`] whose
//!   per-tenant weights are the fps fractions each board serves.
//! - **Spill** of cold tenants onto shared boards: a board hosting
//!   several tenants is solved by the existing single-board planner
//!   (spatial / temporal / overlay regimes, branch-and-bound pruning),
//!   so a cheap board can absorb the long tail.
//! - A global Pareto frontier over **(fleet cost ↓, per-tenant fps ↑,
//!   worst-case latency ↓)**: the cost axis is what makes "leave a
//!   board idle" a first-class answer — a placement using fewer boards
//!   survives the reduction unless the extra hardware buys throughput
//!   or latency.
//!
//! The result is a versioned [`FleetPlan`] ([`FLEET_VERSION`], unknown
//! versions rejected like the plan/fault/trace formats): one
//! [`crate::plan::DeploymentPlan`] per used board plus the routing
//! table. [`crate::sim::Simulator::simulate_fleet`] executes every
//! board's pinned engine and merges per-tenant reports through the
//! routing weights; [`FleetPlanner::replan`] handles a board loss by
//! migrating displaced tenants onto surviving peers (explicit
//! migration / dropped-replica / shed report — nothing vanishes
//! silently).
//!
//! Exactness is part of the contract (property-pinned in
//! `tests/fleet_props.rs`): a single-board fleet reproduces
//! [`crate::plan::Planner::plan`]'s frontier bit-identically, the
//! placement search restricted to per-board frontier sub-plans loses
//! nothing (a dominated sub-plan can only produce a dominated fleet
//! combination), and branch-and-bound assignment pruning
//! ([`FleetPlanner::prune`]) uses admissible solo-probe bounds — with
//! incumbents found on earlier assignments bounding later ones — so
//! the pruned frontier equals the exhaustive one.
//!
//! ```
//! use flexipipe::board::zedboard;
//! use flexipipe::fleet::{FleetPlanner, FleetSpec};
//! use flexipipe::model::zoo;
//! use flexipipe::plan::Workload;
//! use flexipipe::quant::QuantMode;
//!
//! let fleet = FleetSpec::new().board("edge-a", zedboard(), 1.0);
//! let workload = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
//! let set = FleetPlanner::over(fleet).steps(4).plan(&workload).unwrap();
//! let best = &set.plans[set.best];
//! assert_eq!(best.boards.len(), 1);
//! // A solo tenant routes all of its traffic to its one board.
//! assert_eq!(best.routing.tenants[0].routes[0].weight, 1.0);
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::board::{self, Board};
use crate::plan::{
    self, Constraint, DeploymentPlan, Objective, Planner, ReplanPhase, TenantSpec, Workload,
};
use crate::shard::{
    vec_dominates, vec_weakly_dominates, FrontierMerge, ReconfigModel, ScheduleMode,
};
use crate::util::json::{self, num, obj, Value};

/// Fleet-format version this build writes.
pub const FLEET_VERSION: usize = 1;
/// Oldest fleet-format version this build reads.
pub const FLEET_VERSION_MIN: usize = 1;

/// Board-count ceiling: tenant→board subsets are `u32` bitmasks and the
/// assignment space is exponential in practice well before this.
const MAX_BOARDS: usize = 16;
/// Ceiling on the tenant→board-subset assignment space one
/// [`FleetPlanner::plan`] call will enumerate.
const MAX_ASSIGNMENTS: u128 = 20_000;
/// Ceiling on per-assignment sub-plan combinations (the cross product of
/// the used boards' frontier sizes).
const MAX_COMBOS: usize = 4096;

// ---------------------------------------------------------------------------
// FleetSpec
// ---------------------------------------------------------------------------

/// One board of a fleet: a stable identifier (routing and failover are
/// keyed by it), the physical resource model, and its cost share in the
/// fleet-frontier cost axis (arbitrary consistent units — price, power,
/// rack slots).
#[derive(Debug, Clone)]
pub struct FleetBoard {
    /// Fleet-unique board identifier (e.g. `"zc706-a"`).
    pub id: String,
    /// The physical board model.
    pub board: Board,
    /// Cost charged to a placement that uses this board.
    pub cost: f64,
}

/// The fleet a [`FleetPlanner`] places onto: named heterogeneous boards
/// with per-board cost, in a deterministic order (assignment enumeration,
/// routing, and failover first-fit all follow it).
#[derive(Debug, Clone, Default)]
pub struct FleetSpec {
    /// The boards, in fleet order.
    pub boards: Vec<FleetBoard>,
}

impl FleetSpec {
    /// Empty fleet.
    pub fn new() -> FleetSpec {
        FleetSpec::default()
    }

    /// Add a board (builder style).
    pub fn board(mut self, id: &str, board: Board, cost: f64) -> FleetSpec {
        self.boards.push(FleetBoard {
            id: id.to_string(),
            board,
            cost,
        });
        self
    }

    /// Check the spec is usable: at least one board, at most
    /// [`MAX_BOARDS`], unique non-empty ids, positive finite costs.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.boards.is_empty(), "fleet has no boards");
        anyhow::ensure!(
            self.boards.len() <= MAX_BOARDS,
            "fleet has {} boards; the placement search supports at most {MAX_BOARDS}",
            self.boards.len()
        );
        for (i, b) in self.boards.iter().enumerate() {
            anyhow::ensure!(!b.id.is_empty(), "fleet board {i} has an empty id");
            anyhow::ensure!(
                b.cost.is_finite() && b.cost > 0.0,
                "fleet board '{}': cost must be positive and finite (got {})",
                b.id,
                b.cost
            );
            for prev in &self.boards[..i] {
                anyhow::ensure!(prev.id != b.id, "duplicate fleet board id '{}'", b.id);
            }
        }
        Ok(())
    }

    /// JSON document (deterministic field order).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("version", num(FLEET_VERSION)),
            (
                "boards",
                Value::Arr(
                    self.boards
                        .iter()
                        .map(|b| {
                            obj(vec![
                                ("id", Value::Str(b.id.clone())),
                                ("cost", Value::Num(b.cost)),
                                ("board", plan::board_to_json(&b.board)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize from the versioned fleet-spec format. The `board`
    /// field of each entry is either a known board name (resolved via
    /// [`crate::board::by_name`]) or a full embedded board object;
    /// `cost` defaults to 1.0. Unknown `version` values are rejected
    /// outright.
    pub fn from_json(v: &Value) -> crate::Result<FleetSpec> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(
            (FLEET_VERSION_MIN..=FLEET_VERSION).contains(&version),
            "unsupported fleet-spec version {version}: this build reads versions \
             {FLEET_VERSION_MIN}..={FLEET_VERSION}"
        );
        let entries = v
            .req("boards")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'boards' must be an array"))?;
        let mut boards = Vec::with_capacity(entries.len());
        for e in entries {
            let id = e.str_field("id")?.to_string();
            let cost = match e.get("cost") {
                Some(c) => c
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("board '{id}': 'cost' is not a number"))?,
                None => 1.0,
            };
            let board = match e.req("board")? {
                Value::Str(name) => board::by_name(name)?,
                other => plan::board_from_json(other)?,
            };
            boards.push(FleetBoard { id, board, cost });
        }
        let spec = FleetSpec { boards };
        spec.validate()?;
        Ok(spec)
    }

    /// Write the spec to a file (pretty-printed JSON).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a spec from a file; every failure carries the path.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<FleetSpec> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
        FleetSpec::from_json(&v).map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }
}

// ---------------------------------------------------------------------------
// RoutingTable
// ---------------------------------------------------------------------------

/// One board's share of a tenant's traffic.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    /// Fleet board id serving this share.
    pub board: String,
    /// Fraction of the tenant's traffic routed here — the board's share
    /// of the tenant's planned fps. In `(0, 1]`; a tenant's weights sum
    /// to 1 (conservation, [`FleetPlan::validate`]-pinned).
    pub weight: f64,
}

/// Where one tenant's traffic goes.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantRoute {
    /// Tenant model name (fleet-unique; routing is keyed by it).
    pub net: String,
    /// The boards serving this tenant, in fleet order.
    pub routes: Vec<Route>,
}

/// The fleet's traffic split: for every tenant, which boards serve it
/// and with what fraction of its traffic. Invariants (pinned by
/// [`FleetPlan::validate`]): weights per tenant sum to 1, every route
/// points at a board whose plan actually hosts the tenant, and every
/// hosted tenant is routed — no silent strays in either direction.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoutingTable {
    /// Per-tenant routes, in workload tenant order.
    pub tenants: Vec<TenantRoute>,
}

// ---------------------------------------------------------------------------
// FleetPlan
// ---------------------------------------------------------------------------

/// One used board inside a [`FleetPlan`]: its fleet id, the cost it
/// charges, and the single-board deployment serving its sub-workload.
#[derive(Debug, Clone)]
pub struct FleetPlacement {
    /// Fleet board id.
    pub id: String,
    /// Cost this board contributes to [`FleetPlan::cost`].
    pub cost: f64,
    /// The board's deployment (the same artifact `flexipipe simulate
    /// --plan` executes).
    pub plan: DeploymentPlan,
}

/// A versioned fleet deployment: per-board [`DeploymentPlan`]s plus the
/// [`RoutingTable`] — the only currency between the fleet planner, the
/// fleet simulator, and fleet failover. Serializable; a plan on disk
/// re-simulates bit-identically ([`crate::sim::Simulator::simulate_fleet`]).
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Format version ([`FLEET_VERSION`] when produced by this build).
    pub version: usize,
    /// The used boards, in fleet order (unused boards are absent — they
    /// charge no cost).
    pub boards: Vec<FleetPlacement>,
    /// The traffic split across those boards.
    pub routing: RoutingTable,
}

impl FleetPlan {
    /// Total fleet cost: the sum over used boards.
    pub fn cost(&self) -> f64 {
        self.boards.iter().map(|p| p.cost).sum()
    }

    /// Planning record for `net` on board `board_id`, if both exist.
    fn record_on(&self, board_id: &str, net: &str) -> Option<&plan::TenantRecord> {
        let p = self.boards.iter().find(|p| p.id == board_id)?;
        let t = p.plan.tenants.iter().find(|t| t.net.name == net)?;
        t.record.as_ref()
    }

    /// Per-tenant planned fps (routing order): the **sum** over the
    /// tenant's replicas. `None` when any hosting plan lacks planning
    /// records (hand-authored plans).
    pub fn fps_vec(&self) -> Option<Vec<f64>> {
        self.routing
            .tenants
            .iter()
            .map(|tr| {
                tr.routes.iter().try_fold(0.0, |acc, r| {
                    self.record_on(&r.board, &tr.net).map(|rec| acc + rec.fps)
                })
            })
            .collect()
    }

    /// Per-tenant planned worst-case latency in seconds (routing order):
    /// the **max** over the tenant's replicas — a frame is only as safe
    /// as its slowest route. `None` without planning records.
    pub fn latency_vec(&self) -> Option<Vec<f64>> {
        self.routing
            .tenants
            .iter()
            .map(|tr| {
                tr.routes.iter().try_fold(0.0f64, |acc, r| {
                    self.record_on(&r.board, &tr.net).map(|rec| acc.max(rec.latency_s))
                })
            })
            .collect()
    }

    /// Planned min-fps objective over all tenants.
    pub fn min_fps(&self) -> Option<f64> {
        self.fps_vec().map(|v| v.into_iter().fold(f64::INFINITY, f64::min))
    }

    /// Planned weighted-fps objective (weights from the hosting plans).
    pub fn weighted_fps(&self) -> Option<f64> {
        let fps = self.fps_vec()?;
        let mut total = 0.0;
        for (i, tr) in self.routing.tenants.iter().enumerate() {
            let first = tr.routes.first()?;
            let p = self.boards.iter().find(|p| p.id == first.board)?;
            let w = p.plan.tenants.iter().find(|t| t.net.name == tr.net)?.weight;
            total += fps[i] * w;
        }
        Some(total)
    }

    /// The [`FleetSpec`] this plan occupies (used boards only, with the
    /// embedded board models) — what [`FleetPlanner::replan`] plans
    /// against.
    pub fn spec(&self) -> FleetSpec {
        FleetSpec {
            boards: self
                .boards
                .iter()
                .map(|p| FleetBoard {
                    id: p.id.clone(),
                    board: p.plan.board.clone(),
                    cost: p.cost,
                })
                .collect(),
        }
    }

    /// Check the plan's structural invariants: supported version, unique
    /// board ids, and bidirectional routing↔hosting conservation — every
    /// route points at a board whose plan hosts the tenant with a weight
    /// in `(0, 1]`, per-tenant weights sum to 1 (±1e-9), and every
    /// tenant hosted by any board appears in the routing table with a
    /// route to that board.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (FLEET_VERSION_MIN..=FLEET_VERSION).contains(&self.version),
            "unsupported fleet-plan version {}: this build reads versions \
             {FLEET_VERSION_MIN}..={FLEET_VERSION} — regenerate with `flexipipe plan --fleet`",
            self.version
        );
        anyhow::ensure!(!self.boards.is_empty(), "fleet plan uses no boards");
        for (i, p) in self.boards.iter().enumerate() {
            anyhow::ensure!(!p.id.is_empty(), "fleet placement {i} has an empty board id");
            for prev in &self.boards[..i] {
                anyhow::ensure!(prev.id != p.id, "duplicate fleet board id '{}'", p.id);
            }
        }
        anyhow::ensure!(!self.routing.tenants.is_empty(), "fleet plan routes no tenants");
        for (i, tr) in self.routing.tenants.iter().enumerate() {
            for prev in &self.routing.tenants[..i] {
                anyhow::ensure!(prev.net != tr.net, "tenant '{}' routed twice", tr.net);
            }
            anyhow::ensure!(!tr.routes.is_empty(), "tenant '{}' has no routes", tr.net);
            let mut sum = 0.0;
            for (j, r) in tr.routes.iter().enumerate() {
                for prev in &tr.routes[..j] {
                    anyhow::ensure!(
                        prev.board != r.board,
                        "tenant '{}' routed to board '{}' twice",
                        tr.net,
                        r.board
                    );
                }
                anyhow::ensure!(
                    r.weight > 0.0 && r.weight <= 1.0,
                    "tenant '{}' route to '{}': weight {} outside (0, 1]",
                    tr.net,
                    r.board,
                    r.weight
                );
                sum += r.weight;
                let hosts = self
                    .boards
                    .iter()
                    .find(|p| p.id == r.board)
                    .map(|p| p.plan.tenants.iter().any(|t| t.net.name == tr.net));
                match hosts {
                    Some(true) => {}
                    Some(false) => anyhow::bail!(
                        "tenant '{}' routed to board '{}', whose plan does not host it",
                        tr.net,
                        r.board
                    ),
                    None => anyhow::bail!(
                        "tenant '{}' routed to unknown board '{}'",
                        tr.net,
                        r.board
                    ),
                }
            }
            anyhow::ensure!(
                (sum - 1.0).abs() <= 1e-9,
                "tenant '{}': route weights sum to {sum}, not 1",
                tr.net
            );
        }
        for p in &self.boards {
            for t in &p.plan.tenants {
                let routed = self.routing.tenants.iter().any(|tr| {
                    tr.net == t.net.name && tr.routes.iter().any(|r| r.board == p.id)
                });
                anyhow::ensure!(
                    routed,
                    "board '{}' hosts tenant '{}' but the routing table never routes it there",
                    p.id,
                    t.net.name
                );
            }
        }
        Ok(())
    }

    /// JSON document (deterministic field order; `cost` is derived but
    /// serialized for human consumers).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("version", num(self.version)),
            ("cost", Value::Num(self.cost())),
            (
                "boards",
                Value::Arr(
                    self.boards
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("id", Value::Str(p.id.clone())),
                                ("cost", Value::Num(p.cost)),
                                ("plan", p.plan.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "routing",
                Value::Arr(
                    self.routing
                        .tenants
                        .iter()
                        .map(|tr| {
                            obj(vec![
                                ("net", Value::Str(tr.net.clone())),
                                (
                                    "routes",
                                    Value::Arr(
                                        tr.routes
                                            .iter()
                                            .map(|r| {
                                                obj(vec![
                                                    ("board", Value::Str(r.board.clone())),
                                                    ("weight", Value::Num(r.weight)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize from the versioned fleet-plan format (unknown
    /// versions rejected; the derived `cost` field is ignored) and
    /// validate the routing invariants.
    pub fn from_json(v: &Value) -> crate::Result<FleetPlan> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(
            (FLEET_VERSION_MIN..=FLEET_VERSION).contains(&version),
            "unsupported fleet-plan version {version}: this build reads versions \
             {FLEET_VERSION_MIN}..={FLEET_VERSION} — regenerate with `flexipipe plan --fleet`"
        );
        let mut boards = Vec::new();
        for e in v
            .req("boards")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'boards' must be an array"))?
        {
            boards.push(FleetPlacement {
                id: e.str_field("id")?.to_string(),
                cost: e.f64_field("cost")?,
                plan: DeploymentPlan::from_json(e.req("plan")?)?,
            });
        }
        let mut tenants = Vec::new();
        for e in v
            .req("routing")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'routing' must be an array"))?
        {
            let mut routes = Vec::new();
            for r in e
                .req("routes")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'routes' must be an array"))?
            {
                routes.push(Route {
                    board: r.str_field("board")?.to_string(),
                    weight: r.f64_field("weight")?,
                });
            }
            tenants.push(TenantRoute {
                net: e.str_field("net")?.to_string(),
                routes,
            });
        }
        let plan = FleetPlan {
            version,
            boards,
            routing: RoutingTable { tenants },
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Write the plan to a file (pretty-printed JSON).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a plan from a file. Accepts either a bare fleet-plan object
    /// or a whole `flexipipe plan --fleet --json` document (a
    /// [`FleetPlanSet`] dump), in which case the `best` plan is read.
    /// Every failure carries the path.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<FleetPlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
        match v.get("best") {
            Some(best) => FleetPlan::from_json(best),
            None => FleetPlan::from_json(&v),
        }
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }

    /// The plan's fleet-frontier objective vectors:
    /// `(fps per tenant ↑, [cost, latency per tenant] ↓)`. Errors when
    /// planning records are missing (hand-authored plans must be
    /// regenerated before frontier arithmetic).
    pub fn objectives(&self) -> crate::Result<(Vec<f64>, Vec<f64>)> {
        let ups = self
            .fps_vec()
            .ok_or_else(|| anyhow::anyhow!("fleet plan lacks planning records"))?;
        let lat = self
            .latency_vec()
            .ok_or_else(|| anyhow::anyhow!("fleet plan lacks planning records"))?;
        let mut downs = Vec::with_capacity(lat.len() + 1);
        downs.push(self.cost());
        downs.extend_from_slice(&lat);
        Ok((ups, downs))
    }
}

/// Reference Pareto reduction over pre-extracted objective vectors:
/// non-dominated under strict vector dominance, exact ties keeping the
/// first representative. O(n²) — the executable spec the incremental
/// [`FrontierMerge`] accumulator is pinned against.
fn reference_frontier(objs: &[(Vec<f64>, Vec<f64>)]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| {
            !(0..objs.len())
                .any(|j| j != i && vec_dominates(&objs[j].0, &objs[j].1, &objs[i].0, &objs[i].1))
                && !(0..i).any(|j| objs[j] == objs[i])
        })
        .collect()
}

/// Indices of the non-dominated plans under the fleet objective
/// (fleet cost ↓, per-tenant fps ↑, per-tenant worst-case latency ↓),
/// exact ties deduplicated to the first representative — the reference
/// reduction fleet property tests compare [`FleetPlanner::plan`]'s
/// incremental frontier against. All plans must route the same tenant
/// set in the same order and carry planning records.
pub fn frontier(plans: &[FleetPlan]) -> crate::Result<Vec<usize>> {
    let objs = plans.iter().map(|p| p.objectives()).collect::<crate::Result<Vec<_>>>()?;
    for (i, (ups, downs)) in objs.iter().enumerate() {
        anyhow::ensure!(
            ups.len() == objs[0].0.len() && downs.len() == objs[0].1.len(),
            "fleet plan {i} routes a different tenant set than plan 0"
        );
    }
    Ok(reference_frontier(&objs))
}

// ---------------------------------------------------------------------------
// FleetPlanSet + stats
// ---------------------------------------------------------------------------

/// Effort counters for one [`FleetPlanner::plan`] call — the
/// fleet-level analogue of `ShardStats`, surfaced in the CLI and the
/// result JSON so pruning efficacy is observable (and bench-recorded in
/// `BENCH_fleet.json`).
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// Tenant→board-subset assignments in the enumerated space.
    pub assignments: usize,
    /// Assignments skipped because a (tenant, board) pair is
    /// solo-infeasible, or a used board rejected its sub-workload —
    /// exact skips, taken with or without pruning.
    pub infeasible: usize,
    /// Assignments skipped by the admissible solo-probe bound against
    /// the incumbent frontier (only with [`FleetPlanner::prune`]).
    pub bound_skipped: usize,
    /// Assignments fully expanded into sub-plan combinations.
    pub solved: usize,
    /// Feasible fleet combinations offered to the frontier.
    pub combos: usize,
    /// Single-board planner invocations (sub-solve cache misses).
    pub board_solves: usize,
    /// Sub-solves answered from the cache.
    pub cache_hits: usize,
    /// Solo (tenant, board) probe solves for bounds and exact skips.
    pub solo_probes: usize,
}

impl FleetStats {
    /// JSON object (deterministic field order).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("assignments", num(self.assignments)),
            ("infeasible", num(self.infeasible)),
            ("bound_skipped", num(self.bound_skipped)),
            ("solved", num(self.solved)),
            ("combos", num(self.combos)),
            ("board_solves", num(self.board_solves)),
            ("cache_hits", num(self.cache_hits)),
            ("solo_probes", num(self.solo_probes)),
        ])
    }
}

/// What [`FleetPlanner::plan`] returns: the fleet Pareto frontier (every
/// kept plan is non-dominated — unlike [`crate::plan::PlanSet`], the
/// exhaustive listing is not retained at fleet scale), the scalar
/// objective picks, and the search effort counters.
#[derive(Debug, Clone)]
pub struct FleetPlanSet {
    /// The non-dominated fleet plans, in enumeration order.
    pub plans: Vec<FleetPlan>,
    /// Indices of the frontier plans — always `0..plans.len()`, kept for
    /// shape parity with [`crate::plan::PlanSet`].
    pub frontier: Vec<usize>,
    /// Index of the plan maximizing min-fps (first wins ties).
    pub best_min: usize,
    /// Index of the plan maximizing weighted fps (first wins ties).
    pub best_weighted: usize,
    /// Index of the workload-objective pick.
    pub best: usize,
    /// The objective that selected `best`.
    pub objective: Objective,
    /// Search effort counters.
    pub stats: FleetStats,
}

impl FleetPlanSet {
    /// JSON document for `flexipipe plan --fleet --json`: the frontier
    /// plans, the objective pick inline under `best` (what
    /// [`FleetPlan::load`] reads), the scalar picks as frontier indices,
    /// and the effort counters.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("version", num(FLEET_VERSION)),
            ("objective", Value::Str(self.objective.label().to_string())),
            (
                "frontier",
                Value::Arr(self.frontier.iter().map(|&i| self.plans[i].to_json()).collect()),
            ),
            ("best_min_fps_frontier_index", num(self.best_min)),
            ("best_weighted_fps_frontier_index", num(self.best_weighted)),
            ("best_frontier_index", num(self.best)),
            ("best", self.plans[self.best].to_json()),
            ("stats", self.stats.to_json()),
        ])
    }
}

// ---------------------------------------------------------------------------
// FleetPlanner
// ---------------------------------------------------------------------------

/// A sub-workload solved on one board: which workload tenants it hosts
/// (ascending) and the board's frontier sub-plans with their per-tenant
/// objective vectors. Cached and shared across assignments — the same
/// (board, tenant set) subproblem recurs in many assignments, and
/// restricting to frontier sub-plans is exact: a dominated sub-plan can
/// only produce a dominated fleet combination (fps sums, latency maxes,
/// and cost are all monotone in the sub-plan's coordinates).
struct SubSolve {
    /// Workload tenant indices hosted here, ascending.
    tenant_idx: Vec<usize>,
    /// The board's frontier sub-plans.
    plans: Vec<SubPlan>,
}

/// One frontier sub-plan with its objective vectors pre-extracted.
struct SubPlan {
    plan: DeploymentPlan,
    /// Per-tenant planned fps, parallel to [`SubSolve::tenant_idx`].
    fps: Vec<f64>,
    /// Per-tenant planned worst-case latency (seconds), same order.
    lat: Vec<f64>,
}

/// Sub-solve cache key: (board index, hosted-tenant bitmask,
/// replicated-tenant bitmask restricted to the hosted set — replication
/// changes which constraints the sub-workload enforces, so it is part of
/// the identity).
type SubSolveKey = (usize, u64, u64);
type SubSolveCache = HashMap<SubSolveKey, Result<Arc<SubSolve>, String>>;

/// Places N tenants across the fleet's M boards as one optimization.
///
/// The search enumerates, per tenant, every non-empty board subset of
/// size ≤ [`FleetPlanner::replicas`] (assignments, tenant 0 outermost,
/// subsets ordered smallest-first); solves each used board's
/// sub-workload with the single-board [`Planner`] (sub-solves cached
/// across assignments); and combines per-board frontier sub-plans into
/// fleet plans — fps summing over a tenant's replicas, latency maxing,
/// cost summing over used boards — reduced incrementally to the
/// (cost ↓, fps ↑, latency ↓) frontier by the shared [`FrontierMerge`].
///
/// With [`FleetPlanner::prune`], assignments are bound-skipped against
/// the incumbent frontier using admissible solo-probe bounds (per-tenant
/// fps upper = sum of solo fps over the assigned boards; latency lower =
/// max of solo latencies; cost exact) — incumbents found on earlier
/// assignments prune later ones, and the pruned frontier is bit-equal to
/// the exhaustive one (property-pinned). Solo-infeasible (tenant, board)
/// pairs are skipped exactly in both modes: a model that cannot fit a
/// board alone cannot fit it with company.
///
/// Constraint semantics under replication: a replicated tenant's
/// [`Constraint::MinFps`] floor applies to its *summed* fleet fps (the
/// per-board sub-workload drops the floor); [`Constraint::Slo`] ceilings
/// stay per-board, because fleet latency is the max over replicas —
/// every replica must meet the ceiling on its own.
#[derive(Debug, Clone)]
pub struct FleetPlanner {
    /// The fleet to place onto.
    pub fleet: FleetSpec,
    /// Split granularity forwarded to every per-board [`Planner`].
    pub steps: usize,
    /// Sharing regimes forwarded to every per-board [`Planner`].
    pub schedule: ScheduleMode,
    /// Temporal period bound (seconds) forwarded per board.
    pub max_period_s: f64,
    /// Interleave factor bound forwarded per board.
    pub max_interleave: usize,
    /// Reconfiguration cost model forwarded per board.
    pub reconfig: ReconfigModel,
    /// Solo DES calibration frames forwarded per board.
    pub calib_frames: usize,
    /// Admission ceiling on frames per slice, forwarded per board.
    pub max_slice_frames: usize,
    /// DES validation frames forwarded per board (0 = closed-form only).
    pub sim_frames: usize,
    /// Branch-and-bound: prune inside each board's search *and*
    /// bound-skip whole assignments against the incumbent fleet
    /// frontier. Frontier contents are identical either way.
    pub prune: bool,
    /// Largest number of boards one tenant may be replicated across.
    /// Default 2.
    pub max_replicas: usize,
}

impl FleetPlanner {
    /// Plan onto a fleet (defaults match [`Planner::across`];
    /// `max_replicas` defaults to 2).
    pub fn over(fleet: FleetSpec) -> FleetPlanner {
        FleetPlanner {
            fleet,
            steps: 16,
            schedule: ScheduleMode::Spatial,
            max_period_s: 0.5,
            max_interleave: 1,
            reconfig: ReconfigModel::default(),
            calib_frames: 6,
            max_slice_frames: 4096,
            sim_frames: 0,
            prune: false,
            max_replicas: 2,
        }
    }

    /// Set the split granularity.
    pub fn steps(mut self, steps: usize) -> FleetPlanner {
        self.steps = steps;
        self
    }

    /// Set the sharing regime(s) every board enumerates.
    pub fn schedule(mut self, mode: ScheduleMode) -> FleetPlanner {
        self.schedule = mode;
        self
    }

    /// Set the temporal period bound (seconds).
    pub fn max_period(mut self, seconds: f64) -> FleetPlanner {
        self.max_period_s = seconds;
        self
    }

    /// Set the largest per-tenant interleave factor.
    pub fn interleave(mut self, k: usize) -> FleetPlanner {
        self.max_interleave = k;
        self
    }

    /// Set the reconfiguration cost model.
    pub fn reconfig(mut self, model: ReconfigModel) -> FleetPlanner {
        self.reconfig = model;
        self
    }

    /// Enable the DES validation pass on per-board frontier plans.
    pub fn validate(mut self, frames: usize) -> FleetPlanner {
        self.sim_frames = frames;
        self
    }

    /// Enable branch-and-bound pruning (per-board and fleet-level).
    pub fn prune(mut self, on: bool) -> FleetPlanner {
        self.prune = on;
        self
    }

    /// Set the replication cap (boards per tenant).
    pub fn replicas(mut self, k: usize) -> FleetPlanner {
        self.max_replicas = k;
        self
    }

    /// The single-board [`Planner`] this fleet planner runs on `board`
    /// (every knob forwarded).
    pub fn board_planner(&self, board: &Board) -> Planner {
        Planner {
            boards: vec![board.clone()],
            steps: self.steps,
            schedule: self.schedule,
            max_period_s: self.max_period_s,
            max_interleave: self.max_interleave,
            reconfig: self.reconfig.clone(),
            calib_frames: self.calib_frames,
            max_slice_frames: self.max_slice_frames,
            sim_frames: self.sim_frames,
            prune: self.prune,
        }
    }

    /// Solve one board's sub-workload (memoized). `replicated` marks the
    /// workload tenants whose fps floor is lifted to the fleet level.
    fn solve_board(
        &self,
        workload: &Workload,
        board_idx: usize,
        tenant_idx: &[usize],
        replicated: u64,
        cache: &mut SubSolveCache,
        stats: &mut FleetStats,
    ) -> Result<Arc<SubSolve>, String> {
        let tmask: u64 = tenant_idx.iter().fold(0, |acc, &t| acc | (1 << t));
        let key = (board_idx, tmask, replicated & tmask);
        if let Some(hit) = cache.get(&key) {
            stats.cache_hits += 1;
            return hit.clone();
        }
        stats.board_solves += 1;
        let specs: Vec<TenantSpec> = tenant_idx
            .iter()
            .map(|&t| {
                let spec = &workload.tenants[t];
                let constraints = if replicated & (1 << t) != 0 {
                    // Replicated tenant: the fps floor is checked against
                    // the *summed* fleet rate, so the per-board solve
                    // drops it; SLO ceilings stay (latency maxes over
                    // replicas, so each replica must meet them alone).
                    spec.constraints
                        .iter()
                        .filter(|c| matches!(c, Constraint::Slo(_)))
                        .cloned()
                        .collect()
                } else {
                    spec.constraints.clone()
                };
                TenantSpec {
                    net: spec.net.clone(),
                    weight: spec.weight,
                    constraints,
                }
            })
            .collect();
        let sub = Workload {
            tenants: specs,
            mode: workload.mode,
            objective: workload.objective,
        };
        let planner = self.board_planner(&self.fleet.boards[board_idx].board);
        let result = match planner.plan(&sub) {
            Ok(set) => {
                let mut plans = Vec::with_capacity(set.frontier.len());
                let mut broken = None;
                for &i in &set.frontier {
                    let plan = set.plans[i].clone();
                    match (plan.fps_vec(), plan.latency_vec()) {
                        (Some(fps), Some(lat)) => plans.push(SubPlan { plan, fps, lat }),
                        _ => broken = Some("planner produced a plan without records".to_string()),
                    }
                }
                match broken {
                    Some(e) => Err(e),
                    None => Ok(Arc::new(SubSolve {
                        tenant_idx: tenant_idx.to_vec(),
                        plans,
                    })),
                }
            }
            Err(e) => Err(e.to_string()),
        };
        cache.insert(key, result.clone());
        result
    }

    /// Place the workload across the fleet and reduce every feasible
    /// placement to the (fleet cost ↓, per-tenant fps ↑, worst-case
    /// latency ↓) Pareto frontier. See the type-level docs for the
    /// search structure and exactness argument. Errors when the fleet or
    /// workload is invalid, when tenant model names collide (routing is
    /// keyed by them), when the assignment space exceeds the enumeration
    /// cap, or when no placement is feasible.
    pub fn plan(&self, workload: &Workload) -> crate::Result<FleetPlanSet> {
        workload.validate()?;
        self.fleet.validate()?;
        let n = workload.tenants.len();
        let m = self.fleet.boards.len();
        anyhow::ensure!(n <= 64, "fleet placement supports at most 64 tenants (got {n})");
        for i in 0..n {
            for j in 0..i {
                anyhow::ensure!(
                    workload.tenants[i].net.name != workload.tenants[j].net.name,
                    "duplicate tenant model '{}': fleet routing is keyed by model name",
                    workload.tenants[i].net.name
                );
            }
        }

        // Candidate board subsets per tenant: non-empty, at most
        // max_replicas boards, smallest subsets first (so the
        // cheap/simple placements seed the frontier and bound the rest).
        let cap = self.max_replicas.clamp(1, m);
        let mut subsets: Vec<u32> = (1u32..(1u32 << m))
            .filter(|s| (s.count_ones() as usize) <= cap)
            .collect();
        subsets.sort_by_key(|s| (s.count_ones(), *s));
        let base = subsets.len();
        let space = (base as u128)
            .checked_pow(n as u32)
            .filter(|&s| s <= MAX_ASSIGNMENTS)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "fleet assignment space {base}^{n} exceeds the enumeration cap \
                     ({MAX_ASSIGNMENTS}): reduce boards, tenants, or --max-replicas"
                )
            })?;
        let total = space as usize;

        let mut stats = FleetStats {
            assignments: total,
            ..FleetStats::default()
        };

        // Solo probes: one unconstrained single-tenant solve per
        // (tenant, board). An Err is an *exact* skip for every
        // assignment placing that tenant there (a model that cannot fit
        // the board alone cannot fit it with company); an Ok yields the
        // admissible bounds (solo fps is an upper bound on the tenant's
        // fps under any sharing, solo latency a lower bound on its
        // worst-case latency). Overlay needs two tenants, so its probes
        // run temporal (same full-board solo pipeline).
        let probe_schedule = match self.schedule {
            ScheduleMode::Temporal | ScheduleMode::Overlay => ScheduleMode::Temporal,
            _ => ScheduleMode::Spatial,
        };
        let mut solo: Vec<Vec<Result<(f64, f64), String>>> = Vec::with_capacity(n);
        for spec in &workload.tenants {
            let mut row = Vec::with_capacity(m);
            for fb in &self.fleet.boards {
                stats.solo_probes += 1;
                let probe = Workload {
                    tenants: vec![TenantSpec {
                        net: spec.net.clone(),
                        weight: spec.weight,
                        constraints: Vec::new(),
                    }],
                    mode: workload.mode,
                    objective: Objective::MaxMinFps,
                };
                let planner = self.board_planner(&fb.board).schedule(probe_schedule);
                row.push(match planner.plan(&probe) {
                    Ok(set) => {
                        let fps_ub = set
                            .plans
                            .iter()
                            .filter_map(|p| p.min_fps())
                            .fold(0.0f64, f64::max);
                        let lat_lb = set
                            .plans
                            .iter()
                            .filter_map(|p| p.latency_vec())
                            .map(|v| v[0])
                            .fold(f64::INFINITY, f64::min);
                        Ok((fps_ub, lat_lb))
                    }
                    Err(e) => Err(e.to_string()),
                });
            }
            solo.push(row);
        }

        let mut cache: SubSolveCache = HashMap::new();
        let mut merge = FrontierMerge::default();
        // Live frontier members: candidate index → (plan, ups, downs).
        // Only survivors are retained (fleet plans embed whole networks;
        // keeping every offered candidate would not scale).
        let mut live: HashMap<usize, (FleetPlan, Vec<f64>, Vec<f64>)> = HashMap::new();
        let mut next_idx = 0usize;
        let mut digits = vec![0usize; n];

        for a in 0..total {
            // Mixed-radix decode, tenant 0 outermost (deterministic
            // enumeration order → stable frontier representatives).
            let mut rem = a;
            for t in (0..n).rev() {
                digits[t] = rem % base;
                rem /= base;
            }
            let masks: Vec<u32> = digits.iter().map(|&d| subsets[d]).collect();

            // Exact skip: solo-infeasible (tenant, board) pair.
            let pair_infeasible = (0..n).any(|t| {
                (0..m).any(|b| masks[t] & (1 << b) != 0 && solo[t][b].is_err())
            });
            if pair_infeasible {
                stats.infeasible += 1;
                continue;
            }

            let used: u32 = masks.iter().fold(0, |acc, &mk| acc | mk);
            let cost: f64 = (0..m)
                .filter(|&b| used & (1 << b) != 0)
                .map(|b| self.fleet.boards[b].cost)
                .sum();

            if self.prune {
                // Admissible assignment bound: fps can only sum to the
                // solo upper bounds, latency can only max to at least
                // the solo lower bounds, cost is exact. If an incumbent
                // weakly dominates the bound, it weakly dominates every
                // combination of this assignment — skip it whole. (The
                // incumbent was enumerated earlier, so exact-tie
                // representatives are unchanged: pruned ≡ exhaustive.)
                let ups_bound: Vec<f64> = (0..n)
                    .map(|t| {
                        (0..m)
                            .filter(|&b| masks[t] & (1 << b) != 0)
                            .map(|b| solo[t][b].as_ref().map(|s| s.0).unwrap_or(0.0))
                            .sum()
                    })
                    .collect();
                let mut downs_bound = Vec::with_capacity(n + 1);
                downs_bound.push(cost);
                for t in 0..n {
                    downs_bound.push(
                        (0..m)
                            .filter(|&b| masks[t] & (1 << b) != 0)
                            .map(|b| solo[t][b].as_ref().map(|s| s.1).unwrap_or(0.0))
                            .fold(0.0f64, f64::max),
                    );
                }
                let floor_unreachable = workload.tenants.iter().enumerate().any(|(t, spec)| {
                    plan::fps_floor(&spec.constraints).map_or(false, |f| ups_bound[t] < f)
                });
                let dominated = live.values().any(|(_, u, d)| {
                    vec_weakly_dominates(u, d, &ups_bound, &downs_bound)
                });
                if floor_unreachable || dominated {
                    stats.bound_skipped += 1;
                    continue;
                }
            }

            // Solve every used board's sub-workload (cached).
            let replicated: u64 = (0..n)
                .filter(|&t| masks[t].count_ones() > 1)
                .fold(0, |acc, t| acc | (1 << t));
            let used_boards: Vec<usize> = (0..m).filter(|&b| used & (1 << b) != 0).collect();
            let mut solves: Vec<Arc<SubSolve>> = Vec::with_capacity(used_boards.len());
            let mut board_failed = false;
            for &b in &used_boards {
                let tenant_idx: Vec<usize> =
                    (0..n).filter(|&t| masks[t] & (1 << b) != 0).collect();
                match self.solve_board(workload, b, &tenant_idx, replicated, &mut cache, &mut stats)
                {
                    Ok(s) => solves.push(s),
                    Err(_) => {
                        board_failed = true;
                        break;
                    }
                }
            }
            if board_failed {
                stats.infeasible += 1;
                continue;
            }
            stats.solved += 1;

            // Cross product over per-board frontier sub-plans (first
            // used board outermost).
            let combo_count: usize = solves.iter().map(|s| s.plans.len()).product();
            anyhow::ensure!(
                combo_count <= MAX_COMBOS,
                "assignment expands to {combo_count} sub-plan combinations (cap {MAX_COMBOS}): \
                 reduce boards or --shard-steps"
            );
            let mut choice = vec![0usize; solves.len()];
            for c in 0..combo_count {
                let mut rem = c;
                for i in (0..solves.len()).rev() {
                    choice[i] = rem % solves[i].plans.len();
                    rem /= solves[i].plans.len();
                }
                let mut fps = vec![0.0f64; n];
                let mut lat = vec![0.0f64; n];
                for (i, s) in solves.iter().enumerate() {
                    let sp = &s.plans[choice[i]];
                    for (pos, &t) in s.tenant_idx.iter().enumerate() {
                        fps[t] += sp.fps[pos];
                        lat[t] = lat[t].max(sp.lat[pos]);
                    }
                }
                // Fleet-level fps floors (replicated tenants' per-board
                // floors were lifted here).
                let meets = workload.tenants.iter().enumerate().all(|(t, spec)| {
                    plan::fps_floor(&spec.constraints).map_or(true, |f| fps[t] >= f)
                });
                if !meets {
                    continue;
                }
                stats.combos += 1;
                let ups = fps.clone();
                let mut downs = Vec::with_capacity(n + 1);
                downs.push(cost);
                downs.extend_from_slice(&lat);
                let idx = next_idx;
                next_idx += 1;
                let before: Vec<usize> = merge.members().to_vec();
                if merge.offer_vec(&ups, &downs, idx) {
                    // Build the plan only once it survived the offer.
                    let boards_out: Vec<FleetPlacement> = used_boards
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| FleetPlacement {
                            id: self.fleet.boards[b].id.clone(),
                            cost: self.fleet.boards[b].cost,
                            plan: solves[i].plans[choice[i]].plan.clone(),
                        })
                        .collect();
                    let tenants_out: Vec<TenantRoute> = (0..n)
                        .map(|t| TenantRoute {
                            net: workload.tenants[t].net.name.clone(),
                            routes: used_boards
                                .iter()
                                .enumerate()
                                .filter(|&(_, &b)| masks[t] & (1 << b) != 0)
                                .map(|(i, &b)| {
                                    let s = &solves[i];
                                    let pos = s
                                        .tenant_idx
                                        .iter()
                                        .position(|&x| x == t)
                                        .expect("assigned board hosts the tenant");
                                    Route {
                                        board: self.fleet.boards[b].id.clone(),
                                        weight: s.plans[choice[i]].fps[pos] / fps[t],
                                    }
                                })
                                .collect(),
                        })
                        .collect();
                    let plan = FleetPlan {
                        version: FLEET_VERSION,
                        boards: boards_out,
                        routing: RoutingTable {
                            tenants: tenants_out,
                        },
                    };
                    for dropped in &before {
                        if !merge.members().contains(dropped) {
                            live.remove(dropped);
                        }
                    }
                    live.insert(idx, (plan, ups, downs));
                }
            }
        }

        let frontier_idx = merge.into_indices();
        let mut plans = Vec::with_capacity(frontier_idx.len());
        let mut objs = Vec::with_capacity(frontier_idx.len());
        for idx in frontier_idx {
            let (p, u, d) = live.remove(&idx).expect("frontier member retained");
            plans.push(p);
            objs.push((u, d));
        }
        if plans.is_empty() {
            let mut reasons = Vec::new();
            for (t, row) in solo.iter().enumerate() {
                for (b, r) in row.iter().enumerate() {
                    if let Err(e) = r {
                        reasons.push(format!(
                            "{} on {}: {e}",
                            workload.tenants[t].net.name, self.fleet.boards[b].id
                        ));
                    }
                }
            }
            anyhow::bail!(
                "no feasible fleet placement ({} of {} assignments infeasible){}",
                stats.infeasible,
                stats.assignments,
                if reasons.is_empty() {
                    String::new()
                } else {
                    format!("; solo-infeasible pairs: {}", reasons.join("; "))
                }
            );
        }
        // The survivors are mutually non-dominated and tie-free by
        // construction; the reference reduction must keep all of them.
        debug_assert_eq!(reference_frontier(&objs).len(), objs.len());

        let argmax = |score: &dyn Fn(usize) -> f64| -> usize {
            let mut best = 0;
            for i in 1..plans.len() {
                if score(i) > score(best) {
                    best = i;
                }
            }
            best
        };
        let min_of = |i: usize| objs[i].0.iter().copied().fold(f64::INFINITY, f64::min);
        let weighted_of = |i: usize| -> f64 {
            objs[i]
                .0
                .iter()
                .zip(&workload.tenants)
                .map(|(f, t)| f * t.weight)
                .sum()
        };
        let best_min = argmax(&min_of);
        let best_weighted = argmax(&weighted_of);
        let best = match workload.objective {
            Objective::MaxMinFps => best_min,
            Objective::MaxWeightedFps => best_weighted,
        };
        let frontier = (0..plans.len()).collect();
        Ok(FleetPlanSet {
            plans,
            frontier,
            best_min,
            best_weighted,
            best,
            objective: workload.objective,
            stats,
        })
    }
}

// ---------------------------------------------------------------------------
// Fleet failover
// ---------------------------------------------------------------------------

/// One tenant moved off a lost board onto a surviving peer.
#[derive(Debug, Clone)]
pub struct Migration {
    /// The migrated tenant's model name.
    pub net: String,
    /// The lost board it was displaced from.
    pub from: String,
    /// The surviving board now hosting it.
    pub to: String,
}

/// One replica dropped from a lost board whose tenant is still served by
/// replicas on surviving boards — degraded throughput, not an outage.
#[derive(Debug, Clone)]
pub struct DroppedReplica {
    /// The tenant's model name.
    pub net: String,
    /// The lost board the replica ran on.
    pub board: String,
}

/// One tenant dropped from the fleet entirely, with every reason the
/// failover tried and failed (lost board first, then each peer).
#[derive(Debug, Clone)]
pub struct FleetShedEntry {
    /// The dropped tenant's model name.
    pub net: String,
    /// The lost board it was displaced from.
    pub board: String,
    /// Why no surviving board could admit it (joined per-board reasons).
    pub reason: String,
}

/// Outcome of [`FleetPlanner::replan`]: the degraded fleet plan (if any
/// board still serves anything) and the explicit fate of every displaced
/// tenant — migrated, dropped replica, or shed. Never-silent shedding is
/// the fleet-level contract, same as [`crate::plan::ReplanOutcome`].
#[derive(Debug, Clone)]
pub struct FleetReplanOutcome {
    /// The degraded fleet plan; `None` when nothing survives.
    pub plan: Option<FleetPlan>,
    /// Id of the lost board the fault was applied to.
    pub lost: String,
    /// The lost board's surviving capacity the single-board re-plan was
    /// computed against.
    pub board: Board,
    /// Which [`crate::plan::Planner::replan`] phase decided the lost
    /// board's own re-plan (warm start / delta admission / full search).
    pub phase: ReplanPhase,
    /// Tenants migrated onto surviving peers, in displacement order.
    pub migrated: Vec<Migration>,
    /// Replicas dropped without an outage (surviving replicas remain).
    pub dropped_replicas: Vec<DroppedReplica>,
    /// Tenants dropped from the fleet entirely, with reasons.
    pub shed: Vec<FleetShedEntry>,
}

impl FleetReplanOutcome {
    /// JSON document for `flexipipe replan --fleet-plan` (deterministic
    /// field order).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("replanned", Value::Bool(self.plan.is_some())),
            ("lost", Value::Str(self.lost.clone())),
            ("phase", Value::Str(self.phase.label().to_string())),
            ("board", plan::board_to_json(&self.board)),
            (
                "migrated",
                Value::Arr(
                    self.migrated
                        .iter()
                        .map(|mig| {
                            obj(vec![
                                ("net", Value::Str(mig.net.clone())),
                                ("from", Value::Str(mig.from.clone())),
                                ("to", Value::Str(mig.to.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "dropped_replicas",
                Value::Arr(
                    self.dropped_replicas
                        .iter()
                        .map(|d| {
                            obj(vec![
                                ("net", Value::Str(d.net.clone())),
                                ("board", Value::Str(d.board.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "shed",
                Value::Arr(
                    self.shed
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("net", Value::Str(s.net.clone())),
                                ("board", Value::Str(s.board.clone())),
                                ("reason", Value::Str(s.reason.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "plan",
                self.plan.as_ref().map_or(Value::Null, |p| p.to_json()),
            ),
        ])
    }
}

impl FleetPlanner {
    /// Fleet-level failover: apply `faults` to the board named `lost`
    /// and migrate whatever it can no longer serve onto surviving peers.
    ///
    /// 1. The lost board re-plans its own sub-workload on its surviving
    ///    capacity via the single-board [`Planner::replan`] (warm start
    ///    → delta admission → full search with graceful degradation).
    /// 2. Every tenant that board shed is offered to the surviving peers
    ///    **first-fit in fleet order** (boards already hosting a replica
    ///    of it are skipped): the peer's sub-workload plus the displaced
    ///    tenant is re-planned whole; the first peer that admits it
    ///    takes it ([`Migration`]).
    /// 3. A displaced tenant no peer admits is a [`DroppedReplica`] if
    ///    surviving boards still host it, otherwise a [`FleetShedEntry`]
    ///    with every reason collected — never a silent drop.
    ///
    /// The returned plan's routing table is rebuilt from the surviving
    /// plans' planning records (fps-proportional weights, same
    /// arithmetic as [`FleetPlanner::plan`]); hand-authored plans
    /// without records must be regenerated first.
    pub fn replan(
        &self,
        incumbent: &FleetPlan,
        faults: &crate::fault::FaultPlan,
        lost: &str,
    ) -> crate::Result<FleetReplanOutcome> {
        incumbent.validate()?;
        faults.validate()?;
        let lost_pos = incumbent.boards.iter().position(|p| p.id == lost).ok_or_else(|| {
            anyhow::anyhow!(
                "fleet plan has no board '{lost}' (boards: {})",
                incumbent
                    .boards
                    .iter()
                    .map(|p| p.id.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        let lost_plan = &incumbent.boards[lost_pos].plan;
        let planner = self.board_planner(&lost_plan.board);
        let outcome = planner.replan(lost_plan, faults)?;

        let mut new_plans: Vec<Option<DeploymentPlan>> =
            incumbent.boards.iter().map(|p| Some(p.plan.clone())).collect();
        new_plans[lost_pos] = outcome.plan.clone();

        let mut migrated = Vec::new();
        let mut dropped_replicas = Vec::new();
        let mut shed = Vec::new();
        for e in &outcome.shed {
            let pt = lost_plan
                .tenants
                .iter()
                .find(|t| t.net.name == e.net)
                .ok_or_else(|| {
                    anyhow::anyhow!("shed tenant '{}' is not on the lost board's plan", e.net)
                })?;
            let spec = TenantSpec {
                net: pt.net.clone(),
                weight: pt.weight,
                constraints: pt.constraints.clone(),
            };
            let mut reasons = vec![format!("{lost}: {}", e.reason)];
            let mut landed: Option<String> = None;
            for (i, peer) in incumbent.boards.iter().enumerate() {
                if i == lost_pos {
                    continue;
                }
                let Some(current) = new_plans[i].as_ref() else {
                    continue;
                };
                if current.tenants.iter().any(|t| t.net.name == e.net) {
                    // Already a replica host; migrating here would
                    // double-place the tenant on one board.
                    continue;
                }
                let mut tenants: Vec<TenantSpec> = current
                    .tenants
                    .iter()
                    .map(|t| TenantSpec {
                        net: t.net.clone(),
                        weight: t.weight,
                        constraints: t.constraints.clone(),
                    })
                    .collect();
                tenants.push(spec.clone());
                let workload = Workload {
                    tenants,
                    mode: current.mode,
                    objective: Objective::MaxMinFps,
                };
                match self.board_planner(&current.board).plan(&workload) {
                    Ok(set) => {
                        new_plans[i] = Some(set.plans[set.best].clone());
                        landed = Some(peer.id.clone());
                        break;
                    }
                    Err(err) => reasons.push(format!("{}: {err}", peer.id)),
                }
            }
            match landed {
                Some(to) => migrated.push(Migration {
                    net: e.net.clone(),
                    from: lost.to_string(),
                    to,
                }),
                None => {
                    let replica_survives = incumbent
                        .routing
                        .tenants
                        .iter()
                        .find(|tr| tr.net == e.net)
                        .map_or(false, |tr| tr.routes.iter().any(|r| r.board != lost));
                    if replica_survives {
                        dropped_replicas.push(DroppedReplica {
                            net: e.net.clone(),
                            board: lost.to_string(),
                        });
                    } else {
                        shed.push(FleetShedEntry {
                            net: e.net.clone(),
                            board: lost.to_string(),
                            reason: reasons.join("; "),
                        });
                    }
                }
            }
        }

        let mut placements = Vec::new();
        for (i, p) in incumbent.boards.iter().enumerate() {
            if let Some(pl) = new_plans[i].take() {
                placements.push(FleetPlacement {
                    id: p.id.clone(),
                    cost: p.cost,
                    plan: pl,
                });
            }
        }
        let plan = if placements.is_empty() {
            None
        } else {
            Some(reroute(incumbent, placements)?)
        };
        Ok(FleetReplanOutcome {
            plan,
            lost: lost.to_string(),
            board: outcome.board,
            phase: outcome.phase,
            migrated,
            dropped_replicas,
            shed,
        })
    }
}

/// Rebuild a degraded fleet plan's routing table from the surviving
/// placements' planning records: weights are fps-proportional over each
/// tenant's surviving hosts (the same arithmetic [`FleetPlanner::plan`]
/// routes with), tenant order preserved from the incumbent, fully-shed
/// tenants absent.
fn reroute(incumbent: &FleetPlan, placements: Vec<FleetPlacement>) -> crate::Result<FleetPlan> {
    let mut tenants = Vec::new();
    for tr in &incumbent.routing.tenants {
        let mut hosted: Vec<(String, f64)> = Vec::new();
        for p in &placements {
            if let Some(t) = p.plan.tenants.iter().find(|t| t.net.name == tr.net) {
                let rec = t.record.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "board '{}' has no planning record for '{}' — regenerate the fleet \
                         plan with `flexipipe plan --fleet`",
                        p.id,
                        tr.net
                    )
                })?;
                hosted.push((p.id.clone(), rec.fps));
            }
        }
        if hosted.is_empty() {
            continue;
        }
        let total: f64 = hosted.iter().map(|(_, f)| f).sum();
        tenants.push(TenantRoute {
            net: tr.net.clone(),
            routes: hosted
                .into_iter()
                .map(|(b, f)| Route {
                    board: b,
                    weight: f / total,
                })
                .collect(),
        });
    }
    let plan = FleetPlan {
        version: FLEET_VERSION,
        boards: placements,
        routing: RoutingTable { tenants },
    };
    plan.validate()?;
    Ok(plan)
}

// ---------------------------------------------------------------------------
// Fleet simulation report
// ---------------------------------------------------------------------------

/// One route's DES measurement inside a [`FleetTenantSim`].
#[derive(Debug, Clone)]
pub struct FleetRouteSim {
    /// Fleet board id.
    pub board: String,
    /// Simulated fps this board serves the tenant at.
    pub fps: f64,
    /// This board's simulated share of the tenant's total fps.
    pub weight: f64,
}

/// One tenant's fleet-wide DES measurement: summed fps, worst analytic
/// sojourn over its replicas, and the per-route breakdown.
#[derive(Debug, Clone)]
pub struct FleetTenantSim {
    /// Tenant model name.
    pub net: String,
    /// Simulated fleet fps — the sum over the tenant's routes.
    pub fps: f64,
    /// Worst analytic sojourn bound over the tenant's replicas
    /// (seconds); `None` when any hosting plan lacks the bound.
    pub worst_sojourn_s: Option<f64>,
    /// Per-route measurements, in routing order.
    pub routes: Vec<FleetRouteSim>,
}

/// Fleet-wide DES measurements for one executed [`FleetPlan`]
/// ([`crate::sim::Simulator::simulate_fleet`]): each board's pinned
/// engine runs once, and per-tenant reports merge through the routing
/// table.
#[derive(Debug, Clone)]
pub struct FleetSimReport {
    /// One entry per routed tenant, in routing order.
    pub tenants: Vec<FleetTenantSim>,
}

impl FleetSimReport {
    /// Simulated fleet fps per tenant (routing order).
    pub fn tenant_fps(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.fps).collect()
    }

    /// JSON document for `flexipipe simulate --fleet-plan`
    /// (deterministic field order).
    pub fn to_json(&self) -> Value {
        obj(vec![(
            "tenants",
            Value::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        obj(vec![
                            ("net", Value::Str(t.net.clone())),
                            ("fps", Value::Num(t.fps)),
                            (
                                "worst_sojourn_s",
                                t.worst_sojourn_s.map_or(Value::Null, Value::Num),
                            ),
                            (
                                "routes",
                                Value::Arr(
                                    t.routes
                                        .iter()
                                        .map(|r| {
                                            obj(vec![
                                                ("board", Value::Str(r.board.clone())),
                                                ("fps", Value::Num(r.fps)),
                                                ("weight", Value::Num(r.weight)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::{zc706, zedboard};
    use crate::model::zoo;
    use crate::quant::QuantMode;

    fn tiny_fleet() -> FleetSpec {
        FleetSpec::new().board("edge-a", zedboard(), 1.0)
    }

    fn tiny_set() -> FleetPlanSet {
        let workload = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
        FleetPlanner::over(tiny_fleet()).steps(4).plan(&workload).unwrap()
    }

    #[test]
    fn fleet_spec_round_trips_through_json() {
        let spec = FleetSpec::new()
            .board("dc-zc706", zc706(), 1.0)
            .board("edge-a", zedboard(), 0.25);
        let text = spec.to_json().to_pretty();
        let back = FleetSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_pretty(), text);
        assert_eq!(back.boards[1].board.dsps, zedboard().dsps);
    }

    #[test]
    fn fleet_spec_accepts_board_names_and_defaults_cost() {
        let v = json::parse(
            r#"{"version": 1, "boards": [{"id": "a", "board": "zc706"}]}"#,
        )
        .unwrap();
        let spec = FleetSpec::from_json(&v).unwrap();
        assert_eq!(spec.boards[0].board.dsps, zc706().dsps);
        assert_eq!(spec.boards[0].cost, 1.0);
    }

    #[test]
    fn fleet_spec_rejects_unknown_version_and_duplicate_ids() {
        let v = json::parse(
            r#"{"version": 99, "boards": [{"id": "a", "board": "zc706"}]}"#,
        )
        .unwrap();
        let err = FleetSpec::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        assert!(err.contains("1..=1"), "{err}");

        let dup = FleetSpec::new()
            .board("a", zedboard(), 1.0)
            .board("a", zc706(), 1.0);
        let err = dup.validate().unwrap_err().to_string();
        assert!(err.contains("duplicate fleet board id 'a'"), "{err}");
    }

    #[test]
    fn fleet_plan_rejects_unknown_version() {
        let set = tiny_set();
        let mut v = set.plans[set.best].to_json();
        if let Value::Obj(m) = &mut v {
            m.insert("version".to_string(), num(99));
        }
        let err = FleetPlan::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("fleet-plan version 99"), "{err}");
    }

    #[test]
    fn fleet_plan_round_trips_and_validates() {
        let set = tiny_set();
        let best = &set.plans[set.best];
        best.validate().unwrap();
        let text = best.to_json().to_pretty();
        let back = FleetPlan::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_pretty(), text);
        assert_eq!(back.cost(), 1.0);
        assert_eq!(back.fps_vec().unwrap(), best.fps_vec().unwrap());
    }

    #[test]
    fn fleet_plan_validate_catches_broken_routing() {
        let set = tiny_set();
        // Weight off by 2x: conservation fails.
        let mut bad = set.plans[set.best].clone();
        bad.routing.tenants[0].routes[0].weight = 0.5;
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("sum to 0.5"), "{err}");
        // Route to a board that does not exist.
        let mut bad = set.plans[set.best].clone();
        bad.routing.tenants[0].routes[0].board = "ghost".to_string();
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("unknown board 'ghost'"), "{err}");
        // Hosted tenant with no route back to its board.
        let mut bad = set.plans[set.best].clone();
        bad.routing.tenants.clear();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn duplicate_tenant_models_are_rejected() {
        let workload = Workload::new(QuantMode::W8A8)
            .tenant(zoo::lenet())
            .tenant(zoo::lenet());
        let err = FleetPlanner::over(tiny_fleet())
            .steps(4)
            .plan(&workload)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate tenant model 'lenet'"), "{err}");
    }

    #[test]
    fn unknown_lost_board_is_rejected() {
        let set = tiny_set();
        let err = FleetPlanner::over(tiny_fleet())
            .steps(4)
            .replan(&set.plans[set.best], &crate::fault::FaultPlan::none(), "nope")
            .unwrap_err()
            .to_string();
        assert!(err.contains("no board 'nope'"), "{err}");
        assert!(err.contains("edge-a"), "{err}");
    }

    // Synthetic pin of the placement arithmetic, mirrored numerically in
    // Python (see docs/ARCHITECTURE.md §fleet — the repo's no-toolchain
    // cross-check convention): replication sums fps / maxes latency,
    // routing weights are fps fractions, and the reference frontier
    // keeps exactly the non-dominated cost/fps/latency tuples with ties
    // deduplicated to the first representative.
    #[test]
    fn placement_arithmetic_matches_python_mirror() {
        // Tenant replicated on boards A (8.0 fps, 0.04 s) and
        // B (5.5 fps, 0.07 s).
        let fps_a = 8.0f64;
        let fps_b = 5.5f64;
        let total = fps_a + fps_b;
        assert_eq!(total, 13.5);
        assert_eq!(fps_a / total, 0.5925925925925926);
        assert_eq!(fps_b / total, 0.4074074074074074);
        assert_eq!(0.04f64.max(0.07), 0.07);
        // Identical replicas split exactly in half.
        assert_eq!(fps_a / (fps_a + fps_a), 0.5);

        // Candidates (ups = [fps], downs = [cost, latency]):
        //   c0 solo-A, c1 solo-B, c2 replicated, c3 duplicate of c0.
        let objs = vec![
            (vec![12.5], vec![1.0, 0.05]),
            (vec![7.25], vec![0.6, 0.08]),
            (vec![13.5], vec![1.6, 0.07]),
            (vec![12.5], vec![1.0, 0.05]),
        ];
        assert_eq!(reference_frontier(&objs), vec![0, 1, 2]);
        // The incremental accumulator agrees, including tie dedup.
        let mut merge = FrontierMerge::default();
        for (i, (u, d)) in objs.iter().enumerate() {
            merge.offer_vec(u, d, i);
        }
        assert_eq!(merge.into_indices(), vec![0, 1, 2]);
        // A strictly dominated candidate is rejected and evicts nothing.
        let mut merge = FrontierMerge::default();
        assert!(merge.offer_vec(&[12.5], &[1.0, 0.05], 0));
        assert!(!merge.offer_vec(&[12.0], &[1.0, 0.06], 1));
        assert_eq!(merge.members(), &[0]);
    }

    #[test]
    fn single_board_fleet_weight_is_exactly_one() {
        let set = tiny_set();
        let best = &set.plans[set.best];
        for tr in &best.routing.tenants {
            assert_eq!(tr.routes.len(), 1);
            assert_eq!(tr.routes[0].weight, 1.0);
        }
        // Objectives come straight from the records.
        let (ups, downs) = best.objectives().unwrap();
        assert_eq!(ups, best.fps_vec().unwrap());
        assert_eq!(downs[0], 1.0);
    }
}
