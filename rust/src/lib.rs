//! # FlexiPipe
//!
//! Reproduction of *"FPGA Based Accelerator for Neural Networks Computation
//! with Flexible Pipelining"* (Yi, Sun, Fujita — 2021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's contribution is a **layer-wise pipeline** CNN accelerator
//! whose per-layer compute engines are freely parameterized (input-channel
//! parallelism `C'`, output-channel parallelism `M'`, row parallelism `K`)
//! plus a **resource allocation framework** (Algorithms 1 and 2) that picks
//! those parameters to balance all pipeline stages for a given CNN model and
//! FPGA board. The FPGA itself is hardware we do not have, so this crate
//! substitutes a calibrated board model + cycle-level simulator for the
//! silicon (see DESIGN.md §2), while the *functional* datapath (fixed-point
//! conv with channel-wise shift alignment) runs for real: AOT-compiled JAX/
//! Pallas HLO executed through PJRT from the [`runtime`] module.
//!
//! Module map (one module per subsystem, DESIGN.md §5):
//!
//! - [`model`] — CNN layer/network descriptions + the paper's model zoo
//!   (VGG16, AlexNet, ZF, YOLO) and small functional nets.
//! - [`board`] — FPGA resource models (ZC706 et al.).
//! - [`quant`] — fixed-point arithmetic: the engine's datapath in Rust.
//! - [`alloc`] — Algorithm 1 / Algorithm 2 + baseline allocators
//!   (recurrent [1], fusion/Winograd [2], DNNBuilder-constrained [3]).
//! - [`engine`] — convolution-layer-engine micro-model: cycle counts,
//!   line-buffer geometry, BRAM/LUT/FF cost, address generation.
//! - [`sim`] — event-driven pipeline simulator (stall-accurate) and the
//!   recurrent-architecture simulator.
//! - [`search`] — parallel design-space search: boards × models × modes ×
//!   DSP budgets fan-out with shared precomputation + Pareto frontier.
//! - [`shard`] — multi-tenant board sharding, spatial (partition one
//!   board's DSP/BRAM budget across co-resident models) and temporal
//!   (time-multiplex full-board allocations with a partial-reconfiguration
//!   cost model), merged into one per-tenant-fps Pareto frontier and
//!   validated by the multi-pipeline / time-shared DES.
//! - [`power`] — calibrated power estimation (the paper uses Vivado's
//!   estimate; we use an activity-based analytical model).
//! - [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`.
//! - [`coordinator`] — tokio frame server: the Fig. 4 host↔accelerator loop.
//! - [`report`] — Table I regeneration and paper-vs-measured comparison.
//!
//! A map of how the subsystems fit together — and the invariants the
//! regression suites pin — lives in `docs/ARCHITECTURE.md`.
//!
//! # Quickstart
//!
//! Allocate the paper's framework for a model/board pair, read the
//! closed-form report, and confirm it with the cycle-accurate simulator
//! (the `quickstart` example is the narrated version of this):
//!
//! ```
//! use flexipipe::alloc::{allocator_for, ArchKind};
//! use flexipipe::board::zedboard;
//! use flexipipe::model::zoo;
//! use flexipipe::quant::QuantMode;
//! use flexipipe::sim;
//!
//! let alloc = allocator_for(ArchKind::FlexPipeline)
//!     .allocate(&zoo::lenet(), &zedboard(), QuantMode::W8A8)
//!     .unwrap();
//! let report = alloc.evaluate();
//! assert!(report.fps > 0.0 && report.dsps <= zedboard().dsps);
//!
//! let sim = sim::simulate(&alloc, 3);
//! assert!(sim.makespan > 0);
//! // Frames never wait on later frames: completion times are a prefix.
//! assert_eq!(sim.frame_done.len(), 3);
//! ```

// Every public item carries a doc comment (with units where they apply);
// CI builds rustdoc with `-D warnings`, so a missing doc or a broken
// intra-doc link fails the gate.
#![warn(missing_docs)]

pub mod alloc;
pub mod board;
pub mod coordinator;
pub mod engine;
pub mod model;
pub mod power;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod shard;
pub mod sim;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
