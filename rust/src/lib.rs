//! # FlexiPipe
//!
//! Reproduction of *"FPGA Based Accelerator for Neural Networks Computation
//! with Flexible Pipelining"* (Yi, Sun, Fujita — 2021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's contribution is a **layer-wise pipeline** CNN accelerator
//! whose per-layer compute engines are freely parameterized (input-channel
//! parallelism `C'`, output-channel parallelism `M'`, row parallelism `K`)
//! plus a **resource allocation framework** (Algorithms 1 and 2) that picks
//! those parameters to balance all pipeline stages for a given CNN model and
//! FPGA board. The FPGA itself is hardware we do not have, so this crate
//! substitutes a calibrated board model + cycle-level simulator for the
//! silicon (see DESIGN.md §2), while the *functional* datapath (fixed-point
//! conv with channel-wise shift alignment) runs for real: AOT-compiled JAX/
//! Pallas HLO executed through PJRT from the [`runtime`] module.
//!
//! The public API is **plan-centric** — one spine from workload to
//! serving: a [`plan::Workload`] (tenants, constraints, objective) goes
//! through the [`plan::Planner`] facade (solo allocation, spatial /
//! temporal / overlay board sharing, or a multi-board sweep) into a
//! versioned, JSON-serializable [`plan::DeploymentPlan`] — the only
//! currency between subsystems. One [`sim::Simulate`] call executes a
//! plan cycle-accurately;
//! [`coordinator::Coordinator::start_planned`] serves it. A plan written
//! to disk re-simulates bit-identically to the in-process search, so
//! plans are diffed, shipped, and regression-pinned as files
//! (`flexipipe plan … --json plan.json`, then
//! `flexipipe simulate --plan plan.json` / `flexipipe serve --plan
//! plan.json`).
//!
//! Module map (one module per subsystem, DESIGN.md §5):
//!
//! - [`model`] — CNN layer/network descriptions + the paper's model zoo
//!   (VGG16, AlexNet, ZF, YOLO) and small functional nets.
//! - [`board`] — FPGA resource models (ZC706 et al.).
//! - [`quant`] — fixed-point arithmetic: the engine's datapath in Rust.
//! - [`alloc`] — Algorithm 1 / Algorithm 2 + baseline allocators
//!   (recurrent [1], fusion/Winograd [2], DNNBuilder-constrained [3]).
//! - [`engine`] — convolution-layer-engine micro-model: cycle counts,
//!   line-buffer geometry, BRAM/LUT/FF cost, address generation.
//! - [`plan`] — the public spine: `Workload` → `Planner` →
//!   serializable `DeploymentPlan`, plus failover re-planning
//!   ([`plan::Planner::replan`]).
//! - [`fault`] — fault tolerance: seeded [`fault::FaultPlan`] scenarios
//!   injected into the DES ([`sim::Simulator::simulate_faulted`]) and
//!   typed plan deltas ([`fault::PlanDiff`]) with drain-overlapped
//!   reconfiguration costs.
//! - [`fleet`] — fleet-scale planning: place N tenants across M
//!   heterogeneous boards ([`fleet::FleetPlanner`]) with hot-tenant
//!   replication, cold-tenant spill onto shared boards, a versioned
//!   [`fleet::FleetPlan`] (per-board plans + routing table), a global
//!   (fleet cost ↓, fps ↑, latency ↓) frontier, and cross-board failover
//!   ([`fleet::FleetPlanner::replan`]).
//! - [`ingest`] — traffic-driven serving: seeded open-loop workloads
//!   ([`ingest::TraceSpec`]), deterministic trace replay against a plan's
//!   timeline ([`ingest::serve_trace`] → measured latency tails vs. the
//!   analytic sojourn bound), and the live bounded-queue front-end
//!   ([`ingest::IngestService`]) with typed admission control.
//! - [`sim`] — event-driven pipeline simulator (stall-accurate);
//!   [`sim::Simulate`] executes whole deployment plans.
//! - [`search`] — parallel design-space search: boards × models × modes ×
//!   DSP budgets fan-out with shared precomputation + Pareto frontier.
//! - [`shard`] — multi-tenant board sharding, spatial (partition one
//!   board's DSP/BRAM budget across co-resident models) and temporal
//!   (time-multiplex full-board allocations with a partial-reconfiguration
//!   cost model), merged into one per-tenant-fps Pareto frontier and
//!   validated by the multi-pipeline / time-shared DES — the search
//!   engine [`plan::Planner`] fronts.
//! - [`power`] — calibrated power estimation (the paper uses Vivado's
//!   estimate; we use an activity-based analytical model).
//! - [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`.
//! - [`coordinator`] — tokio frame server: the Fig. 4 host↔accelerator
//!   loop, including the plan-driven multi-tenant service
//!   ([`coordinator::Coordinator::start_planned`]).
//! - [`control`] — operator control plane: a dependency-free HTTP/1.1
//!   API over a live [`ingest::IngestService`] (health, queues, plan
//!   apply/replan, submit with deadlines, deterministic replay), with a
//!   socket-free handler core ([`control::ControlPlane::handle`]).
//! - [`report`] — Table I regeneration and paper-vs-measured comparison.
//!
//! A map of how the subsystems fit together — and the invariants the
//! regression suites pin — lives in `docs/ARCHITECTURE.md`.
//!
//! # Quickstart: the plan-centric flow
//!
//! Describe the workload, plan it onto a board, and execute the plan with
//! the cycle-accurate simulator:
//!
//! ```
//! use flexipipe::board::zedboard;
//! use flexipipe::model::zoo;
//! use flexipipe::plan::{Planner, Workload};
//! use flexipipe::quant::QuantMode;
//! use flexipipe::sim::{Simulate, Simulator};
//!
//! let workload = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
//! let set = Planner::on(zedboard()).steps(4).plan(&workload).unwrap();
//! let plan = &set.plans[set.best];
//! let report = Simulator::default().simulate(plan).unwrap();
//! assert!(report.tenants[0].fps > 0.0);
//! ```
//!
//! # Single-allocation quickstart
//!
//! The Sec. 4 machinery is still directly addressable — allocate one
//! model/board pair, read the closed-form report, and confirm it with the
//! simulator (the `quickstart` example is the narrated version of this):
//!
//! ```
//! use flexipipe::alloc::{allocator_for, ArchKind};
//! use flexipipe::board::zedboard;
//! use flexipipe::model::zoo;
//! use flexipipe::quant::QuantMode;
//! use flexipipe::sim;
//!
//! let alloc = allocator_for(ArchKind::FlexPipeline)
//!     .allocate(&zoo::lenet(), &zedboard(), QuantMode::W8A8)
//!     .unwrap();
//! let report = alloc.evaluate();
//! assert!(report.fps > 0.0 && report.dsps <= zedboard().dsps);
//!
//! let sim = sim::simulate(&alloc, 3);
//! assert!(sim.makespan > 0);
//! // Frames never wait on later frames: completion times are a prefix.
//! assert_eq!(sim.frame_done.len(), 3);
//! ```

// Every public item carries a doc comment (with units where they apply);
// CI builds rustdoc with `-D warnings`, so a missing doc or a broken
// intra-doc link fails the gate.
#![warn(missing_docs)]

pub mod alloc;
pub mod board;
pub mod control;
pub mod coordinator;
pub mod engine;
pub mod fault;
pub mod fleet;
pub mod ingest;
pub mod model;
pub mod plan;
pub mod power;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod shard;
pub mod sim;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
