//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The interchange contract with `python/compile/aot.py` (see that file's
//! docs): HLO **text** + `manifest.json`. Text is mandatory — jax ≥ 0.5
//! serializes HloModuleProto with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs here: `Runtime::load` compiles every artifact once at
//! startup (or lazily), and [`Runtime::execute_i8`] is the only thing on
//! the request path.
//!
//! [`backend`] abstracts the execution engine behind the serving stack:
//! [`PjrtBackend`] wraps this runtime, [`SimBackend`] is a deterministic
//! in-process substitute (quantized reference operators, seeded weights)
//! that needs no artifacts — the coordinator auto-selects PJRT when
//! `manifest.json` exists and SimBackend otherwise.

pub mod backend;
pub mod manifest;

pub use backend::{Backend, PjrtBackend, SimBackend, SIM_BATCHES};
pub use manifest::{Artifact, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A loaded PJRT runtime serving one artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    /// Compiled executables, keyed by artifact name (lazy, interior-mutable
    /// so `execute` can take `&self` from the coordinator's worker thread).
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: Mutex::new(HashMap::new()),
        })
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    fn executable(
        &self,
        name: &str,
    ) -> crate::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let art = self.manifest.get(name)?;
        let path = self.dir.join(&art.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-UTF-8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.exes
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact (startup warm-up so the serving path
    /// never pays compilation latency).
    pub fn warm_up(&self) -> crate::Result<()> {
        for a in &self.manifest.artifacts {
            self.executable(&a.name)?;
        }
        Ok(())
    }

    /// Execute an int8 artifact on a full batch of frames.
    ///
    /// `frames` must contain exactly `batch × frame_elems` values in CHW
    /// layout (the golden-file layout). Returns `batch × out_elems` values.
    pub fn execute_i8(&self, name: &str, frames: &[i8]) -> crate::Result<Vec<i8>> {
        let art = self.manifest.get(name)?;
        anyhow::ensure!(art.bits == 8, "{name} is not an 8-bit artifact");
        let want = art.input_elems();
        anyhow::ensure!(
            frames.len() == want,
            "{name}: expected {want} input elements, got {}",
            frames.len()
        );
        let exe = self.executable(name)?;
        // i8 has no NativeType impl in the crate (no vec1); build the
        // literal from raw bytes instead (i8 and u8 share representation).
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(frames.as_ptr() as *const u8, frames.len()) };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &art.input_shape,
            bytes,
        )
        .map_err(|e| anyhow::anyhow!("literal: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        out.to_vec::<i8>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    /// Read golden input frames for an artifact (testing/e2e).
    pub fn golden_inputs(&self, name: &str) -> crate::Result<Vec<i8>> {
        let art = self.manifest.get(name)?;
        read_i8(self.dir.join(&art.golden.input))
    }

    /// Read golden outputs for an artifact (testing/e2e).
    pub fn golden_outputs(&self, name: &str) -> crate::Result<Vec<i8>> {
        let art = self.manifest.get(name)?;
        read_i8(self.dir.join(&art.golden.output))
    }
}

/// Read a little-endian i8 binary file.
pub fn read_i8(path: impl AsRef<Path>) -> crate::Result<Vec<i8>> {
    let bytes = std::fs::read(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
    Ok(bytes.into_iter().map(|b| b as i8).collect())
}

/// Default artifact directory: `$FLEXIPIPE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FLEXIPIPE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration-grade tests that need built artifacts live in
    /// rust/tests/runtime_golden.rs; here only pure helpers.
    #[test]
    fn read_i8_round_trips_sign() {
        let dir = std::env::temp_dir().join("flexipipe_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        std::fs::write(&p, [0u8, 127, 128, 255]).unwrap();
        assert_eq!(read_i8(&p).unwrap(), vec![0, 127, -128, -1]);
    }

    #[test]
    fn default_dir_env_override() {
        // (can't set env safely in parallel tests; just check the default)
        assert!(default_artifact_dir().ends_with("artifacts") || std::env::var("FLEXIPIPE_ARTIFACTS").is_ok());
    }
}
