//! Artifact manifest: the typed view of `artifacts/manifest.json` emitted
//! by `python/compile/aot.py` (the Python↔Rust interchange contract).

use crate::util::json::{self, Value};
use std::path::Path;

/// Golden-file description for an artifact.
#[derive(Debug, Clone)]
pub struct Golden {
    /// Frames in the golden input/output files.
    pub frames: usize,
    /// Input file path, relative to the artifact directory.
    pub input: String,
    /// Expected-output file path, relative to the artifact directory.
    pub output: String,
    /// Elements per input frame.
    pub frame_elems: usize,
    /// Elements per output frame.
    pub out_elems: usize,
}

/// One compiled executable variant (a net at a fixed batch size).
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Unique artifact name (`net_bBATCH_WxAx` convention).
    pub name: String,
    /// Network the artifact executes.
    pub net: String,
    /// Batch size the HLO was compiled at.
    pub batch: usize,
    /// Quantization width (8 or 16).
    pub bits: usize,
    /// Row parallelism the kernel was compiled with.
    pub row_parallelism: usize,
    /// HLO text file path, relative to the artifact directory.
    pub hlo: String,
    /// Input tensor shape (batch first).
    pub input_shape: Vec<usize>,
    /// Output tensor shape (batch first).
    pub output_shape: Vec<usize>,
    /// Golden-file description for bit-exact checking.
    pub golden: Golden,
    /// SHA-256 of the HLO text (staleness detection).
    pub hlo_sha256: String,
}

impl Artifact {
    /// Total input elements per execution (batch × frame).
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Total output elements per execution.
    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: usize,
    /// Every compiled variant the directory holds.
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Parse from a JSON value.
    pub fn from_json(v: &Value) -> crate::Result<Manifest> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let artifacts = v
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'artifacts' must be an array"))?
            .iter()
            .map(parse_artifact)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Manifest { version, artifacts })
    }

    /// Load from disk.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Find an artifact by exact name.
    pub fn get(&self, name: &str) -> crate::Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact '{name}' (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Artifacts for a net, sorted by batch size ascending — the batcher
    /// picks the largest compiled batch ≤ queue depth.
    pub fn variants(&self, net: &str, bits: usize) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.net == net && a.bits == bits)
            .collect();
        v.sort_by_key(|a| a.batch);
        v
    }
}

fn parse_artifact(v: &Value) -> crate::Result<Artifact> {
    let shape = |key: &str| -> crate::Result<Vec<usize>> {
        v.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'{key}' must be an array"))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("'{key}' entries must be integers"))
            })
            .collect()
    };
    let g = v.req("golden")?;
    let bits = match v.str_field("dtype")? {
        "s8" => 8,
        "s16" => 16,
        other => anyhow::bail!("unsupported dtype '{other}'"),
    };
    anyhow::ensure!(v.usize_field("bits")? == bits, "bits/dtype mismatch");
    Ok(Artifact {
        name: v.str_field("name")?.to_string(),
        net: v.str_field("net")?.to_string(),
        batch: v.usize_field("batch")?,
        bits,
        row_parallelism: v.usize_field("row_parallelism")?,
        hlo: v.str_field("hlo")?.to_string(),
        input_shape: shape("input_shape")?,
        output_shape: shape("output_shape")?,
        golden: Golden {
            frames: g.usize_field("frames")?,
            input: g.str_field("input")?.to_string(),
            output: g.str_field("output")?.to_string(),
            frame_elems: g.usize_field("frame_elems")?,
            out_elems: g.usize_field("out_elems")?,
        },
        hlo_sha256: v.str_field("hlo_sha256")?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{"version":1,"artifacts":[{
            "name":"tinycnn_b2_8b","net":"tinycnn","batch":2,"bits":8,
            "row_parallelism":2,"hlo":"tinycnn_b2_8b.hlo.txt",
            "input_shape":[2,3,32,32],"output_shape":[2,10],"dtype":"s8",
            "golden":{"frames":3,"input":"i.bin","output":"o.bin",
                      "frame_elems":3072,"out_elems":10},
            "hlo_sha256":"abc"}]}"#
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&json::parse(sample()).unwrap()).unwrap();
        let a = m.get("tinycnn_b2_8b").unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.input_elems(), 2 * 3072);
        assert_eq!(a.output_elems(), 20);
    }

    #[test]
    fn get_unknown_lists_available() {
        let m = Manifest::from_json(&json::parse(sample()).unwrap()).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("tinycnn_b2_8b"));
    }

    #[test]
    fn variants_sorted_by_batch() {
        let mut m = Manifest::from_json(&json::parse(sample()).unwrap()).unwrap();
        let mut a1 = m.artifacts[0].clone();
        a1.name = "tinycnn_b8_8b".into();
        a1.batch = 8;
        m.artifacts.insert(0, a1);
        let v = m.variants("tinycnn", 8);
        assert_eq!(v.len(), 2);
        assert!(v[0].batch < v[1].batch);
        assert!(m.variants("tinycnn", 16).is_empty());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = sample().replace("\"version\":1", "\"version\":9");
        assert!(Manifest::from_json(&json::parse(&bad).unwrap()).is_err());
    }
}
