//! Execution backends for the serving stack.
//!
//! [`Backend`] is the contract the [`crate::coordinator`] batches against:
//! a set of batch-size variants plus an `execute_i8` entry point. Two
//! implementations:
//!
//! - [`PjrtBackend`] — the AOT-compiled HLO artifacts through PJRT
//!   (the original path; needs `make artifacts` + real xla bindings).
//! - [`SimBackend`] — a deterministic in-process reference: the quantized
//!   golden operators of [`crate::quant::ops`] run the network directly,
//!   with weights generated from a seed derived from the network name.
//!   No artifacts, no PJRT, bit-stable across runs and platforms — the
//!   backend the serving/runtime tests (and artifact-free CI) run on.
//!
//! Selection rule: PJRT when `artifacts/manifest.json` exists
//! ([`Coordinator::start_auto`]), SimBackend otherwise. The plan-driven
//! service ([`Coordinator::start_planned`]) always runs on SimBackend —
//! one deterministic backend per tenant of a
//! [`crate::plan::DeploymentPlan`], built from the plan's embedded
//! networks so a plan file serves without any artifact or zoo lookup.
//!
//! [`Coordinator::start_auto`]: crate::coordinator::Coordinator::start_auto
//! [`Coordinator::start_planned`]: crate::coordinator::Coordinator::start_planned

use super::Runtime;
use crate::model::{Layer, Network};
use crate::quant::ops::{conv_grouped_fixed, fc_fixed, maxpool_fixed, Chw, ConvParams};
use crate::quant::QuantMode;
use crate::util::prop::Rng;
use std::path::PathBuf;

/// What the coordinator needs from an execution engine. Implementations
/// live on the coordinator's worker thread (constructed there by a `Send`
/// factory), so the trait itself needs no `Send` bound — PJRT clients
/// are `!Send`.
pub trait Backend {
    /// Human label for diagnostics (`"pjrt-cpu"`, `"sim"`).
    fn platform(&self) -> String;
    /// Batch-size variants, `(name, batch)` sorted by batch ascending —
    /// the batcher picks the largest batch ≤ queue depth.
    fn variants(&self) -> Vec<(String, usize)>;
    /// Elements per input frame.
    fn frame_elems(&self) -> usize;
    /// Elements per output frame.
    fn out_elems(&self) -> usize;
    /// Execute one variant on a full batch (`batch × frame_elems` values,
    /// CHW per frame); returns `batch × out_elems` values.
    fn execute_i8(&self, name: &str, frames: &[i8]) -> crate::Result<Vec<i8>>;
}

/// Default batch-size variants a [`SimBackend`] serves.
pub const SIM_BATCHES: &[usize] = &[1, 4, 8];

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// The artifact-backed PJRT path as a [`Backend`].
pub struct PjrtBackend {
    rt: Runtime,
    variants: Vec<(String, usize)>,
    frame_elems: usize,
    out_elems: usize,
}

impl PjrtBackend {
    /// Open an artifact directory and select `net`'s `bits`-bit variants.
    pub fn open(dir: impl Into<PathBuf>, net: &str, bits: usize) -> crate::Result<PjrtBackend> {
        let rt = Runtime::load(dir.into())?;
        let variants: Vec<(String, usize)> = rt
            .manifest()
            .variants(net, bits)
            .iter()
            .map(|a| (a.name.clone(), a.batch))
            .collect();
        anyhow::ensure!(
            !variants.is_empty(),
            "no artifacts for net '{net}' at {bits}-bit — run `make artifacts`"
        );
        let (frame_elems, out_elems) = {
            let art = rt.manifest().get(&variants[0].0)?;
            (art.golden.frame_elems, art.golden.out_elems)
        };
        Ok(PjrtBackend {
            rt,
            variants,
            frame_elems,
            out_elems,
        })
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        format!("pjrt-{}", self.rt.platform())
    }

    fn variants(&self) -> Vec<(String, usize)> {
        self.variants.clone()
    }

    fn frame_elems(&self) -> usize {
        self.frame_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn execute_i8(&self, name: &str, frames: &[i8]) -> crate::Result<Vec<i8>> {
        self.rt.execute_i8(name, frames)
    }
}

// ---------------------------------------------------------------------------
// SimBackend
// ---------------------------------------------------------------------------

/// One instantiated layer of the reference datapath.
enum SimLayer {
    Conv {
        p: ConvParams,
        /// Grouped-conv factor (AlexNet's split layers); `p` holds the
        /// per-group channel count and the `[M][C/g][R][S]` weights.
        groups: usize,
        stride: usize,
        pad: usize,
        relu: bool,
    },
    Pool {
        r: usize,
        stride: usize,
    },
    Fc {
        w: Vec<i64>,
        bias: Vec<i64>,
        rshift: Vec<u32>,
        relu: bool,
    },
}

/// Deterministic in-process backend: the quantized reference operators of
/// [`crate::quant::ops`] with seeded pseudo-random weights.
///
/// Determinism contract: weights depend only on the network *name* and
/// layer order (xorshift64* stream, seed = FNV-1a of the name), and the
/// operators are pure integer arithmetic — two instances of the same
/// network produce bit-identical outputs on every platform. That makes
/// `execute_i8` its own golden oracle: tests compare a served response
/// against a direct [`SimBackend::forward_frame`] call.
pub struct SimBackend {
    name: String,
    input: (usize, usize, usize),
    layers: Vec<SimLayer>,
    batches: Vec<usize>,
    frame_elems: usize,
    out_elems: usize,
}

/// FNV-1a, so the weight stream is a stable function of the net name.
fn seed_from_name(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        })
}

impl SimBackend {
    /// Instantiate `net` with deterministic weights, serving the given
    /// batch sizes (deduplicated, sorted ascending).
    pub fn new(net: &Network, batches: &[usize]) -> crate::Result<SimBackend> {
        net.validate()?;
        anyhow::ensure!(!net.layers.is_empty(), "SimBackend: network has no layers");
        let mut batches: Vec<usize> = batches.iter().copied().filter(|&b| b >= 1).collect();
        batches.sort_unstable();
        batches.dedup();
        anyhow::ensure!(!batches.is_empty(), "SimBackend needs at least one batch size");

        let mut rng = Rng::new(seed_from_name(&net.name));
        let last = net.layers.len() - 1;
        let mut layers = Vec::with_capacity(net.layers.len());
        for (i, l) in net.layers.iter().enumerate() {
            // Hidden layers ReLU; the final layer emits raw logits.
            let relu = i < last;
            match l {
                Layer::Conv(c) => {
                    // Scale the psum back near activation range. Random
                    // ±2 weights make the psum a zero-mean walk whose std
                    // grows like √(C_eff·R·S·E[w²]), not like the worst
                    // case — shifting by the worst case's bit length
                    // collapses every activation to {−1,0} within three
                    // layers (verified numerically), so shift by *half*
                    // the bit length (≈ log2 of the std gain) instead.
                    // Grouped convs accumulate over C/groups channels.
                    let c_eff = c.c / c.groups;
                    let gain = (c_eff * c.r * c.s * 2) as u64;
                    let rshift = (64 - gain.leading_zeros()) / 2;
                    layers.push(SimLayer::Conv {
                        p: ConvParams {
                            w: (0..c.m * c_eff * c.r * c.s).map(|_| rng.range(-2, 2)).collect(),
                            m: c.m,
                            c: c_eff,
                            r: c.r,
                            s: c.s,
                            bias: (0..c.m).map(|_| rng.range(-64, 64)).collect(),
                            lshift: vec![0; c.c],
                            rshift: vec![rshift; c.m],
                        },
                        groups: c.groups,
                        stride: c.stride,
                        pad: c.pad,
                        relu,
                    });
                }
                Layer::Pool(p) => layers.push(SimLayer::Pool {
                    r: p.r,
                    stride: p.stride,
                }),
                Layer::Fc(f) => {
                    let gain = (f.n_in * 2) as u64;
                    let rshift = (64 - gain.leading_zeros()) / 2;
                    layers.push(SimLayer::Fc {
                        w: (0..f.n_out * f.n_in).map(|_| rng.range(-2, 2)).collect(),
                        bias: (0..f.n_out).map(|_| rng.range(-64, 64)).collect(),
                        rshift: vec![rshift; f.n_out],
                        relu,
                    });
                }
            }
        }

        let (c0, h0, w0) = net.input;
        let out_elems = match net.layers[last] {
            Layer::Fc(f) => f.n_out,
            Layer::Conv(c) => c.m * c.h * c.w,
            Layer::Pool(p) => p.c * p.h * p.w,
        };
        Ok(SimBackend {
            name: net.name.clone(),
            input: net.input,
            layers,
            batches,
            frame_elems: c0 * h0 * w0,
            out_elems,
        })
    }

    /// Run one frame through the reference datapath (the oracle the served
    /// path is tested against).
    pub fn forward_frame(&self, frame: &[i8]) -> crate::Result<Vec<i8>> {
        anyhow::ensure!(
            frame.len() == self.frame_elems,
            "frame must have {} elements, got {}",
            self.frame_elems,
            frame.len()
        );
        let (c0, h0, w0) = self.input;
        let mut x = Chw::from_i8(c0, h0, w0, frame);
        let mut flat: Option<Vec<i64>> = None;
        for l in &self.layers {
            match l {
                SimLayer::Conv { p, groups, stride, pad, relu } => {
                    x = conv_grouped_fixed(&x, p, *groups, *stride, *pad, QuantMode::W8A8, *relu);
                }
                SimLayer::Pool { r, stride } => {
                    x = maxpool_fixed(&x, *r, *stride);
                }
                SimLayer::Fc { w, bias, rshift, relu } => {
                    let input = match flat.take() {
                        Some(v) => v,
                        None => x.data.clone(),
                    };
                    flat = Some(fc_fixed(&input, w, bias, rshift, QuantMode::W8A8, *relu));
                }
            }
        }
        let out = flat.unwrap_or(x.data);
        // shift_sat already clamped everything to the 8-bit rails.
        Ok(out.into_iter().map(|v| v as i8).collect())
    }

    /// The variant name this backend gives a batch size.
    pub fn variant_name(&self, batch: usize) -> String {
        format!("{}_b{}_sim8", self.name, batch)
    }
}

impl Backend for SimBackend {
    fn platform(&self) -> String {
        "sim".into()
    }

    fn variants(&self) -> Vec<(String, usize)> {
        self.batches
            .iter()
            .map(|&b| (self.variant_name(b), b))
            .collect()
    }

    fn frame_elems(&self) -> usize {
        self.frame_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn execute_i8(&self, name: &str, frames: &[i8]) -> crate::Result<Vec<i8>> {
        let batch = self
            .batches
            .iter()
            .copied()
            .find(|&b| self.variant_name(b) == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no sim variant '{name}' (have: {})",
                    self.variants()
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        let want = batch * self.frame_elems;
        anyhow::ensure!(
            frames.len() == want,
            "{name}: expected {want} input elements, got {}",
            frames.len()
        );
        let mut out = Vec::with_capacity(batch * self.out_elems);
        for f in 0..batch {
            out.extend(self.forward_frame(
                &frames[f * self.frame_elems..(f + 1) * self.frame_elems],
            )?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn frame(elems: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..elems).map(|_| rng.range(-128, 127) as i8).collect()
    }

    #[test]
    fn sim_backend_shapes_match_the_net() {
        let be = SimBackend::new(&zoo::tinycnn(), &[1, 4]).unwrap();
        assert_eq!(be.frame_elems(), 3 * 32 * 32);
        assert_eq!(be.out_elems(), 10);
        assert_eq!(
            be.variants(),
            vec![("tinycnn_b1_sim8".to_string(), 1), ("tinycnn_b4_sim8".to_string(), 4)]
        );
    }

    #[test]
    fn sim_backend_is_deterministic() {
        let a = SimBackend::new(&zoo::lenet(), &[1]).unwrap();
        let b = SimBackend::new(&zoo::lenet(), &[1]).unwrap();
        let f = frame(a.frame_elems(), 7);
        assert_eq!(
            a.execute_i8("lenet_b1_sim8", &f).unwrap(),
            b.execute_i8("lenet_b1_sim8", &f).unwrap()
        );
    }

    #[test]
    fn sim_backend_outputs_are_nondegenerate() {
        // Guard against an all-saturated or all-zero datapath, which would
        // make the serving correctness tests vacuous.
        let be = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
        let a = be.execute_i8("tinycnn_b1_sim8", &frame(be.frame_elems(), 1)).unwrap();
        let b = be.execute_i8("tinycnn_b1_sim8", &frame(be.frame_elems(), 2)).unwrap();
        assert_ne!(a, b, "different frames must map to different outputs");
        assert!(a.iter().any(|&v| v != a[0]), "output is constant: {a:?}");
    }

    #[test]
    fn sim_backend_serves_alexnet_artifact_free() {
        // The whole point of grouped-conv support: AlexNet (grouped layers
        // 3, 6, 7) instantiates and produces deterministic, nondegenerate
        // outputs with no artifacts.
        let a = SimBackend::new(&zoo::alexnet(), &[1]).unwrap();
        let b = SimBackend::new(&zoo::alexnet(), &[1]).unwrap();
        assert_eq!(a.frame_elems(), 3 * 227 * 227);
        assert_eq!(a.out_elems(), 1000);
        let f = frame(a.frame_elems(), 11);
        let out = a.execute_i8("alexnet_b1_sim8", &f).unwrap();
        assert_eq!(out, b.execute_i8("alexnet_b1_sim8", &f).unwrap());
        assert!(out.iter().any(|&v| v != out[0]), "degenerate output");
        let other = a.execute_i8("alexnet_b1_sim8", &frame(a.frame_elems(), 12)).unwrap();
        assert_ne!(out, other);
    }

    #[test]
    fn grouped_conv_net_matches_split_and_concat_of_ungrouped_halves() {
        // Golden: a one-layer grouped net must equal running each channel
        // band through an equivalent *ungrouped* net and concatenating —
        // with the grouped net's own weight stream transplanted, since
        // weights are a function of the network name.
        use crate::model::{gconv, Network};
        let grouped_net = Network {
            name: "g2".into(),
            input: (4, 6, 6),
            layers: vec![gconv(4, 6, 6, 6, 3, 1, 1, 2)],
        };
        let be = SimBackend::new(&grouped_net, &[1]).unwrap();
        let f = frame(be.frame_elems(), 3);
        let got = be.forward_frame(&f).unwrap();

        // Reconstruct the reference by hand from the same weight stream.
        let mut rng = Rng::new(seed_from_name("g2"));
        let (cg, mg, r) = (2usize, 3usize, 3usize);
        let w: Vec<i64> = (0..6 * cg * r * r).map(|_| rng.range(-2, 2)).collect();
        let bias: Vec<i64> = (0..6).map(|_| rng.range(-64, 64)).collect();
        let gain = (cg * r * r * 2) as u64;
        let rshift = (64 - gain.leading_zeros()) / 2;
        let mut out = Vec::new();
        for g in 0..2 {
            let xg: Vec<i8> = f[g * cg * 36..(g + 1) * cg * 36].to_vec();
            let x = Chw::from_i8(cg, 6, 6, &xg);
            let p = ConvParams {
                w: w[g * mg * cg * r * r..(g + 1) * mg * cg * r * r].to_vec(),
                m: mg,
                c: cg,
                r,
                s: r,
                bias: bias[g * mg..(g + 1) * mg].to_vec(),
                lshift: vec![0; cg],
                rshift: vec![rshift; mg],
            };
            // Final layer of the net: no ReLU.
            let y = crate::quant::ops::conv_fixed(&x, &p, 1, 1, QuantMode::W8A8, false);
            out.extend(y.data.into_iter().map(|v| v as i8));
        }
        assert_eq!(got, out, "grouped net != split-and-concat reference");
    }

    #[test]
    fn sim_backend_rejects_bad_sizes() {
        let be = SimBackend::new(&zoo::tinycnn(), &[2]).unwrap();
        assert!(be.execute_i8("tinycnn_b2_sim8", &[0i8; 5]).is_err());
        assert!(be.execute_i8("tinycnn_b9_sim8", &[0i8; 9]).is_err());
        assert!(SimBackend::new(&zoo::tinycnn(), &[]).is_err());
    }
}
