//! FPGA board resource models.
//!
//! The allocator consumes a [`Board`] exactly the way the paper's framework
//! consumes "available hardware resources on FPGA" (Sec. 4): total DSP
//! slices Θ-source, BRAM budget α, and DDR bandwidth β, plus LUT/FF caps
//! used by the engine cost model for feasibility checks.


/// An FPGA board: the paper's (Θ, α, β) plus logic resources.
#[derive(Debug, Clone, PartialEq)]
pub struct Board {
    /// Board name (`zc706`, …).
    pub name: String,
    /// DSP48 slices (paper Θ-source; ZC706: 900).
    pub dsps: usize,
    /// LUTs (ZC706: 218 600).
    pub luts: usize,
    /// Flip-flops (ZC706: 437 200).
    pub ffs: usize,
    /// BRAM36 blocks (paper α; ZC706: 545).
    pub bram36: usize,
    /// Peak DDR bandwidth in bytes/second (paper β; ZC706 DDR3-1066 x64).
    pub ddr_bytes_per_sec: f64,
    /// Accelerator clock in Hz (paper f; Table I: 200 MHz).
    pub freq_hz: f64,
}

impl Board {
    /// DDR bytes available per accelerator cycle (β in the simulator's units).
    pub fn ddr_bytes_per_cycle(&self) -> f64 {
        self.ddr_bytes_per_sec / self.freq_hz
    }

    /// BRAM18 half-blocks (the engine cost model sizes in 18 Kb units).
    pub fn bram18(&self) -> usize {
        self.bram36 * 2
    }
}

/// Xilinx ZC706 (Zynq XC7Z045) — the paper's evaluation board.
pub fn zc706() -> Board {
    Board {
        name: "zc706".into(),
        dsps: 900,
        luts: 218_600,
        ffs: 437_200,
        bram36: 545,
        // PL-side DDR3-1600 64-bit SODIMM: 8 B x 1600 MT/s = 12.8 GB/s peak
        // (the PS DDR is separate; the accelerator owns the PL SODIMM).
        ddr_bytes_per_sec: 12.8e9,
        freq_hz: 200e6,
    }
}

/// Xilinx ZCU102 (Zynq UltraScale+ XCZU9EG) — larger design-space point.
pub fn zcu102() -> Board {
    Board {
        name: "zcu102".into(),
        dsps: 2520,
        luts: 274_080,
        ffs: 548_160,
        bram36: 912,
        ddr_bytes_per_sec: 19.2e9,
        freq_hz: 300e6,
    }
}

/// Xilinx VC707 (Virtex-7 XC7VX485T).
pub fn vc707() -> Board {
    Board {
        name: "vc707".into(),
        dsps: 2800,
        luts: 303_600,
        ffs: 607_200,
        bram36: 1030,
        ddr_bytes_per_sec: 12.8e9,
        freq_hz: 200e6,
    }
}

/// Small Zynq (ZedBoard, XC7Z020) — resource-starved point for sweeps.
pub fn zedboard() -> Board {
    Board {
        name: "zedboard".into(),
        dsps: 220,
        luts: 53_200,
        ffs: 106_400,
        bram36: 140,
        ddr_bytes_per_sec: 4.2e9,
        freq_hz: 150e6,
    }
}

/// Look a board up by name.
pub fn by_name(name: &str) -> crate::Result<Board> {
    match name {
        "zc706" => Ok(zc706()),
        "zcu102" => Ok(zcu102()),
        "vc707" => Ok(vc707()),
        "zedboard" => Ok(zedboard()),
        other => anyhow::bail!("unknown board '{other}' (zc706 zcu102 vc707 zedboard)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zc706_matches_table1_denominators() {
        // Table I prints utilization against these exact totals.
        let b = zc706();
        assert_eq!(b.dsps, 900);
        assert_eq!(b.luts, 218_600);
        assert_eq!(b.ffs, 437_200);
        assert_eq!(b.bram36, 545);
    }

    #[test]
    fn bytes_per_cycle_consistent() {
        let b = zc706();
        assert!((b.ddr_bytes_per_cycle() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("de10").is_err());
    }
}
