//! CNN model descriptions.
//!
//! A [`Network`] is an ordered list of pipeline stages ([`Layer`]), exactly
//! the granularity the paper instantiates on chip (Sec. 3.2: convolution,
//! pooling and fully-connected layers are individual pipeline stages).
//!
//! Dimension names follow the paper's Eq. 1:
//! `O[M×H×W] = f(W[M×C×R×S] ⊗ I[C×(H+R−1)×(W+S−1)] + B[M])` — `H`/`W` are
//! *output* feature-map sizes, so a layer's MAC count is
//! `π = H·W·R·S·C·M` (Algorithm 1, line 1).

pub mod config;
pub mod zoo;


/// A convolution stage (paper Eq. 1). `h`/`w` are output sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels `C`.
    pub c: usize,
    /// Output channels `M`.
    pub m: usize,
    /// Output feature-map height `H`.
    pub h: usize,
    /// Output feature-map width `W`.
    pub w: usize,
    /// Kernel height `R`.
    pub r: usize,
    /// Kernel width `S`.
    pub s: usize,
    /// Stride `G` (paper's stride of conv/pool layer).
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
    /// Grouped convolution factor (AlexNet's split layers). MACs divide by
    /// this; `1` everywhere else.
    pub groups: usize,
}

impl ConvShape {
    /// MAC operations for this layer: `π = H·W·R·S·(C/g)·M` (Alg. 1 line 1).
    pub fn macs(&self) -> u64 {
        (self.h as u64)
            * (self.w as u64)
            * (self.r as u64)
            * (self.s as u64)
            * (self.c as u64 / self.groups as u64)
            * (self.m as u64)
    }

    /// Weight parameter count `M·(C/g)·R·S`.
    pub fn weights(&self) -> u64 {
        (self.m as u64) * (self.c as u64 / self.groups as u64) * (self.r as u64) * (self.s as u64)
    }

    /// Input feature-map height consumed (`H·G` pre-stride rows, ignoring pad).
    pub fn in_h(&self) -> usize {
        (self.h - 1) * self.stride + self.r - 2 * self.pad
    }

    /// Input feature-map width.
    pub fn in_w(&self) -> usize {
        (self.w - 1) * self.stride + self.s - 2 * self.pad
    }
}

/// A max-pooling stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolShape {
    /// Channels (pass-through).
    pub c: usize,
    /// Output height.
    pub h: usize,
    /// Output width.
    pub w: usize,
    /// Window size.
    pub r: usize,
    /// Stride `G`.
    pub stride: usize,
}

/// A fully-connected stage — allocated like a `1×1` conv on a `1×1` map
/// (the paper pipelines FC layers as stages too).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FcShape {
    /// Input features.
    pub n_in: usize,
    /// Output features.
    pub n_out: usize,
}

impl FcShape {
    /// MACs = `n_in · n_out`.
    pub fn macs(&self) -> u64 {
        self.n_in as u64 * self.n_out as u64
    }
}

/// One pipeline stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// Convolution.
    Conv(ConvShape),
    /// Max pooling.
    Pool(PoolShape),
    /// Fully connected.
    Fc(FcShape),
}

impl Layer {
    /// MAC count (pooling contributes none — comparators, not DSPs).
    pub fn macs(&self) -> u64 {
        match self {
            Layer::Conv(c) => c.macs(),
            Layer::Pool(_) => 0,
            Layer::Fc(f) => f.macs(),
        }
    }

    /// Weight parameters held in DDR for this stage.
    pub fn weights(&self) -> u64 {
        match self {
            Layer::Conv(c) => c.weights(),
            Layer::Pool(_) => 0,
            Layer::Fc(f) => f.macs(),
        }
    }

    /// Stage stride `G` (Eq. 3's `G_j`): rows consumed per row produced.
    pub fn stride(&self) -> usize {
        match self {
            Layer::Conv(c) => c.stride,
            Layer::Pool(p) => p.stride,
            Layer::Fc(_) => 1,
        }
    }

    /// Output rows per frame (`H` for spatial stages, 1 for FC).
    pub fn out_rows(&self) -> usize {
        match self {
            Layer::Conv(c) => c.h,
            Layer::Pool(p) => p.h,
            Layer::Fc(_) => 1,
        }
    }

    /// Does this stage consume DSP multipliers?
    pub fn uses_dsps(&self) -> bool {
        self.macs() > 0
    }

    /// Short human label (`conv3x3/512`, `pool2`, `fc4096`).
    pub fn label(&self) -> String {
        match self {
            Layer::Conv(c) => format!("conv{}x{}/{}", c.r, c.s, c.m),
            Layer::Pool(p) => format!("pool{}", p.r),
            Layer::Fc(f) => format!("fc{}", f.n_out),
        }
    }
}

/// A full network: the unit the allocator + simulator operate on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    /// Zoo name (`vgg16`, `alexnet`, `zf`, `yolo`, …).
    pub name: String,
    /// Input `(C, H, W)`.
    pub input: (usize, usize, usize),
    /// Pipeline stages in order.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total MAC count.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Complexity in GOP (paper counts 2 ops per MAC: multiply + add).
    pub fn gops(&self) -> f64 {
        2.0 * self.macs() as f64 / 1e9
    }

    /// Total weight parameters.
    pub fn weights(&self) -> u64 {
        self.layers.iter().map(Layer::weights).sum()
    }

    /// Input rows `H_0` (Eq. 4 denominator).
    pub fn h0(&self) -> usize {
        self.input.1
    }

    /// Indices of DSP-consuming stages.
    pub fn compute_layers(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].uses_dsps())
            .collect()
    }

    /// Structural validation: channel/spatial continuity between stages,
    /// and rejection of zero-extent layers (any dimension of 0 rows, 0
    /// columns, 0 channels, 0 features, a 0-size kernel or a 0 stride).
    /// Downstream cycle models index `need_rows - 1` style tables, so a
    /// degenerate stage must be a typed error here, not a panic there.
    pub fn validate(&self) -> crate::Result<()> {
        let (mut c, mut h, mut w) = self.input;
        anyhow::ensure!(
            c > 0 && h > 0 && w > 0,
            "network {}: zero-extent input {}x{}x{}",
            self.name,
            c,
            h,
            w
        );
        let mut flat: Option<usize> = None;
        for (i, l) in self.layers.iter().enumerate() {
            match l {
                Layer::Conv(cv) => {
                    anyhow::ensure!(flat.is_none(), "layer {i}: conv after fc");
                    anyhow::ensure!(
                        cv.c > 0
                            && cv.m > 0
                            && cv.h > 0
                            && cv.w > 0
                            && cv.r > 0
                            && cv.s > 0
                            && cv.stride > 0
                            && cv.groups > 0,
                        "layer {i} ({}): zero-extent conv dimension",
                        l.label()
                    );
                    anyhow::ensure!(
                        cv.c == c,
                        "layer {i} ({}): expects C={} but previous stage produces {c}",
                        l.label(),
                        cv.c
                    );
                    anyhow::ensure!(cv.c % cv.groups == 0, "layer {i}: groups must divide C");
                    anyhow::ensure!(cv.m % cv.groups == 0, "layer {i}: groups must divide M");
                    let eh = (h + 2 * cv.pad - cv.r) / cv.stride + 1;
                    let ew = (w + 2 * cv.pad - cv.s) / cv.stride + 1;
                    anyhow::ensure!(
                        cv.h == eh && cv.w == ew,
                        "layer {i} ({}): declared {}x{}, geometry gives {eh}x{ew}",
                        l.label(),
                        cv.h,
                        cv.w
                    );
                    c = cv.m;
                    h = cv.h;
                    w = cv.w;
                }
                Layer::Pool(p) => {
                    anyhow::ensure!(flat.is_none(), "layer {i}: pool after fc");
                    anyhow::ensure!(
                        p.c > 0 && p.h > 0 && p.w > 0 && p.r > 0 && p.stride > 0,
                        "layer {i} (pool): zero-extent pool dimension"
                    );
                    anyhow::ensure!(p.c == c, "layer {i}: pool channels {} != {c}", p.c);
                    let eh = (h - p.r) / p.stride + 1;
                    let ew = (w - p.r) / p.stride + 1;
                    anyhow::ensure!(
                        p.h == eh && p.w == ew,
                        "layer {i} (pool): declared {}x{}, geometry gives {eh}x{ew}",
                        p.h,
                        p.w
                    );
                    h = p.h;
                    w = p.w;
                }
                Layer::Fc(f) => {
                    anyhow::ensure!(
                        f.n_in > 0 && f.n_out > 0,
                        "layer {i} (fc): zero-extent fc dimension"
                    );
                    let n = flat.unwrap_or(c * h * w);
                    anyhow::ensure!(
                        f.n_in == n,
                        "layer {i} (fc): expects n_in={} but gets {n}",
                        f.n_in
                    );
                    flat = Some(f.n_out);
                }
            }
        }
        Ok(())
    }
}

/// Convenience conv builder used by the zoo tables.
#[allow(clippy::too_many_arguments)]
pub fn conv(c: usize, m: usize, h: usize, w: usize, r: usize, stride: usize, pad: usize) -> Layer {
    Layer::Conv(ConvShape {
        c,
        m,
        h,
        w,
        r,
        s: r,
        stride,
        pad,
        groups: 1,
    })
}

/// Grouped conv builder (AlexNet).
#[allow(clippy::too_many_arguments)]
pub fn gconv(
    c: usize,
    m: usize,
    h: usize,
    w: usize,
    r: usize,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Layer {
    Layer::Conv(ConvShape {
        c,
        m,
        h,
        w,
        r,
        s: r,
        stride,
        pad,
        groups,
    })
}

/// Pool builder.
pub fn pool(c: usize, h: usize, w: usize, r: usize, stride: usize) -> Layer {
    Layer::Pool(PoolShape { c, h, w, r, stride })
}

/// FC builder.
pub fn fc(n_in: usize, n_out: usize) -> Layer {
    Layer::Fc(FcShape { n_in, n_out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_match_hand_count() {
        // VGG16 conv1_1: 224·224·3·3·3·64 = 86.7M MACs
        let l = conv(3, 64, 224, 224, 3, 1, 1);
        assert_eq!(l.macs(), 224 * 224 * 9 * 3 * 64);
    }

    #[test]
    fn grouped_conv_halves_macs() {
        let g1 = gconv(96, 256, 27, 27, 5, 1, 2, 1);
        let g2 = gconv(96, 256, 27, 27, 5, 1, 2, 2);
        assert_eq!(g1.macs(), 2 * g2.macs());
    }

    #[test]
    fn validate_catches_channel_mismatch() {
        let net = Network {
            name: "bad".into(),
            input: (3, 8, 8),
            layers: vec![conv(4, 8, 8, 8, 3, 1, 1)],
        };
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_geometry() {
        let net = Network {
            name: "bad".into(),
            input: (3, 8, 8),
            layers: vec![conv(3, 8, 9, 8, 3, 1, 1)],
        };
        assert!(net.validate().is_err());
    }

    #[test]
    fn in_dims_invert_out_dims() {
        let Layer::Conv(c) = conv(3, 8, 112, 112, 3, 2, 1) else {
            unreachable!()
        };
        // floor() in the forward direction makes inversion minimal, not
        // unique: a 112-row stride-2 output needs at least 223 input rows.
        assert_eq!(c.in_h(), 223);
    }

    #[test]
    fn validate_rejects_zero_extent_layers() {
        // Zero output height: previously this panicked deep in the cycle
        // model (`need_rows - 1` underflow); now it is a typed error here.
        let net = Network {
            name: "degenerate".into(),
            input: (3, 8, 8),
            layers: vec![Layer::Conv(ConvShape {
                c: 3,
                m: 8,
                h: 0,
                w: 8,
                r: 3,
                s: 3,
                stride: 1,
                pad: 1,
                groups: 1,
            })],
        };
        let err = net.validate().unwrap_err().to_string();
        assert!(err.contains("zero-extent"), "got: {err}");

        // Zero stride would divide by zero in the geometry check.
        let net = Network {
            name: "degenerate".into(),
            input: (3, 8, 8),
            layers: vec![Layer::Conv(ConvShape {
                c: 3,
                m: 8,
                h: 8,
                w: 8,
                r: 3,
                s: 3,
                stride: 0,
                pad: 1,
                groups: 1,
            })],
        };
        assert!(net.validate().unwrap_err().to_string().contains("zero-extent"));

        // Zero-feature FC.
        let net = Network {
            name: "degenerate".into(),
            input: (1, 2, 2),
            layers: vec![fc(4, 0)],
        };
        assert!(net.validate().unwrap_err().to_string().contains("zero-extent"));

        // Zero-extent input.
        let net = Network {
            name: "degenerate".into(),
            input: (3, 0, 8),
            layers: vec![],
        };
        assert!(net.validate().unwrap_err().to_string().contains("zero-extent"));

        // Zero-window pool.
        let net = Network {
            name: "degenerate".into(),
            input: (3, 8, 8),
            layers: vec![pool(3, 8, 8, 0, 1)],
        };
        assert!(net.validate().unwrap_err().to_string().contains("zero-extent"));
    }

    #[test]
    fn fc_treated_as_compute_layer() {
        assert!(fc(100, 10).uses_dsps());
        assert!(!pool(8, 4, 4, 2, 2).uses_dsps());
    }
}
