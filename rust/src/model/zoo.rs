//! The model zoo: the paper's four evaluation networks (Table I) plus the
//! small functional nets that mirror `python/compile/model.py`.
//!
//! Complexity cross-check (unit-tested): VGG16 ≈ 30.94 GOP, AlexNet ≈ 1.45,
//! ZF ≈ 2.34, YOLO ≈ 40.14 — the paper's "Complexity(GOP)" row.

use super::{conv, fc, gconv, pool, Network};

/// VGG16 @ 224×224 — 13 convs + 3 FC, 30.94 GOP (paper Table I).
pub fn vgg16() -> Network {
    Network {
        name: "vgg16".into(),
        input: (3, 224, 224),
        layers: vec![
            conv(3, 64, 224, 224, 3, 1, 1),
            conv(64, 64, 224, 224, 3, 1, 1),
            pool(64, 112, 112, 2, 2),
            conv(64, 128, 112, 112, 3, 1, 1),
            conv(128, 128, 112, 112, 3, 1, 1),
            pool(128, 56, 56, 2, 2),
            conv(128, 256, 56, 56, 3, 1, 1),
            conv(256, 256, 56, 56, 3, 1, 1),
            conv(256, 256, 56, 56, 3, 1, 1),
            pool(256, 28, 28, 2, 2),
            conv(256, 512, 28, 28, 3, 1, 1),
            conv(512, 512, 28, 28, 3, 1, 1),
            conv(512, 512, 28, 28, 3, 1, 1),
            pool(512, 14, 14, 2, 2),
            conv(512, 512, 14, 14, 3, 1, 1),
            conv(512, 512, 14, 14, 3, 1, 1),
            conv(512, 512, 14, 14, 3, 1, 1),
            pool(512, 7, 7, 2, 2),
            fc(25088, 4096),
            fc(4096, 4096),
            fc(4096, 1000),
        ],
    }
}

/// AlexNet @ 227×227 — grouped convs as in the original, 1.45 GOP.
pub fn alexnet() -> Network {
    Network {
        name: "alexnet".into(),
        input: (3, 227, 227),
        layers: vec![
            conv(3, 96, 55, 55, 11, 4, 0),
            pool(96, 27, 27, 3, 2),
            gconv(96, 256, 27, 27, 5, 1, 2, 2),
            pool(256, 13, 13, 3, 2),
            conv(256, 384, 13, 13, 3, 1, 1),
            gconv(384, 384, 13, 13, 3, 1, 1, 2),
            gconv(384, 256, 13, 13, 3, 1, 1, 2),
            pool(256, 6, 6, 3, 2),
            fc(9216, 4096),
            fc(4096, 4096),
            fc(4096, 1000),
        ],
    }
}

/// ZFNet @ 224×224 — 2.34 GOP.
pub fn zf() -> Network {
    Network {
        name: "zf".into(),
        input: (3, 224, 224),
        layers: vec![
            conv(3, 96, 110, 110, 7, 2, 1),
            pool(96, 55, 55, 2, 2),
            conv(96, 256, 26, 26, 5, 2, 0),
            pool(256, 13, 13, 2, 2),
            conv(256, 384, 13, 13, 3, 1, 1),
            conv(384, 384, 13, 13, 3, 1, 1),
            conv(384, 256, 13, 13, 3, 1, 1),
            pool(256, 6, 6, 3, 2),
            fc(9216, 4096),
            fc(4096, 4096),
            fc(4096, 1000),
        ],
    }
}

/// YOLOv1 @ 448×448 — 24 convs + 2 FC, 40.14 GOP.
pub fn yolo() -> Network {
    let mut layers = vec![
        conv(3, 64, 224, 224, 7, 2, 3),
        pool(64, 112, 112, 2, 2),
        conv(64, 192, 112, 112, 3, 1, 1),
        pool(192, 56, 56, 2, 2),
        conv(192, 128, 56, 56, 1, 1, 0),
        conv(128, 256, 56, 56, 3, 1, 1),
        conv(256, 256, 56, 56, 1, 1, 0),
        conv(256, 512, 56, 56, 3, 1, 1),
        pool(512, 28, 28, 2, 2),
    ];
    for _ in 0..4 {
        layers.push(conv(512, 256, 28, 28, 1, 1, 0));
        layers.push(conv(256, 512, 28, 28, 3, 1, 1));
    }
    layers.push(conv(512, 512, 28, 28, 1, 1, 0));
    layers.push(conv(512, 1024, 28, 28, 3, 1, 1));
    layers.push(pool(1024, 14, 14, 2, 2));
    for _ in 0..2 {
        layers.push(conv(1024, 512, 14, 14, 1, 1, 0));
        layers.push(conv(512, 1024, 14, 14, 3, 1, 1));
    }
    layers.push(conv(1024, 1024, 14, 14, 3, 1, 1));
    layers.push(conv(1024, 1024, 7, 7, 3, 2, 1));
    layers.push(conv(1024, 1024, 7, 7, 3, 1, 1));
    layers.push(conv(1024, 1024, 7, 7, 3, 1, 1));
    layers.push(fc(50176, 4096));
    layers.push(fc(4096, 1470));
    Network {
        name: "yolo".into(),
        input: (3, 448, 448),
        layers,
    }
}

/// TinyCNN @ 32×32 — mirrors `python/compile/model.py::tinycnn` (the e2e
/// serving artifact). Shapes must match the AOT manifest (integration-tested).
pub fn tinycnn() -> Network {
    Network {
        name: "tinycnn".into(),
        input: (3, 32, 32),
        layers: vec![
            conv(3, 16, 32, 32, 3, 1, 1),
            pool(16, 16, 16, 2, 2),
            conv(16, 32, 16, 16, 3, 1, 1),
            pool(32, 8, 8, 2, 2),
            conv(32, 32, 8, 8, 3, 1, 1),
            pool(32, 4, 4, 2, 2),
            fc(512, 10),
        ],
    }
}

/// LeNet-5 @ 28×28 — mirrors the Python zoo.
pub fn lenet() -> Network {
    Network {
        name: "lenet".into(),
        input: (1, 28, 28),
        layers: vec![
            conv(1, 6, 28, 28, 5, 1, 2),
            pool(6, 14, 14, 2, 2),
            conv(6, 16, 10, 10, 5, 1, 0),
            pool(16, 5, 5, 2, 2),
            fc(400, 120),
            fc(120, 84),
            fc(84, 10),
        ],
    }
}

/// VGG-micro @ 32×32 — mirrors the Python zoo (deep-pipeline artifact).
pub fn vgg_micro() -> Network {
    Network {
        name: "vgg_micro".into(),
        input: (3, 32, 32),
        layers: vec![
            conv(3, 16, 32, 32, 3, 1, 1),
            conv(16, 16, 32, 32, 3, 1, 1),
            pool(16, 16, 16, 2, 2),
            conv(16, 32, 16, 16, 3, 1, 1),
            conv(32, 32, 16, 16, 3, 1, 1),
            pool(32, 8, 8, 2, 2),
            conv(32, 48, 8, 8, 3, 1, 1),
            conv(48, 48, 8, 8, 3, 1, 1),
            pool(48, 4, 4, 2, 2),
            fc(768, 10),
        ],
    }
}

/// Look a network up by zoo name.
pub fn by_name(name: &str) -> crate::Result<Network> {
    let net = match name {
        "vgg16" => vgg16(),
        "alexnet" => alexnet(),
        "zf" => zf(),
        "yolo" => yolo(),
        "tinycnn" => tinycnn(),
        "lenet" => lenet(),
        "vgg_micro" => vgg_micro(),
        other => anyhow::bail!(
            "unknown network '{other}' (zoo: vgg16 alexnet zf yolo tinycnn lenet vgg_micro)"
        ),
    };
    Ok(net)
}

/// The four Table I evaluation networks.
pub fn paper_nets() -> Vec<Network> {
    vec![vgg16(), alexnet(), zf(), yolo()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_gop(net: &Network, paper: f64, tol: f64) {
        let got = net.gops();
        assert!(
            (got - paper).abs() / paper < tol,
            "{}: {got:.2} GOP vs paper {paper:.2}",
            net.name
        );
    }

    #[test]
    fn all_zoo_nets_validate() {
        for n in [
            vgg16(),
            alexnet(),
            zf(),
            yolo(),
            tinycnn(),
            lenet(),
            vgg_micro(),
        ] {
            n.validate().unwrap_or_else(|e| panic!("{}: {e}", n.name));
        }
    }

    #[test]
    fn complexity_matches_table1() {
        assert_gop(&vgg16(), 30.94, 0.02);
        assert_gop(&alexnet(), 1.45, 0.02);
        assert_gop(&zf(), 2.34, 0.02);
        assert_gop(&yolo(), 40.14, 0.02);
    }

    #[test]
    fn vgg16_has_13_convs_3_fcs() {
        let n = vgg16();
        let convs = n
            .layers
            .iter()
            .filter(|l| matches!(l, super::super::Layer::Conv(_)))
            .count();
        let fcs = n
            .layers
            .iter()
            .filter(|l| matches!(l, super::super::Layer::Fc(_)))
            .count();
        assert_eq!((convs, fcs), (13, 3));
    }

    #[test]
    fn yolo_has_24_convs() {
        let n = yolo();
        let convs = n
            .layers
            .iter()
            .filter(|l| matches!(l, super::super::Layer::Conv(_)))
            .count();
        assert_eq!(convs, 24);
    }

    #[test]
    fn by_name_round_trips() {
        for name in ["vgg16", "alexnet", "zf", "yolo", "tinycnn", "lenet", "vgg_micro"] {
            assert_eq!(by_name(name).unwrap().name, name);
        }
        assert!(by_name("resnet50").is_err());
    }
}
