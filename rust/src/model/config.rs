//! Network config files: load/save [`Network`] descriptions as JSON so
//! users can run the framework on models outside the built-in zoo
//! (`flexipipe allocate --model mynet.json`).
//!
//! Hand-rolled (de)serialization over [`crate::util::json`] — the offline
//! vendor set has no serde.

use super::{ConvShape, FcShape, Layer, Network, PoolShape};
use crate::util::json::{self, num, obj, Value};
use std::path::Path;

/// Serialize a network to a JSON value.
pub fn to_json(net: &Network) -> Value {
    let layers: Vec<Value> = net
        .layers
        .iter()
        .map(|l| match l {
            Layer::Conv(c) => obj(vec![
                ("kind", Value::Str("conv".into())),
                ("c", num(c.c)),
                ("m", num(c.m)),
                ("h", num(c.h)),
                ("w", num(c.w)),
                ("r", num(c.r)),
                ("s", num(c.s)),
                ("stride", num(c.stride)),
                ("pad", num(c.pad)),
                ("groups", num(c.groups)),
            ]),
            Layer::Pool(p) => obj(vec![
                ("kind", Value::Str("pool".into())),
                ("c", num(p.c)),
                ("h", num(p.h)),
                ("w", num(p.w)),
                ("r", num(p.r)),
                ("stride", num(p.stride)),
            ]),
            Layer::Fc(f) => obj(vec![
                ("kind", Value::Str("fc".into())),
                ("n_in", num(f.n_in)),
                ("n_out", num(f.n_out)),
            ]),
        })
        .collect();
    obj(vec![
        ("name", Value::Str(net.name.clone())),
        (
            "input",
            Value::Arr(vec![num(net.input.0), num(net.input.1), num(net.input.2)]),
        ),
        ("layers", Value::Arr(layers)),
    ])
}

/// Deserialize a network from a JSON value.
pub fn from_json(v: &Value) -> crate::Result<Network> {
    let name = v.str_field("name")?.to_string();
    let input = v.req("input")?.as_arr().ok_or_else(|| {
        anyhow::anyhow!("'input' must be an array [c, h, w]")
    })?;
    anyhow::ensure!(input.len() == 3, "'input' must have 3 entries");
    let dim = |i: usize| -> crate::Result<usize> {
        input[i]
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("input[{i}] must be a non-negative integer"))
    };
    let layers = v
        .req("layers")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("'layers' must be an array"))?
        .iter()
        .enumerate()
        .map(|(i, lv)| -> crate::Result<Layer> {
            let kind = lv
                .str_field("kind")
                .map_err(|e| anyhow::anyhow!("layer {i}: {e}"))?;
            let l = match kind {
                "conv" => Layer::Conv(ConvShape {
                    c: lv.usize_field("c")?,
                    m: lv.usize_field("m")?,
                    h: lv.usize_field("h")?,
                    w: lv.usize_field("w")?,
                    r: lv.usize_field("r")?,
                    s: lv.usize_field("s")?,
                    stride: lv.usize_field("stride")?,
                    pad: lv.usize_field("pad")?,
                    groups: lv.get("groups").and_then(Value::as_usize).unwrap_or(1),
                }),
                "pool" => Layer::Pool(PoolShape {
                    c: lv.usize_field("c")?,
                    h: lv.usize_field("h")?,
                    w: lv.usize_field("w")?,
                    r: lv.usize_field("r")?,
                    stride: lv.usize_field("stride")?,
                }),
                "fc" => Layer::Fc(FcShape {
                    n_in: lv.usize_field("n_in")?,
                    n_out: lv.usize_field("n_out")?,
                }),
                other => anyhow::bail!("layer {i}: unknown kind '{other}'"),
            };
            Ok(l)
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(Network {
        name,
        input: (dim(0)?, dim(1)?, dim(2)?),
        layers,
    })
}

/// Load and validate a network from a JSON file.
pub fn load(path: impl AsRef<Path>) -> crate::Result<Network> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
    let net = from_json(&json::parse(&text)?)?;
    net.validate()?;
    Ok(net)
}

/// Save a network to JSON (pretty-printed, stable field order).
pub fn save(net: &Network, path: impl AsRef<Path>) -> crate::Result<()> {
    std::fs::write(path.as_ref(), to_json(net).to_pretty())?;
    Ok(())
}

/// Resolve `--model`: a zoo name, or a path to a JSON file.
pub fn resolve(spec: &str) -> crate::Result<Network> {
    if spec.ends_with(".json") || spec.contains('/') {
        load(spec)
    } else {
        super::zoo::by_name(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn round_trip_preserves_network() {
        let dir = std::env::temp_dir().join("flexipipe_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("vgg16.json");
        let net = zoo::vgg16();
        save(&net, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(net, back);
    }

    #[test]
    fn all_zoo_nets_round_trip_via_value() {
        for net in zoo::paper_nets() {
            let back = from_json(&to_json(&net)).unwrap();
            assert_eq!(net, back);
        }
    }

    #[test]
    fn load_rejects_invalid_geometry() {
        let dir = std::env::temp_dir().join("flexipipe_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        let mut net = zoo::tinycnn();
        if let Layer::Conv(ref mut c) = net.layers[0] {
            c.m = 64; // downstream layers now mismatch
        }
        std::fs::write(&p, to_json(&net).to_string()).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn groups_default_to_one() {
        let v = json::parse(
            r#"{"name":"t","input":[1,3,3],
                "layers":[{"kind":"conv","c":1,"m":1,"h":3,"w":3,"r":1,"s":1,"stride":1,"pad":0}]}"#,
        )
        .unwrap();
        let net = from_json(&v).unwrap();
        let Layer::Conv(c) = &net.layers[0] else {
            panic!()
        };
        assert_eq!(c.groups, 1);
    }

    #[test]
    fn resolve_prefers_zoo_names() {
        assert_eq!(resolve("alexnet").unwrap().name, "alexnet");
        assert!(resolve("nonexistent").is_err());
    }
}
